"""Docs gate for CI: intra-repo markdown links + public-API docstrings.

    python tools/check_docs.py

Two checks, both hard failures:

1. Every relative link in the repo's markdown files must resolve to an
   existing file (anchors and external http(s)/mailto links are ignored).
2. Every public module / class / function / method in the public API
   surface (``src/repro/core``, ``src/repro/storage`` and
   ``src/repro/kernels``) must have a docstring. Private names (leading
   underscore), dunders, and trivial dataclass plumbing like
   ``children``/``__repr__`` overrides are exempt.

Run locally before pushing; CI runs it in the ``docs`` job.
"""

from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# markdown files that gate the build (generated/contract files excluded)
MD_SKIP = {"CHANGES.md", "ISSUE.md", "SNIPPETS.md", "PAPERS.md", "PAPER.md"}

# public API surface for the docstring check
API_DIRS = ("src/repro/core", "src/repro/storage", "src/repro/kernels")

# names whose absence of a docstring is noise, not information
EXEMPT_NAMES = {"children", "main"}

# implementations of a protocol documented once on the base/contract:
# the Velox operator contract (open/add_input/finish), expression-tree
# methods (evaluate/out_dtype/references), the exchange protocol, storage
# source hooks, and jax pytree hooks. The *base* definition still needs a
# docstring; overrides inherit it.
PROTOCOL_METHODS = {
    "open", "add_input", "finish",
    "evaluate", "out_dtype", "references",
    "repartition", "broadcast",
    "num_rows", "num_chunks",
    "tree_flatten", "tree_unflatten",
}

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_markdown_links() -> list:
    """Every relative markdown link must point at an existing file."""
    errors = []
    for dirpath, dirnames, filenames in os.walk(REPO):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", ".claude", "__pycache__",
                                    "results", ".ruff_cache",
                                    ".pytest_cache")]
        for fname in filenames:
            if not fname.endswith(".md") or fname in MD_SKIP:
                continue
            path = os.path.join(dirpath, fname)
            with open(path, encoding="utf-8") as f:
                text = f.read()
            for m in _LINK.finditer(text):
                target = m.group(1).split("#")[0]
                if (not target or target.startswith(("http://", "https://",
                                                     "mailto:"))):
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), target))
                if not os.path.exists(resolved):
                    rel = os.path.relpath(path, REPO)
                    errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def _missing_docstrings(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, REPO)
    errors = []
    if ast.get_docstring(tree) is None:
        errors.append(f"{rel}: missing module docstring")

    def is_public(name: str) -> bool:
        return not name.startswith("_") and name not in EXEMPT_NAMES

    def visit(node, prefix: str) -> None:
        in_class = bool(prefix)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                if not is_public(child.name):
                    continue           # private classes gate nothing
                if ast.get_docstring(child) is None:
                    errors.append(
                        f"{rel}: class {prefix}{child.name} has no docstring")
                visit(child, f"{prefix}{child.name}.")
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if in_class and child.name in PROTOCOL_METHODS \
                        and prefix.count(".") >= 1 and _has_base(node):
                    continue           # documented-protocol implementation
                if is_public(child.name) and ast.get_docstring(child) is None:
                    errors.append(
                        f"{rel}: def {prefix}{child.name} has no docstring")

    def _has_base(cls) -> bool:
        return isinstance(cls, ast.ClassDef) and bool(cls.bases)

    visit(tree, "")
    return errors


def check_api_docstrings() -> list:
    """Public classes/functions in the API surface carry docstrings."""
    errors = []
    for api_dir in API_DIRS:
        root = os.path.join(REPO, api_dir)
        for fname in sorted(os.listdir(root)):
            if fname.endswith(".py"):
                errors.extend(_missing_docstrings(os.path.join(root, fname)))
    return errors


def main() -> int:
    errors = check_markdown_links() + check_api_docstrings()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"\n{len(errors)} docs problems")
        return 1
    print("docs OK: markdown links resolve, public API is documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
