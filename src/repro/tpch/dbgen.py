"""dbgen: numpy TPC-H-like data generator (the paper's modified dbgen).

Deterministic per (sf, seed). Value distributions follow the TPC-H spec
closely enough that all 22 queries return non-empty, selective results;
the engine is always validated against the numpy oracle over the *same*
generated data, so generator fidelity affects realism, not correctness.

``write_dataset`` emits the column-chunk format of §2.2 (one file per
column x chunk, metadata in file names) — the "modified dbgen to generate
compact data-sets" of the paper.
"""

from __future__ import annotations

import os
from typing import Dict

import numpy as np

from ..core import dtypes as dt
from ..core.session import Catalog, InMemoryTable
from ..storage.colchunk import ColumnChunkTable, write_table
from . import schema as S

_D = dt.date_to_i32

START = _D("1992-01-01")             # o_orderdate range per spec
END = _D("1998-08-02")


def _bytes_fmt(prefix: str, keys: np.ndarray, width: int) -> np.ndarray:
    out = np.full((len(keys), width), ord(" "), dtype=np.uint8)
    for i, k in enumerate(keys):
        s = f"{prefix}{k:09d}".encode()[:width]
        out[i, : len(s)] = np.frombuffer(s, dtype=np.uint8)
    return out


def _rand_text(rng, n: int, width: int, inject=None, p_inject=0.0) -> np.ndarray:
    """Random lowercase filler text with optional injected pattern."""
    data = rng.integers(ord("a"), ord("z") + 1, size=(n, width)).astype(np.uint8)
    spaces = rng.random((n, width)) < 0.15
    data[spaces] = ord(" ")
    if inject is not None and p_inject > 0:
        hit = rng.random(n) < p_inject
        pat = np.frombuffer(inject.encode(), dtype=np.uint8)
        pos = rng.integers(0, max(width - len(pat), 1), size=n)
        for i in np.where(hit)[0]:
            data[i, pos[i]: pos[i] + len(pat)] = pat
    return data


def _phones(rng, nationkeys: np.ndarray) -> np.ndarray:
    n = len(nationkeys)
    out = np.full((n, 15), ord(" "), dtype=np.uint8)
    rest = rng.integers(0, 10, size=(n, 9))
    for i in range(n):
        code = nationkeys[i] + 10
        s = f"{code:02d}-{rest[i,0]}{rest[i,1]}{rest[i,2]}-{rest[i,3]}" \
            f"{rest[i,4]}{rest[i,5]}-{rest[i,6]}{rest[i,7]}{rest[i,8]}".encode()
        out[i, : len(s)] = np.frombuffer(s, dtype=np.uint8)
    return out


def _part_names(rng, n: int) -> np.ndarray:
    """p_name: 5 color words (Q9/Q20 match '%green%' / 'forest%')."""
    out = np.full((n, 36), ord(" "), dtype=np.uint8)
    colors = [c.encode() for c in S.COLORS]
    picks = rng.integers(0, len(colors), size=(n, 5))
    for i in range(n):
        s = b" ".join(colors[j] for j in picks[i])[:36]
        out[i, : len(s)] = np.frombuffer(s, dtype=np.uint8)
    return out


def generate(sf: float = 0.01, seed: int = 19940729) -> Dict[str, Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n_supp = max(int(S.BASE_ROWS["supplier"] * sf), 10)
    n_cust = max(int(S.BASE_ROWS["customer"] * sf), 30)
    n_part = max(int(S.BASE_ROWS["part"] * sf), 40)
    n_ord = max(int(S.BASE_ROWS["orders"] * sf), 150)

    region = {
        "r_regionkey": np.arange(5, dtype=np.int32),
        "r_name": np.arange(5, dtype=np.int32),
    }
    nation = {
        "n_nationkey": np.arange(25, dtype=np.int32),
        "n_name": np.arange(25, dtype=np.int32),
        "n_regionkey": np.array(S.NATION_REGION, dtype=np.int32),
    }

    s_nation = rng.integers(0, 25, n_supp).astype(np.int32)
    supplier = {
        "s_suppkey": np.arange(1, n_supp + 1, dtype=np.int32),
        "s_name": _bytes_fmt("Supplier#", np.arange(1, n_supp + 1), 18),
        "s_address": _rand_text(rng, n_supp, 16),
        "s_nationkey": s_nation,
        "s_phone": _phones(rng, s_nation),
        "s_acctbal": (rng.random(n_supp) * 10999.99 - 999.99).astype(np.float32),
        "s_comment": _rand_text(rng, n_supp, 44,
                                inject="Customer Complaints", p_inject=0.02),
    }

    c_nation = rng.integers(0, 25, n_cust).astype(np.int32)
    customer = {
        "c_custkey": np.arange(1, n_cust + 1, dtype=np.int32),
        "c_name": _bytes_fmt("Customer#", np.arange(1, n_cust + 1), 18),
        "c_address": _rand_text(rng, n_cust, 16),
        "c_nationkey": c_nation,
        "c_phone": _phones(rng, c_nation),
        "c_acctbal": (rng.random(n_cust) * 10999.99 - 999.99).astype(np.float32),
        "c_mktsegment": rng.integers(0, 5, n_cust).astype(np.int32),
        "c_comment": _rand_text(rng, n_cust, 24),
    }

    part = {
        "p_partkey": np.arange(1, n_part + 1, dtype=np.int32),
        "p_name": _part_names(rng, n_part),
        "p_mfgr": rng.integers(0, 5, n_part).astype(np.int32),
        "p_brand": rng.integers(0, 25, n_part).astype(np.int32),
        "p_type": rng.integers(0, 150, n_part).astype(np.int32),
        "p_size": rng.integers(1, 51, n_part).astype(np.int32),
        "p_container": rng.integers(0, 40, n_part).astype(np.int32),
        "p_retailprice": (900 + (np.arange(1, n_part + 1) % 1000) / 10
                          ).astype(np.float32),
    }

    # partsupp: 4 suppliers per part (spec), supplier spread deterministic
    ps_part = np.repeat(np.arange(1, n_part + 1, dtype=np.int32), 4)
    ps_supp = np.zeros(n_part * 4, dtype=np.int32)
    for j in range(4):
        ps_supp[j::4] = ((np.arange(n_part) + j * (n_supp // 4 + 1)) % n_supp) + 1
    partsupp = {
        "ps_partkey": ps_part,
        "ps_suppkey": ps_supp,
        "ps_availqty": rng.integers(1, 10000, n_part * 4).astype(np.int32),
        "ps_supplycost": (rng.random(n_part * 4) * 999 + 1).astype(np.float32),
    }

    o_orderdate = rng.integers(START, END - 151, n_ord).astype(np.int32)
    orders_key = np.arange(1, n_ord + 1, dtype=np.int32) * 4 - 3  # sparse keys
    n_lines = rng.integers(1, 8, n_ord)
    # per spec, a third of customers never place orders (keeps Q13/Q22 real)
    ordering_custs = np.array([k for k in range(1, n_cust + 1) if k % 3 != 0],
                              dtype=np.int32)
    orders = {
        "o_orderkey": orders_key,
        "o_custkey": rng.choice(ordering_custs, n_ord).astype(np.int32),
        "o_orderstatus": np.zeros(n_ord, dtype=np.int32),   # fixed below
        "o_totalprice": np.zeros(n_ord, dtype=np.float32),  # fixed below
        "o_orderdate": o_orderdate,
        "o_orderpriority": rng.integers(0, 5, n_ord).astype(np.int32),
        "o_shippriority": np.zeros(n_ord, dtype=np.int32),
        "o_comment": _rand_text(rng, n_ord, 44),
    }
    # Q13 patterns: 'special...requests'
    special = rng.random(n_ord) < 0.05
    pat1 = np.frombuffer(b"special", dtype=np.uint8)
    pat2 = np.frombuffer(b"requests", dtype=np.uint8)
    for i in np.where(special)[0]:
        orders["o_comment"][i, 2: 2 + len(pat1)] = pat1
        orders["o_comment"][i, 14: 14 + len(pat2)] = pat2

    # lineitem
    total = int(n_lines.sum())
    l_order = np.repeat(orders_key, n_lines)
    l_odate = np.repeat(o_orderdate, n_lines)
    ln = np.concatenate([np.arange(1, k + 1) for k in n_lines]).astype(np.int32)
    l_part = rng.integers(1, n_part + 1, total).astype(np.int32)
    # supplier must be one of the part's 4 partsupp suppliers (Q9/Q20/Q21)
    pick = rng.integers(0, 4, total)
    l_supp = ps_supp.reshape(n_part, 4)[l_part - 1, pick]
    qty = rng.integers(1, 51, total).astype(np.float32)
    price = part["p_retailprice"][l_part - 1] * qty / 10.0
    ship_delay = rng.integers(1, 122, total)
    commit_delay = rng.integers(30, 91, total)
    receipt_delay = rng.integers(1, 31, total)
    l_ship = (l_odate + ship_delay).astype(np.int32)
    l_commit = (l_odate + commit_delay).astype(np.int32)
    l_receipt = (l_ship + receipt_delay).astype(np.int32)
    today = _D("1995-06-17")
    lstat = (l_ship > today).astype(np.int32)           # 'O' if not shipped
    rflag = np.where(
        l_receipt <= today,
        rng.integers(0, 2, total) * 2,                  # 'A'(0) or 'R'(2)
        1,                                              # 'N'
    ).astype(np.int32)
    lineitem = {
        "l_orderkey": l_order,
        "l_partkey": l_part,
        "l_suppkey": l_supp.astype(np.int32),
        "l_linenumber": ln,
        "l_quantity": qty,
        "l_extendedprice": price.astype(np.float32),
        "l_discount": (rng.integers(0, 11, total) / 100).astype(np.float32),
        "l_tax": (rng.integers(0, 9, total) / 100).astype(np.float32),
        "l_returnflag": rflag,
        "l_linestatus": lstat,
        "l_shipdate": l_ship,
        "l_commitdate": l_commit,
        "l_receiptdate": l_receipt,
        "l_shipmode": rng.integers(0, 7, total).astype(np.int32),
        "l_shipinstruct": rng.integers(0, 4, total).astype(np.int32),
    }

    # order status/totalprice derived from lines
    all_f = np.ones(n_ord, dtype=bool)
    any_f = np.zeros(n_ord, dtype=bool)
    order_index = np.repeat(np.arange(n_ord), n_lines)
    np.logical_and.at(all_f, order_index, lstat == 0)
    np.logical_or.at(any_f, order_index, lstat == 0)
    orders["o_orderstatus"] = np.where(all_f, 0, np.where(any_f, 2, 1)).astype(np.int32)
    tp = np.zeros(n_ord, dtype=np.float64)
    np.add.at(tp, order_index,
              lineitem["l_extendedprice"] * (1 + lineitem["l_tax"])
              * (1 - lineitem["l_discount"]))
    orders["o_totalprice"] = tp.astype(np.float32)

    return {
        "region": region, "nation": nation, "supplier": supplier,
        "customer": customer, "part": part, "partsupp": partsupp,
        "orders": orders, "lineitem": lineitem,
    }


def load_catalog(sf: float = 0.01, seed: int = 19940729) -> Catalog:
    """In-memory catalog (tests); for the storage path use write_dataset."""
    data = generate(sf, seed)
    cat = Catalog()
    for name, tab in data.items():
        cat.register(InMemoryTable(name, tab, S.SCHEMAS[name],
                                   unique_keys=(S.PRIMARY_KEYS[name],)))
    return cat


# fact tables are clustered (sorted) on their date column before chunking,
# so chunk min/max stats form a useful zone map for date-range predicates
# (the layout a date-partitioned warehouse table would have)
CLUSTER_KEYS = {"lineitem": "l_shipdate", "orders": "o_orderdate"}


def write_dataset(root: str, sf: float = 0.01, seed: int = 19940729,
                  chunks: int = 4,
                  cluster: bool = True) -> Dict[str, Dict[str, np.ndarray]]:
    """Generate + persist in the column-chunk format. Returns the data
    actually written (row order included), so oracles computed from the
    return value always agree with scans of the files."""
    data = generate(sf, seed)
    if cluster:
        for name, key in CLUSTER_KEYS.items():
            order = np.argsort(data[name][key], kind="stable")
            data[name] = {c: v[order] for c, v in data[name].items()}
    os.makedirs(root, exist_ok=True)
    for name, tab in data.items():
        c = chunks if name in ("lineitem", "orders", "partsupp", "customer",
                               "part") else 1
        write_table(root, name, tab, S.SCHEMAS[name], chunks=c)
    return data


def storage_catalog(root: str, skip_with_stats: bool = True) -> Catalog:
    cat = Catalog()
    for name in S.SCHEMAS:
        src = ColumnChunkTable(root, name, skip_with_stats)
        src.unique_keys = (S.PRIMARY_KEYS[name],)
        cat.register(src)
    return cat
