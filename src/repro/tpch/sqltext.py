"""TPC-H queries as SQL text, for the ``core.sql`` frontend.

Twenty of the 22 spec queries expressed in the SQL subset the frontend
lowers (see ``core/sql.py``); output column names match ``oracle.py`` so
``tests.tpch_util.assert_results_match`` validates SQL-path executions the
same way it validates the hand-built plans. Two queries need constructs
the engine has no operator for and are intentionally absent, documented in
``UNSUPPORTED``: Q13 (LEFT OUTER JOIN aggregation) and Q21 (correlated
EXISTS with a non-equi predicate).

Three queries are restated in equivalent SQL to stay inside the engine's
static-shape operator set — the results are identical:

* Q10/Q18 group through a derived table on the integer key alone instead
  of the spec's "drag every output column into GROUP BY" form (the engine
  groups on int-family keys; ``c_acctbal``/``o_totalprice`` are floats);
* Q11's threshold subexpression ``0.0001 / SF`` is a literal computed from
  the catalog row counts, so the text depends on the loaded scale factor.

``sql_text(qnum, catalog)`` returns the text; the same string runs on
DuckDB unmodified (``tests/sql_oracle.py`` does exactly that).
"""

from __future__ import annotations

_Q1 = """
SELECT l_returnflag, l_linestatus,
       sum(l_quantity) AS sum_qty,
       sum(l_extendedprice) AS sum_base_price,
       sum(l_extendedprice * (1 - l_discount)) AS sum_disc_price,
       sum(l_extendedprice * (1 - l_discount) * (1 + l_tax)) AS sum_charge,
       avg(l_quantity) AS avg_qty,
       avg(l_extendedprice) AS avg_price,
       avg(l_discount) AS avg_disc,
       count(*) AS count_order
FROM lineitem
WHERE l_shipdate <= DATE '1998-12-01' - INTERVAL '90' DAY
GROUP BY l_returnflag, l_linestatus
ORDER BY l_returnflag, l_linestatus
"""

_Q2 = """
SELECT s_acctbal, s_name, n_name, p_partkey
FROM part, supplier, partsupp, nation, region
WHERE p_partkey = ps_partkey AND s_suppkey = ps_suppkey
  AND p_size = 15 AND p_type LIKE '%BRASS'
  AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
  AND r_name = 'EUROPE'
  AND ps_supplycost = (
      SELECT min(ps_supplycost)
      FROM partsupp, supplier, nation, region
      WHERE p_partkey = ps_partkey AND s_suppkey = ps_suppkey
        AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
        AND r_name = 'EUROPE')
ORDER BY s_acctbal DESC, n_name, s_name, p_partkey
LIMIT 100
"""

_Q3 = """
SELECT l_orderkey,
       sum(l_extendedprice * (1 - l_discount)) AS revenue,
       o_orderdate, o_shippriority
FROM customer, orders, lineitem
WHERE c_mktsegment = 'BUILDING'
  AND c_custkey = o_custkey AND l_orderkey = o_orderkey
  AND o_orderdate < DATE '1995-03-15'
  AND l_shipdate > DATE '1995-03-15'
GROUP BY l_orderkey, o_orderdate, o_shippriority
ORDER BY revenue DESC, o_orderdate
LIMIT 10
"""

_Q4 = """
SELECT o_orderpriority, count(*) AS order_count
FROM orders
WHERE o_orderdate >= DATE '1993-07-01'
  AND o_orderdate < DATE '1993-07-01' + INTERVAL '3' MONTH
  AND EXISTS (
      SELECT * FROM lineitem
      WHERE l_orderkey = o_orderkey AND l_commitdate < l_receiptdate)
GROUP BY o_orderpriority
ORDER BY o_orderpriority
"""

_Q5 = """
SELECT n_name, sum(l_extendedprice * (1 - l_discount)) AS revenue
FROM customer, orders, lineitem, supplier, nation, region
WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
  AND l_suppkey = s_suppkey AND c_nationkey = s_nationkey
  AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
  AND r_name = 'ASIA'
  AND o_orderdate >= DATE '1994-01-01'
  AND o_orderdate < DATE '1994-01-01' + INTERVAL '1' YEAR
GROUP BY n_name
ORDER BY revenue DESC
"""

_Q6 = """
SELECT sum(l_extendedprice * l_discount) AS revenue
FROM lineitem
WHERE l_shipdate >= DATE '1994-01-01'
  AND l_shipdate < DATE '1994-01-01' + INTERVAL '1' YEAR
  AND l_discount BETWEEN 0.05 AND 0.07
  AND l_quantity < 24
"""

_Q7 = """
SELECT supp_nation, cust_nation, l_year, sum(volume) AS revenue
FROM (SELECT n1.n_name AS supp_nation, n2.n_name AS cust_nation,
             EXTRACT(YEAR FROM l_shipdate) AS l_year,
             l_extendedprice * (1 - l_discount) AS volume
      FROM supplier, lineitem, orders, customer, nation n1, nation n2
      WHERE s_suppkey = l_suppkey AND o_orderkey = l_orderkey
        AND c_custkey = o_custkey
        AND s_nationkey = n1.n_nationkey
        AND c_nationkey = n2.n_nationkey
        AND ((n1.n_name = 'FRANCE' AND n2.n_name = 'GERMANY')
             OR (n1.n_name = 'GERMANY' AND n2.n_name = 'FRANCE'))
        AND l_shipdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
     ) shipping
GROUP BY supp_nation, cust_nation, l_year
ORDER BY supp_nation, cust_nation, l_year
"""

_Q8 = """
SELECT o_year,
       sum(CASE WHEN nation = 'BRAZIL' THEN volume ELSE 0.0 END)
         / sum(volume) AS mkt_share
FROM (SELECT EXTRACT(YEAR FROM o_orderdate) AS o_year,
             l_extendedprice * (1 - l_discount) AS volume,
             n2.n_name AS nation
      FROM part, supplier, lineitem, orders, customer,
           nation n1, nation n2, region
      WHERE p_partkey = l_partkey AND s_suppkey = l_suppkey
        AND l_orderkey = o_orderkey AND o_custkey = c_custkey
        AND c_nationkey = n1.n_nationkey AND n1.n_regionkey = r_regionkey
        AND r_name = 'AMERICA' AND s_nationkey = n2.n_nationkey
        AND o_orderdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
        AND p_type = 'ECONOMY ANODIZED STEEL'
     ) all_nations
GROUP BY o_year
ORDER BY o_year
"""

_Q9 = """
SELECT nation, o_year, sum(amount) AS sum_profit
FROM (SELECT n_name AS nation,
             EXTRACT(YEAR FROM o_orderdate) AS o_year,
             l_extendedprice * (1 - l_discount)
               - ps_supplycost * l_quantity AS amount
      FROM part, supplier, lineitem, partsupp, orders, nation
      WHERE s_suppkey = l_suppkey
        AND ps_suppkey = l_suppkey AND ps_partkey = l_partkey
        AND p_partkey = l_partkey AND o_orderkey = l_orderkey
        AND s_nationkey = n_nationkey
        AND p_name LIKE '%green%'
     ) profit
GROUP BY nation, o_year
ORDER BY nation, o_year DESC
"""

_Q10 = """
SELECT c_custkey, revenue, c_acctbal
FROM customer,
     (SELECT o_custkey,
             sum(l_extendedprice * (1 - l_discount)) AS revenue
      FROM orders, lineitem
      WHERE l_orderkey = o_orderkey
        AND o_orderdate >= DATE '1993-10-01'
        AND o_orderdate < DATE '1993-10-01' + INTERVAL '3' MONTH
        AND l_returnflag = 'R'
      GROUP BY o_custkey) rev
WHERE c_custkey = o_custkey
ORDER BY revenue DESC
LIMIT 20
"""

_Q11 = """
SELECT ps_partkey, sum(ps_supplycost * ps_availqty) AS value
FROM partsupp, supplier, nation
WHERE ps_suppkey = s_suppkey AND s_nationkey = n_nationkey
  AND n_name = 'GERMANY'
GROUP BY ps_partkey
HAVING sum(ps_supplycost * ps_availqty) > (
    SELECT sum(ps_supplycost * ps_availqty) * {fraction:.12g}
    FROM partsupp, supplier, nation
    WHERE ps_suppkey = s_suppkey AND s_nationkey = n_nationkey
      AND n_name = 'GERMANY')
ORDER BY value DESC
"""

_Q12 = """
SELECT l_shipmode,
       sum(CASE WHEN o_orderpriority = '1-URGENT'
                  OR o_orderpriority = '2-HIGH'
                THEN 1 ELSE 0 END) AS high_line_count,
       sum(CASE WHEN o_orderpriority <> '1-URGENT'
                 AND o_orderpriority <> '2-HIGH'
                THEN 1 ELSE 0 END) AS low_line_count
FROM orders, lineitem
WHERE o_orderkey = l_orderkey
  AND l_shipmode IN ('MAIL', 'SHIP')
  AND l_commitdate < l_receiptdate
  AND l_shipdate < l_commitdate
  AND l_receiptdate >= DATE '1994-01-01'
  AND l_receiptdate < DATE '1994-01-01' + INTERVAL '1' YEAR
GROUP BY l_shipmode
ORDER BY l_shipmode
"""

_Q14 = """
SELECT 100.00 * sum(CASE WHEN p_type LIKE 'PROMO%'
                         THEN l_extendedprice * (1 - l_discount)
                         ELSE 0.0 END)
       / sum(l_extendedprice * (1 - l_discount)) AS promo_revenue
FROM lineitem, part
WHERE l_partkey = p_partkey
  AND l_shipdate >= DATE '1995-09-01'
  AND l_shipdate < DATE '1995-09-01' + INTERVAL '1' MONTH
"""

_Q15 = """
WITH revenue AS (
    SELECT l_suppkey AS supplier_no,
           sum(l_extendedprice * (1 - l_discount)) AS total_revenue
    FROM lineitem
    WHERE l_shipdate >= DATE '1996-01-01'
      AND l_shipdate < DATE '1996-01-01' + INTERVAL '3' MONTH
    GROUP BY l_suppkey)
SELECT s_suppkey, total_revenue
FROM supplier, revenue
WHERE s_suppkey = supplier_no
  AND total_revenue = (SELECT max(total_revenue) FROM revenue)
ORDER BY s_suppkey
"""

_Q16 = """
SELECT p_brand, p_type, p_size,
       count(DISTINCT ps_suppkey) AS supplier_cnt
FROM partsupp, part
WHERE p_partkey = ps_partkey
  AND p_brand <> 'Brand#45'
  AND p_type NOT LIKE 'MEDIUM POLISHED%'
  AND p_size IN (49, 14, 23, 45, 19, 3, 36, 9)
  AND ps_suppkey NOT IN (
      SELECT s_suppkey FROM supplier
      WHERE s_comment LIKE '%Customer%Complaints%')
GROUP BY p_brand, p_type, p_size
ORDER BY supplier_cnt DESC, p_brand, p_type, p_size
"""

_Q17 = """
SELECT sum(l_extendedprice) / 7.0 AS avg_yearly
FROM lineitem, part
WHERE p_partkey = l_partkey
  AND p_brand = 'Brand#23' AND p_container = 'MED BOX'
  AND l_quantity < (SELECT 0.2 * avg(l_quantity)
                    FROM lineitem l2
                    WHERE l2.l_partkey = p_partkey)
"""

_Q18 = """
SELECT c_custkey, o_orderkey, o_orderdate, o_totalprice, sum_qty
FROM customer, orders,
     (SELECT l_orderkey, sum(l_quantity) AS sum_qty
      FROM lineitem GROUP BY l_orderkey) lq
WHERE sum_qty > 300
  AND c_custkey = o_custkey AND o_orderkey = l_orderkey
ORDER BY o_totalprice DESC, o_orderdate
LIMIT 100
"""

_Q19 = """
SELECT sum(l_extendedprice * (1 - l_discount)) AS revenue
FROM lineitem JOIN part ON p_partkey = l_partkey
WHERE ((p_brand = 'Brand#12'
        AND p_container IN ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG')
        AND l_quantity >= 1 AND l_quantity <= 11
        AND p_size BETWEEN 1 AND 5)
    OR (p_brand = 'Brand#23'
        AND p_container IN ('MED BAG', 'MED BOX', 'MED PKG', 'MED PACK')
        AND l_quantity >= 10 AND l_quantity <= 20
        AND p_size BETWEEN 1 AND 10)
    OR (p_brand = 'Brand#34'
        AND p_container IN ('LG CASE', 'LG BOX', 'LG PACK', 'LG PKG')
        AND l_quantity >= 20 AND l_quantity <= 30
        AND p_size BETWEEN 1 AND 15))
  AND l_shipmode IN ('AIR', 'REG AIR')
  AND l_shipinstruct = 'DELIVER IN PERSON'
"""

_Q20 = """
SELECT s_name, s_suppkey
FROM supplier, nation
WHERE s_suppkey IN (
      SELECT ps_suppkey
      FROM partsupp, part
      WHERE ps_partkey = p_partkey
        AND p_name LIKE 'forest%'
        AND ps_availqty > (
            SELECT 0.5 * sum(l_quantity)
            FROM lineitem
            WHERE l_partkey = ps_partkey AND l_suppkey = ps_suppkey
              AND l_shipdate >= DATE '1994-01-01'
              AND l_shipdate < DATE '1994-01-01' + INTERVAL '1' YEAR))
  AND s_nationkey = n_nationkey AND n_name = 'CANADA'
ORDER BY s_name
"""

_Q22 = """
SELECT cntrycode, count(*) AS numcust, sum(c_acctbal) AS totacctbal
FROM (SELECT SUBSTRING(c_phone, 1, 2) AS cntrycode, c_acctbal, c_custkey
      FROM customer
      WHERE SUBSTRING(c_phone, 1, 2) IN
              ('13', '31', '23', '29', '30', '18', '17')
        AND c_acctbal > (
            SELECT avg(c_acctbal) FROM customer
            WHERE c_acctbal > 0.00
              AND SUBSTRING(c_phone, 1, 2) IN
                    ('13', '31', '23', '29', '30', '18', '17'))
        AND NOT EXISTS (
            SELECT * FROM orders WHERE o_custkey = c_custkey)
     ) custsale
GROUP BY cntrycode
ORDER BY cntrycode
"""

_TEXTS = {1: _Q1, 2: _Q2, 3: _Q3, 4: _Q4, 5: _Q5, 6: _Q6, 7: _Q7, 8: _Q8,
          9: _Q9, 10: _Q10, 11: _Q11, 12: _Q12, 14: _Q14, 15: _Q15,
          16: _Q16, 17: _Q17, 18: _Q18, 19: _Q19, 20: _Q20, 22: _Q22}

SUPPORTED = tuple(sorted(_TEXTS))

#: qnum -> the construct that keeps it off the SQL path (the engine has no
#: operator for it; ``core.sql`` raises SqlUnsupportedError for both)
UNSUPPORTED = {
    13: "LEFT OUTER JOIN (count-orders-per-customer including zeros)",
    21: "correlated EXISTS with a non-equi (<>) predicate",
}


def sql_text(qnum: int, catalog=None) -> str:
    """SQL text for TPC-H query ``qnum``.

    Q11's HAVING threshold is scale-factor dependent (``0.0001 / SF``); the
    spec derives it from the supplier count, so Q11 needs ``catalog``.
    """
    if qnum not in _TEXTS:
        raise KeyError(
            f"q{qnum} has no SQL-path port: "
            f"{UNSUPPORTED.get(qnum, 'unknown query')}")
    text = _TEXTS[qnum]
    if qnum == 11:
        if catalog is None:
            raise ValueError("sql_text(11) needs the catalog (the HAVING "
                             "fraction depends on the scale factor)")
        n_supp = catalog.get("supplier").num_rows()
        fraction = 0.0001 / max(n_supp / 10000.0, 1e-9)
        text = text.replace("{fraction:.12g}", f"{fraction:.12g}")
        return text.strip()
    return text.strip()
