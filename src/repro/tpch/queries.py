"""All 22 TPC-H query plans in the engine's DSL (paper §3.4 runs all 22).

Correlated/EXISTS subqueries are rewritten into joins/aggregations the way
Presto's planner does (semi/anti joins, scalar broadcasts, count-distinct
via dedup). ``max_groups``/``max_matches`` are the planner's capacity hints
(derived from catalog row counts, like a stats-backed optimizer).

Every query is validated against the pure-numpy oracle in oracle.py.
"""

from __future__ import annotations

import math
from typing import Callable, Dict

from ..core import plan as P
from ..core.expr import col, date_lit, lit, prefix_code, year
from . import schema as S

_D = date_lit


def _pow2(n: int) -> int:
    return max(int(2 ** math.ceil(math.log2(max(n, 2)))), 2)


class Sizes:
    """Planner statistics: row counts per table -> capacity hints."""

    def __init__(self, catalog):
        self.n = {t: catalog.get(t).num_rows() for t in S.SCHEMAS}

    def groups(self, table: str, frac: float = 1.0) -> int:
        return _pow2(int(self.n[table] * frac) + 8)


def _dict_code(schema_col, value: str) -> int:
    return schema_col.dictionary.index(value)


def _nation(name: str) -> int:
    return S.NATIONS.index(name)


def _region(name: str) -> int:
    return S.REGIONS.index(name)


# ---------------------------------------------------------------------------

def q1(sz: Sizes) -> P.PlanNode:
    disc_price = col("l_extendedprice") * (lit(1.0) - col("l_discount"))
    charge = disc_price * (lit(1.0) + col("l_tax"))
    return P.OrderBy(
        P.Aggregation(
            P.Project(
                P.TableScan("lineitem",
                            columns=["l_returnflag", "l_linestatus",
                                     "l_quantity", "l_extendedprice",
                                     "l_discount", "l_tax", "l_shipdate"],
                            filter=col("l_shipdate") <= lit(
                                _D("1998-12-01").value - 90)),
                [("l_returnflag", col("l_returnflag")),
                 ("l_linestatus", col("l_linestatus")),
                 ("l_quantity", col("l_quantity")),
                 ("l_extendedprice", col("l_extendedprice")),
                 ("disc_price", disc_price),
                 ("charge", charge),
                 ("l_discount", col("l_discount"))]),
            group_keys=["l_returnflag", "l_linestatus"],
            aggs=[("sum_qty", "sum", "l_quantity"),
                  ("sum_base_price", "sum", "l_extendedprice"),
                  ("sum_disc_price", "sum", "disc_price"),
                  ("sum_charge", "sum", "charge"),
                  ("avg_qty", "avg", "l_quantity"),
                  ("avg_price", "avg", "l_extendedprice"),
                  ("avg_disc", "avg", "l_discount"),
                  ("count_order", "count", None)],
            max_groups=8),
        keys=["l_returnflag", "l_linestatus"])


def q2(sz: Sizes) -> P.PlanNode:
    eu_nation = P.Join(
        probe=P.TableScan("nation"),
        build=P.Filter(P.TableScan("region"),
                       col("r_name") == lit(_region("EUROPE"))),
        probe_keys=["n_regionkey"], build_keys=["r_regionkey"],
        join_type="left_semi")
    eu_supp = P.Join(
        probe=P.TableScan("supplier"),
        build=eu_nation,
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        build_payload=["n_name"])
    ps_eu = P.Join(
        probe=P.TableScan("partsupp",
                          columns=["ps_partkey", "ps_suppkey", "ps_supplycost"]),
        build=eu_supp,
        probe_keys=["ps_suppkey"], build_keys=["s_suppkey"],
        build_payload=["s_acctbal", "s_name", "s_address", "s_phone",
                       "s_comment", "n_name"])
    parts_f = P.Filter(
        P.TableScan("part", columns=["p_partkey", "p_mfgr", "p_size", "p_type"]),
        (col("p_size") == lit(15)) & _type_endswith_brass())
    joined = P.Join(probe=ps_eu, build=parts_f,
                    probe_keys=["ps_partkey"], build_keys=["p_partkey"],
                    build_payload=["p_mfgr"])
    min_cost = P.Aggregation(joined, ["ps_partkey"],
                             [("min_cost", "min", "ps_supplycost")],
                             max_groups=sz.groups("part"))
    final = P.Filter(
        P.Join(probe=joined, build=min_cost,
               probe_keys=["ps_partkey"], build_keys=["ps_partkey"],
               build_payload=["min_cost"]),
        col("ps_supplycost") == col("min_cost"))
    return P.OrderBy(
        P.Project(final, [("s_acctbal", col("s_acctbal")),
                          ("s_name", col("s_name")),
                          ("n_name", col("n_name")),
                          ("p_partkey", col("ps_partkey")),
                          ("p_mfgr", col("p_mfgr")),
                          ("s_address", col("s_address")),
                          ("s_phone", col("s_phone")),
                          ("s_comment", col("s_comment"))]),
        keys=["s_acctbal", "n_name", "s_name", "p_partkey"],
        descending=[True, False, False, False], limit=100)


def _type_endswith_brass():
    # p_type is dictionary encoded; LIKE '%BRASS' = membership in the codes
    # whose decoded string ends with BRASS (planner constant-folds this)
    codes = [i for i, t in enumerate(S.TYPES) if t.endswith("BRASS")]
    return col("p_type").isin(codes)


def q3(sz: Sizes) -> P.PlanNode:
    cust = P.Filter(P.TableScan("customer", columns=["c_custkey", "c_mktsegment"]),
                    col("c_mktsegment") == lit(_dict_code(
                        S.CUSTOMER["c_mktsegment"], "BUILDING")))
    orders = P.Join(
        probe=P.Filter(P.TableScan("orders",
                                   columns=["o_orderkey", "o_custkey",
                                            "o_orderdate", "o_shippriority"]),
                       col("o_orderdate") < _D("1995-03-15")),
        build=cust, probe_keys=["o_custkey"], build_keys=["c_custkey"],
        join_type="left_semi")
    li = P.Join(
        probe=P.Filter(P.TableScan("lineitem",
                                   columns=["l_orderkey", "l_extendedprice",
                                            "l_discount", "l_shipdate"]),
                       col("l_shipdate") > _D("1995-03-15")),
        build=orders, probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
        build_payload=["o_orderdate", "o_shippriority"])
    return P.OrderBy(
        P.Aggregation(
            P.Project(li, [("l_orderkey", col("l_orderkey")),
                           ("o_orderdate", col("o_orderdate")),
                           ("o_shippriority", col("o_shippriority")),
                           ("rev", col("l_extendedprice")
                            * (lit(1.0) - col("l_discount")))]),
            group_keys=["l_orderkey"],
            aggs=[("revenue", "sum", "rev"),
                  ("o_orderdate", "first", "o_orderdate"),
                  ("o_shippriority", "first", "o_shippriority")],
            max_groups=sz.groups("orders")),
        keys=["revenue", "o_orderdate"], descending=[True, False], limit=10)


def q4(sz: Sizes) -> P.PlanNode:
    late = P.Filter(P.TableScan("lineitem",
                                columns=["l_orderkey", "l_commitdate",
                                         "l_receiptdate"]),
                    col("l_commitdate") < col("l_receiptdate"))
    orders = P.Filter(P.TableScan("orders",
                                  columns=["o_orderkey", "o_orderdate",
                                           "o_orderpriority"]),
                      col("o_orderdate").between(_D("1993-07-01"),
                                                 lit(_D("1993-10-01").value - 1)))
    semi = P.Join(probe=orders, build=late, probe_keys=["o_orderkey"],
                  build_keys=["l_orderkey"], join_type="left_semi")
    return P.OrderBy(
        P.Aggregation(semi, ["o_orderpriority"],
                      [("order_count", "count", None)], max_groups=8),
        keys=["o_orderpriority"])


def q5(sz: Sizes) -> P.PlanNode:
    asia_nation = P.Join(
        probe=P.TableScan("nation"),
        build=P.Filter(P.TableScan("region"),
                       col("r_name") == lit(_region("ASIA"))),
        probe_keys=["n_regionkey"], build_keys=["r_regionkey"],
        join_type="left_semi")
    supp = P.Join(probe=P.TableScan("supplier",
                                    columns=["s_suppkey", "s_nationkey"]),
                  build=asia_nation, probe_keys=["s_nationkey"],
                  build_keys=["n_nationkey"], build_payload=["n_name"])
    orders = P.Join(
        probe=P.Filter(P.TableScan("orders",
                                   columns=["o_orderkey", "o_custkey",
                                            "o_orderdate"]),
                       col("o_orderdate").between(_D("1994-01-01"),
                                                  lit(_D("1995-01-01").value - 1))),
        build=P.TableScan("customer", columns=["c_custkey", "c_nationkey"]),
        probe_keys=["o_custkey"], build_keys=["c_custkey"],
        build_payload=["c_nationkey"])
    li = P.Join(
        probe=P.TableScan("lineitem",
                          columns=["l_orderkey", "l_suppkey",
                                   "l_extendedprice", "l_discount"]),
        build=orders, probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
        build_payload=["c_nationkey"])
    li_s = P.Join(probe=li, build=supp, probe_keys=["l_suppkey"],
                  build_keys=["s_suppkey"],
                  build_payload=["s_nationkey", "n_name"])
    matched = P.Filter(li_s, col("c_nationkey") == col("s_nationkey"))
    return P.OrderBy(
        P.Aggregation(
            P.Project(matched, [("n_name", col("n_name")),
                                ("rev", col("l_extendedprice")
                                 * (lit(1.0) - col("l_discount")))]),
            group_keys=["n_name"], aggs=[("revenue", "sum", "rev")],
            max_groups=32),
        keys=["revenue"], descending=[True])


def q6(sz: Sizes) -> P.PlanNode:
    f = (col("l_shipdate").between(_D("1994-01-01"),
                                   lit(_D("1995-01-01").value - 1))
         & col("l_discount").between(0.05, 0.07)
         & (col("l_quantity") < 24.0))
    return P.Aggregation(
        P.Project(
            P.TableScan("lineitem",
                        columns=["l_shipdate", "l_discount", "l_quantity",
                                 "l_extendedprice"], filter=f),
            [("v", col("l_extendedprice") * col("l_discount"))]),
        group_keys=[], aggs=[("revenue", "sum", "v")], max_groups=1)


def _q7_nations():
    return _nation("FRANCE"), _nation("GERMANY")


def q7(sz: Sizes) -> P.PlanNode:
    fr, de = _q7_nations()
    npair = P.Filter(P.TableScan("nation"),
                     col("n_nationkey").isin([fr, de]))
    supp = P.Join(probe=P.TableScan("supplier",
                                    columns=["s_suppkey", "s_nationkey"]),
                  build=npair, probe_keys=["s_nationkey"],
                  build_keys=["n_nationkey"], build_payload=["n_name"])
    cust = P.Join(probe=P.TableScan("customer",
                                    columns=["c_custkey", "c_nationkey"]),
                  build=npair, probe_keys=["c_nationkey"],
                  build_keys=["n_nationkey"], build_payload=["n_name"])
    cust = P.Project(cust, [("c_custkey", col("c_custkey")),
                            ("cust_nation", col("n_name"))])
    orders = P.Join(probe=P.TableScan("orders",
                                      columns=["o_orderkey", "o_custkey"]),
                    build=cust, probe_keys=["o_custkey"],
                    build_keys=["c_custkey"], build_payload=["cust_nation"])
    li = P.Filter(P.TableScan("lineitem",
                              columns=["l_orderkey", "l_suppkey", "l_shipdate",
                                       "l_extendedprice", "l_discount"]),
                  col("l_shipdate").between(_D("1995-01-01"), _D("1996-12-31")))
    li_s = P.Join(probe=li, build=supp, probe_keys=["l_suppkey"],
                  build_keys=["s_suppkey"], build_payload=["n_name"])
    li_s = P.Project(li_s, [("l_orderkey", col("l_orderkey")),
                            ("supp_nation", col("n_name")),
                            ("l_shipdate", col("l_shipdate")),
                            ("l_extendedprice", col("l_extendedprice")),
                            ("l_discount", col("l_discount"))])
    both = P.Join(probe=li_s, build=orders, probe_keys=["l_orderkey"],
                  build_keys=["o_orderkey"], build_payload=["cust_nation"])
    matched = P.Filter(
        both,
        ((col("supp_nation") == lit(fr)) & (col("cust_nation") == lit(de)))
        | ((col("supp_nation") == lit(de)) & (col("cust_nation") == lit(fr))))
    return P.OrderBy(
        P.Aggregation(
            P.Project(matched, [("supp_nation", col("supp_nation")),
                                ("cust_nation", col("cust_nation")),
                                ("l_year", year(col("l_shipdate"))),
                                ("volume", col("l_extendedprice")
                                 * (lit(1.0) - col("l_discount")))]),
            group_keys=["supp_nation", "cust_nation", "l_year"],
            aggs=[("revenue", "sum", "volume")], max_groups=16),
        keys=["supp_nation", "cust_nation", "l_year"])


def q8(sz: Sizes) -> P.PlanNode:
    target_type = _dict_code(S.PART["p_type"], "ECONOMY ANODIZED STEEL")
    brazil = _nation("BRAZIL")
    part_f = P.Filter(P.TableScan("part", columns=["p_partkey", "p_type"]),
                      col("p_type") == lit(target_type))
    am_cust = P.Join(
        probe=P.TableScan("customer", columns=["c_custkey", "c_nationkey"]),
        build=P.Join(probe=P.TableScan("nation"),
                     build=P.Filter(P.TableScan("region"),
                                    col("r_name") == lit(_region("AMERICA"))),
                     probe_keys=["n_regionkey"], build_keys=["r_regionkey"],
                     join_type="left_semi"),
        probe_keys=["c_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    orders = P.Join(
        probe=P.Filter(P.TableScan("orders",
                                   columns=["o_orderkey", "o_custkey",
                                            "o_orderdate"]),
                       col("o_orderdate").between(_D("1995-01-01"),
                                                  _D("1996-12-31"))),
        build=am_cust, probe_keys=["o_custkey"], build_keys=["c_custkey"],
        join_type="left_semi")
    li = P.Join(
        probe=P.TableScan("lineitem",
                          columns=["l_orderkey", "l_partkey", "l_suppkey",
                                   "l_extendedprice", "l_discount"]),
        build=part_f, probe_keys=["l_partkey"], build_keys=["p_partkey"],
        join_type="left_semi")
    li_o = P.Join(probe=li, build=orders, probe_keys=["l_orderkey"],
                  build_keys=["o_orderkey"], build_payload=["o_orderdate"])
    li_os = P.Join(probe=li_o,
                   build=P.TableScan("supplier",
                                     columns=["s_suppkey", "s_nationkey"]),
                   probe_keys=["l_suppkey"], build_keys=["s_suppkey"],
                   build_payload=["s_nationkey"])
    vols = P.Project(li_os, [
        ("o_year", year(col("o_orderdate"))),
        ("volume", col("l_extendedprice") * (lit(1.0) - col("l_discount"))),
        ("is_brazil", (col("s_nationkey") == lit(brazil)))])
    vols = P.Project(vols, [
        ("o_year", col("o_year")),
        ("volume", col("volume")),
        ("brazil_volume", col("volume") * col("is_brazil"))])
    agg = P.Aggregation(vols, ["o_year"],
                        [("nat", "sum", "brazil_volume"),
                         ("total", "sum", "volume")], max_groups=4)
    return P.OrderBy(
        P.Project(agg, [("o_year", col("o_year")),
                        ("mkt_share", col("nat") / col("total"))]),
        keys=["o_year"])


def q9(sz: Sizes) -> P.PlanNode:
    part_f = P.Filter(P.TableScan("part", columns=["p_partkey", "p_name"]),
                      col("p_name").contains("green"))
    li = P.Join(probe=P.TableScan("lineitem",
                                  columns=["l_orderkey", "l_partkey",
                                           "l_suppkey", "l_quantity",
                                           "l_extendedprice", "l_discount"]),
                build=part_f, probe_keys=["l_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    li_s = P.Join(probe=li,
                  build=P.TableScan("supplier",
                                    columns=["s_suppkey", "s_nationkey"]),
                  probe_keys=["l_suppkey"], build_keys=["s_suppkey"],
                  build_payload=["s_nationkey"])
    li_ps = P.Join(probe=li_s,
                   build=P.TableScan("partsupp"),
                   probe_keys=["l_partkey", "l_suppkey"],
                   build_keys=["ps_partkey", "ps_suppkey"],
                   build_payload=["ps_supplycost"],
                   max_matches=4)   # hashed composite key: collision headroom
    li_o = P.Join(probe=li_ps,
                  build=P.TableScan("orders",
                                    columns=["o_orderkey", "o_orderdate"]),
                  probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
                  build_payload=["o_orderdate"])
    li_n = P.Join(probe=li_o, build=P.TableScan("nation"),
                  probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
                  build_payload=["n_name"])
    amount = (col("l_extendedprice") * (lit(1.0) - col("l_discount"))
              - col("ps_supplycost") * col("l_quantity"))
    return P.OrderBy(
        P.Aggregation(
            P.Project(li_n, [("nation", col("n_name")),
                             ("o_year", year(col("o_orderdate"))),
                             ("amount", amount)]),
            group_keys=["nation", "o_year"],
            aggs=[("sum_profit", "sum", "amount")], max_groups=256),
        keys=["nation", "o_year"], descending=[False, True])


def q10(sz: Sizes) -> P.PlanNode:
    orders = P.Filter(P.TableScan("orders",
                                  columns=["o_orderkey", "o_custkey",
                                           "o_orderdate"]),
                      col("o_orderdate").between(_D("1993-10-01"),
                                                 lit(_D("1994-01-01").value - 1)))
    li = P.Join(
        probe=P.Filter(P.TableScan("lineitem",
                                   columns=["l_orderkey", "l_returnflag",
                                            "l_extendedprice", "l_discount"]),
                       col("l_returnflag") == lit(_dict_code(
                           S.LINEITEM["l_returnflag"], "R"))),
        build=orders, probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
        build_payload=["o_custkey"])
    rev = P.Aggregation(
        P.Project(li, [("o_custkey", col("o_custkey")),
                       ("rev", col("l_extendedprice")
                        * (lit(1.0) - col("l_discount")))]),
        group_keys=["o_custkey"], aggs=[("revenue", "sum", "rev")],
        max_groups=sz.groups("customer"))
    cust = P.Join(probe=P.TableScan("customer"), build=rev,
                  probe_keys=["c_custkey"], build_keys=["o_custkey"],
                  build_payload=["revenue"])
    cust_n = P.Join(probe=cust, build=P.TableScan("nation"),
                    probe_keys=["c_nationkey"], build_keys=["n_nationkey"],
                    build_payload=["n_name"])
    return P.OrderBy(
        P.Project(cust_n, [("c_custkey", col("c_custkey")),
                           ("c_name", col("c_name")),
                           ("revenue", col("revenue")),
                           ("c_acctbal", col("c_acctbal")),
                           ("n_name", col("n_name")),
                           ("c_address", col("c_address")),
                           ("c_phone", col("c_phone")),
                           ("c_comment", col("c_comment"))]),
        keys=["revenue"], descending=[True], limit=20)


def q11(sz: Sizes, fraction: float = None) -> P.PlanNode:
    if fraction is None:
        fraction = 0.0001 / max(sz.n["supplier"] / 10000.0, 1e-9)
    de_supp = P.Join(
        probe=P.TableScan("supplier", columns=["s_suppkey", "s_nationkey"]),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("GERMANY"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    ps = P.Join(probe=P.TableScan("partsupp"), build=de_supp,
                probe_keys=["ps_suppkey"], build_keys=["s_suppkey"],
                join_type="left_semi")
    ps = P.Project(ps, [("ps_partkey", col("ps_partkey")),
                        ("value", col("ps_supplycost") * col("ps_availqty"))])
    per_part = P.Aggregation(ps, ["ps_partkey"], [("value", "sum", "value")],
                             max_groups=sz.groups("part"))
    total = P.Aggregation(P.Project(per_part, [("tval", col("value"))]),
                          [], [("total", "sum", "tval")], max_groups=1)
    filtered = P.Filter(
        P.ScalarBroadcast(per_part, total, ["total"]),
        col("value") > col("total") * lit(float(fraction)))
    return P.OrderBy(P.Project(filtered, [("ps_partkey", col("ps_partkey")),
                                          ("value", col("value"))]),
                     keys=["value"], descending=[True])


def q12(sz: Sizes) -> P.PlanNode:
    mail = _dict_code(S.LINEITEM["l_shipmode"], "MAIL")
    ship = _dict_code(S.LINEITEM["l_shipmode"], "SHIP")
    urgent = _dict_code(S.ORDERS["o_orderpriority"], "1-URGENT")
    high = _dict_code(S.ORDERS["o_orderpriority"], "2-HIGH")
    li = P.Filter(
        P.TableScan("lineitem", columns=["l_orderkey", "l_shipmode",
                                         "l_shipdate", "l_commitdate",
                                         "l_receiptdate"]),
        col("l_shipmode").isin([mail, ship])
        & (col("l_commitdate") < col("l_receiptdate"))
        & (col("l_shipdate") < col("l_commitdate"))
        & col("l_receiptdate").between(_D("1994-01-01"),
                                       lit(_D("1995-01-01").value - 1)))
    li_o = P.Join(probe=li,
                  build=P.TableScan("orders",
                                    columns=["o_orderkey", "o_orderpriority"]),
                  probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
                  build_payload=["o_orderpriority"])
    flagged = P.Project(li_o, [
        ("l_shipmode", col("l_shipmode")),
        ("is_high", (col("o_orderpriority") == lit(urgent))
         | (col("o_orderpriority") == lit(high)))])
    flagged = P.Project(flagged, [
        ("l_shipmode", col("l_shipmode")),
        ("high", col("is_high") * lit(1)),
        ("low", (~col("is_high")) * lit(1))])
    return P.OrderBy(
        P.Aggregation(flagged, ["l_shipmode"],
                      [("high_line_count", "sum", "high"),
                       ("low_line_count", "sum", "low")], max_groups=8),
        keys=["l_shipmode"])


def q13(sz: Sizes) -> P.PlanNode:
    orders = P.Filter(P.TableScan("orders", columns=["o_orderkey", "o_custkey",
                                                     "o_comment"]),
                      ~col("o_comment").contains("special", "requests"))
    per_cust = P.Aggregation(orders, ["o_custkey"],
                             [("c_count", "count", None)],
                             max_groups=sz.groups("customer"))
    cust = P.Join(probe=P.TableScan("customer", columns=["c_custkey"]),
                  build=per_cust, probe_keys=["c_custkey"],
                  build_keys=["o_custkey"], build_payload=["c_count"],
                  join_type="left_outer")
    cust = P.Project(cust, [("c_count", col("c_count") * col("__matched"))])
    return P.OrderBy(
        P.Aggregation(cust, ["c_count"], [("custdist", "count", None)],
                      max_groups=64),
        keys=["custdist", "c_count"], descending=[True, True])


def q14(sz: Sizes) -> P.PlanNode:
    promo_codes = [i for i, t in enumerate(S.TYPES) if t.startswith("PROMO")]
    li = P.Filter(P.TableScan("lineitem",
                              columns=["l_partkey", "l_shipdate",
                                       "l_extendedprice", "l_discount"]),
                  col("l_shipdate").between(_D("1995-09-01"),
                                            lit(_D("1995-10-01").value - 1)))
    li_p = P.Join(probe=li, build=P.TableScan("part",
                                              columns=["p_partkey", "p_type"]),
                  probe_keys=["l_partkey"], build_keys=["p_partkey"],
                  build_payload=["p_type"])
    flagged = P.Project(li_p, [
        ("rev", col("l_extendedprice") * (lit(1.0) - col("l_discount"))),
        ("is_promo", col("p_type").isin(promo_codes))])
    flagged = P.Project(flagged, [
        ("rev", col("rev")),
        ("promo_rev", col("rev") * col("is_promo"))])
    agg = P.Aggregation(flagged, [], [("promo", "sum", "promo_rev"),
                                      ("total", "sum", "rev")], max_groups=1)
    return P.Project(agg, [("promo_revenue",
                            lit(100.0) * col("promo") / col("total"))])


def q15(sz: Sizes) -> P.PlanNode:
    li = P.Filter(P.TableScan("lineitem",
                              columns=["l_suppkey", "l_shipdate",
                                       "l_extendedprice", "l_discount"]),
                  col("l_shipdate").between(_D("1996-01-01"),
                                            lit(_D("1996-04-01").value - 1)))
    rev = P.Aggregation(
        P.Project(li, [("l_suppkey", col("l_suppkey")),
                       ("rev", col("l_extendedprice")
                        * (lit(1.0) - col("l_discount")))]),
        group_keys=["l_suppkey"], aggs=[("total_revenue", "sum", "rev")],
        max_groups=sz.groups("supplier"))
    maxrev = P.Aggregation(P.Project(rev, [("r", col("total_revenue"))]),
                           [], [("max_rev", "max", "r")], max_groups=1)
    best = P.Filter(P.ScalarBroadcast(rev, maxrev, ["max_rev"]),
                    col("total_revenue") == col("max_rev"))
    supp = P.Join(probe=P.TableScan("supplier",
                                    columns=["s_suppkey", "s_name",
                                             "s_address", "s_phone"]),
                  build=best, probe_keys=["s_suppkey"],
                  build_keys=["l_suppkey"], build_payload=["total_revenue"])
    return P.OrderBy(supp, keys=["s_suppkey"])


def q16(sz: Sizes) -> P.PlanNode:
    brand45 = _dict_code(S.PART["p_brand"], "Brand#45")
    med_pol = [i for i, t in enumerate(S.TYPES)
               if t.startswith("MEDIUM POLISHED")]
    sizes = [49, 14, 23, 45, 19, 3, 36, 9]
    part_f = P.Filter(
        P.TableScan("part", columns=["p_partkey", "p_brand", "p_type",
                                     "p_size"]),
        (col("p_brand") != lit(brand45))
        & (~col("p_type").isin(med_pol))
        & col("p_size").isin(sizes))
    ps = P.Join(probe=P.TableScan("partsupp",
                                  columns=["ps_partkey", "ps_suppkey"]),
                build=part_f, probe_keys=["ps_partkey"],
                build_keys=["p_partkey"],
                build_payload=["p_brand", "p_type", "p_size"])
    bad_supp = P.Filter(P.TableScan("supplier",
                                    columns=["s_suppkey", "s_comment"]),
                        col("s_comment").contains("Customer", "Complaints"))
    ps = P.Join(probe=ps, build=bad_supp, probe_keys=["ps_suppkey"],
                build_keys=["s_suppkey"], join_type="left_anti")
    dedup = P.Distinct(ps, ["p_brand", "p_type", "p_size", "ps_suppkey"],
                       max_groups=sz.groups("partsupp"))
    return P.OrderBy(
        P.Aggregation(dedup, ["p_brand", "p_type", "p_size"],
                      [("supplier_cnt", "count", None)],
                      max_groups=sz.groups("part")),
        keys=["supplier_cnt", "p_brand", "p_type", "p_size"],
        descending=[True, False, False, False])


def q17(sz: Sizes) -> P.PlanNode:
    brand = _dict_code(S.PART["p_brand"], "Brand#23")
    box = _dict_code(S.PART["p_container"], "MED BOX")
    part_f = P.Filter(P.TableScan("part", columns=["p_partkey", "p_brand",
                                                   "p_container"]),
                      (col("p_brand") == lit(brand))
                      & (col("p_container") == lit(box)))
    li = P.Join(probe=P.TableScan("lineitem",
                                  columns=["l_partkey", "l_quantity",
                                           "l_extendedprice"]),
                build=part_f, probe_keys=["l_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    avg_q = P.Aggregation(li, ["l_partkey"], [("avg_qty", "avg", "l_quantity")],
                          max_groups=sz.groups("part", 0.1))
    joined = P.Join(probe=li, build=avg_q, probe_keys=["l_partkey"],
                    build_keys=["l_partkey"], build_payload=["avg_qty"])
    small = P.Filter(joined, col("l_quantity") < lit(0.2) * col("avg_qty"))
    agg = P.Aggregation(small, [], [("s", "sum", "l_extendedprice")],
                        max_groups=1)
    return P.Project(agg, [("avg_yearly", col("s") / lit(7.0))])


def q18(sz: Sizes) -> P.PlanNode:
    per_order = P.Aggregation(
        P.TableScan("lineitem", columns=["l_orderkey", "l_quantity"]),
        ["l_orderkey"], [("sum_qty", "sum", "l_quantity")],
        max_groups=sz.groups("orders"))
    big = P.Filter(per_order, col("sum_qty") > lit(300.0))
    orders = P.Join(probe=P.TableScan("orders",
                                      columns=["o_orderkey", "o_custkey",
                                               "o_orderdate", "o_totalprice"]),
                    build=big, probe_keys=["o_orderkey"],
                    build_keys=["l_orderkey"], build_payload=["sum_qty"])
    cust = P.Join(probe=orders,
                  build=P.TableScan("customer",
                                    columns=["c_custkey", "c_name"]),
                  probe_keys=["o_custkey"], build_keys=["c_custkey"],
                  build_payload=["c_name"])
    return P.OrderBy(cust, keys=["o_totalprice", "o_orderdate"],
                     descending=[True, False], limit=100)


def q19(sz: Sizes) -> P.PlanNode:
    sm = S.LINEITEM["l_shipmode"]
    air, reg_air = _dict_code(sm, "AIR"), _dict_code(sm, "REG AIR")
    deliver = _dict_code(S.LINEITEM["l_shipinstruct"], "DELIVER IN PERSON")
    b12 = _dict_code(S.PART["p_brand"], "Brand#12")
    b23 = _dict_code(S.PART["p_brand"], "Brand#23")
    b34 = _dict_code(S.PART["p_brand"], "Brand#34")
    cont = S.PART["p_container"]
    sm_containers = [_dict_code(cont, c) for c in
                     ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    med_containers = [_dict_code(cont, c) for c in
                      ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    lg_containers = [_dict_code(cont, c) for c in
                     ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    li = P.Filter(P.TableScan("lineitem",
                              columns=["l_partkey", "l_quantity",
                                       "l_extendedprice", "l_discount",
                                       "l_shipmode", "l_shipinstruct"]),
                  col("l_shipmode").isin([air, reg_air])
                  & (col("l_shipinstruct") == lit(deliver)))
    li_p = P.Join(probe=li,
                  build=P.TableScan("part",
                                    columns=["p_partkey", "p_brand", "p_size",
                                             "p_container"]),
                  probe_keys=["l_partkey"], build_keys=["p_partkey"],
                  build_payload=["p_brand", "p_size", "p_container"])
    bracket1 = ((col("p_brand") == lit(b12))
                & col("p_container").isin(sm_containers)
                & col("l_quantity").between(1.0, 11.0)
                & col("p_size").between(1, 5))
    bracket2 = ((col("p_brand") == lit(b23))
                & col("p_container").isin(med_containers)
                & col("l_quantity").between(10.0, 20.0)
                & col("p_size").between(1, 10))
    bracket3 = ((col("p_brand") == lit(b34))
                & col("p_container").isin(lg_containers)
                & col("l_quantity").between(20.0, 30.0)
                & col("p_size").between(1, 15))
    matched = P.Filter(li_p, bracket1 | bracket2 | bracket3)
    return P.Aggregation(
        P.Project(matched, [("rev", col("l_extendedprice")
                             * (lit(1.0) - col("l_discount")))]),
        group_keys=[], aggs=[("revenue", "sum", "rev")], max_groups=1)


def q20(sz: Sizes) -> P.PlanNode:
    forest = P.Filter(P.TableScan("part", columns=["p_partkey", "p_name"]),
                      col("p_name").startswith("forest"))
    qty94 = P.Aggregation(
        P.Filter(P.TableScan("lineitem",
                             columns=["l_partkey", "l_suppkey", "l_shipdate",
                                      "l_quantity"]),
                 col("l_shipdate").between(_D("1994-01-01"),
                                           lit(_D("1995-01-01").value - 1))),
        ["l_partkey", "l_suppkey"], [("qty", "sum", "l_quantity")],
        max_groups=sz.groups("partsupp"))
    ps = P.Join(probe=P.TableScan("partsupp",
                                  columns=["ps_partkey", "ps_suppkey",
                                           "ps_availqty"]),
                build=forest, probe_keys=["ps_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    ps_q = P.Join(probe=ps, build=qty94,
                  probe_keys=["ps_partkey", "ps_suppkey"],
                  build_keys=["l_partkey", "l_suppkey"],
                  build_payload=["qty"],
                  max_matches=4)   # hashed composite key: collision headroom
    excess = P.Filter(ps_q, col("ps_availqty") > lit(0.5) * col("qty"))
    supp_keys = P.Distinct(excess, ["ps_suppkey"],
                           max_groups=sz.groups("supplier"))
    ca_supp = P.Join(
        probe=P.Join(probe=P.TableScan("supplier",
                                       columns=["s_suppkey", "s_name",
                                                "s_address", "s_nationkey"]),
                     build=supp_keys, probe_keys=["s_suppkey"],
                     build_keys=["ps_suppkey"], join_type="left_semi"),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("CANADA"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    return P.OrderBy(P.Project(ca_supp, [("s_name", col("s_name")),
                                         ("s_address", col("s_address"))]),
                     keys=["s_name"])


def q21(sz: Sizes) -> P.PlanNode:
    li = P.TableScan("lineitem", columns=["l_orderkey", "l_suppkey",
                                          "l_commitdate", "l_receiptdate"])
    all_supp = P.Aggregation(
        P.Distinct(li, ["l_orderkey", "l_suppkey"],
                   max_groups=sz.groups("lineitem")),
        ["l_orderkey"], [("nsupp", "count", None)],
        max_groups=sz.groups("orders"))
    late = P.Filter(li, col("l_receiptdate") > col("l_commitdate"))
    late_supp = P.Aggregation(
        P.Distinct(late, ["l_orderkey", "l_suppkey"],
                   max_groups=sz.groups("lineitem")),
        ["l_orderkey"], [("nlate", "count", None)],
        max_groups=sz.groups("orders"))
    f_orders = P.Filter(P.TableScan("orders",
                                    columns=["o_orderkey", "o_orderstatus"]),
                        col("o_orderstatus") == lit(_dict_code(
                            S.ORDERS["o_orderstatus"], "F")))
    l1 = P.Join(probe=late, build=f_orders, probe_keys=["l_orderkey"],
                build_keys=["o_orderkey"], join_type="left_semi")
    sa_supp = P.Join(
        probe=P.TableScan("supplier", columns=["s_suppkey", "s_name",
                                               "s_nationkey"]),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("SAUDI ARABIA"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    l1_s = P.Join(probe=l1, build=sa_supp, probe_keys=["l_suppkey"],
                  build_keys=["s_suppkey"], build_payload=["s_name"])
    l1_c = P.Join(probe=l1_s, build=all_supp, probe_keys=["l_orderkey"],
                  build_keys=["l_orderkey"], build_payload=["nsupp"])
    l1_cc = P.Join(probe=l1_c, build=late_supp, probe_keys=["l_orderkey"],
                   build_keys=["l_orderkey"], build_payload=["nlate"])
    waiting = P.Filter(l1_cc, (col("nsupp") >= lit(2)) & (col("nlate") == lit(1)))
    return P.OrderBy(
        P.Aggregation(waiting, ["s_name"], [("numwait", "count", None)],
                      max_groups=sz.groups("supplier")),
        keys=["numwait", "s_name"], descending=[True, False], limit=100)


def q22(sz: Sizes) -> P.PlanNode:
    codes = [13, 31, 23, 29, 30, 18, 17]
    cust = P.Project(P.TableScan("customer",
                                 columns=["c_custkey", "c_phone", "c_acctbal"]),
                     [("c_custkey", col("c_custkey")),
                      ("cntrycode", prefix_code(col("c_phone"), 2)),
                      ("c_acctbal", col("c_acctbal"))])
    in_codes = P.Filter(cust, col("cntrycode").isin(codes))
    positive = P.Filter(in_codes, col("c_acctbal") > lit(0.0))
    avg_bal = P.Aggregation(positive, [], [("avg_bal", "avg", "c_acctbal")],
                            max_groups=1)
    rich = P.Filter(P.ScalarBroadcast(in_codes, avg_bal, ["avg_bal"]),
                    col("c_acctbal") > col("avg_bal"))
    no_orders = P.Join(probe=rich,
                       build=P.TableScan("orders", columns=["o_custkey"]),
                       probe_keys=["c_custkey"], build_keys=["o_custkey"],
                       join_type="left_anti")
    return P.OrderBy(
        P.Aggregation(no_orders, ["cntrycode"],
                      [("numcust", "count", None),
                       ("totacctbal", "sum", "c_acctbal")], max_groups=64),
        keys=["cntrycode"])


QUERIES: Dict[int, Callable] = {
    1: q1, 2: q2, 3: q3, 4: q4, 5: q5, 6: q6, 7: q7, 8: q8, 9: q9, 10: q10,
    11: q11, 12: q12, 13: q13, 14: q14, 15: q15, 16: q16, 17: q17, 18: q18,
    19: q19, 20: q20, 21: q21, 22: q22,
}


def build_query(qnum: int, catalog) -> P.PlanNode:
    return QUERIES[qnum](Sizes(catalog))
