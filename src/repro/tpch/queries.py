"""All 22 TPC-H queries (paper §3.4 runs all 22).

Queries describe *logical* plans only: no capacity hints, no distribution
choices. ``build_query`` runs every plan through the rule-based logical
optimizer (``repro.core.optimizer``), which pushes predicates into scans,
prunes unreferenced columns, picks join distributions, and derives the
static-shape capacity hints (``max_groups``/``max_matches``) from catalog
statistics -- the planner work the hand-threaded ``Sizes`` helper used to
approximate.

Q1, Q3, Q5, Q6, Q10 and Q14 are written in the fluent builder API
(``repro.core.builder``); the remaining queries are hand-assembled
``PlanNode`` trees (correlated/EXISTS subqueries rewritten into joins the
way Presto's planner does). Every query is validated against the
pure-numpy oracle in oracle.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from ..core import plan as P
from ..core.builder import table as _t
from ..core.expr import col, date_lit, lit, prefix_code, year
from ..core.optimizer import DEFAULT_CONFIG, optimize
from . import schema as S

_D = date_lit


def _dict_code(schema_col, value: str) -> int:
    return schema_col.dictionary.index(value)


def _nation(name: str) -> int:
    return S.NATIONS.index(name)


def _region(name: str) -> int:
    return S.REGIONS.index(name)


# ---------------------------------------------------------------------------

def q1(catalog) -> P.PlanNode:
    disc_price = col("l_extendedprice") * (lit(1.0) - col("l_discount"))
    charge = disc_price * (lit(1.0) + col("l_tax"))
    return (
        _t(catalog, "lineitem")
        .filter(col("l_shipdate") <= lit(_D("1998-12-01").value - 90))
        .project("l_returnflag", "l_linestatus", "l_quantity",
                 "l_extendedprice", "l_discount",
                 disc_price=disc_price, charge=charge)
        .group_by("l_returnflag", "l_linestatus")
        .agg(sum_qty=("sum", "l_quantity"),
             sum_base_price=("sum", "l_extendedprice"),
             sum_disc_price=("sum", "disc_price"),
             sum_charge=("sum", "charge"),
             avg_qty=("avg", "l_quantity"),
             avg_price=("avg", "l_extendedprice"),
             avg_disc=("avg", "l_discount"),
             count_order=("count", None))
        .order_by("l_returnflag", "l_linestatus")
        .to_plan())


def q2(catalog) -> P.PlanNode:
    eu_nation = P.Join(
        probe=P.TableScan("nation"),
        build=P.Filter(P.TableScan("region"),
                       col("r_name") == lit(_region("EUROPE"))),
        probe_keys=["n_regionkey"], build_keys=["r_regionkey"],
        join_type="left_semi")
    eu_supp = P.Join(
        probe=P.TableScan("supplier"),
        build=eu_nation,
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        build_payload=["n_name"])
    ps_eu = P.Join(
        probe=P.TableScan("partsupp"),
        build=eu_supp,
        probe_keys=["ps_suppkey"], build_keys=["s_suppkey"],
        build_payload=["s_acctbal", "s_name", "s_address", "s_phone",
                       "s_comment", "n_name"])
    parts_f = P.Filter(
        P.TableScan("part"),
        (col("p_size") == lit(15)) & _type_endswith_brass())
    joined = P.Join(probe=ps_eu, build=parts_f,
                    probe_keys=["ps_partkey"], build_keys=["p_partkey"],
                    build_payload=["p_mfgr"])
    min_cost = P.Aggregation(joined, ["ps_partkey"],
                             [("min_cost", "min", "ps_supplycost")])
    final = P.Filter(
        P.Join(probe=joined, build=min_cost,
               probe_keys=["ps_partkey"], build_keys=["ps_partkey"],
               build_payload=["min_cost"]),
        col("ps_supplycost") == col("min_cost"))
    return P.OrderBy(
        P.Project(final, [("s_acctbal", col("s_acctbal")),
                          ("s_name", col("s_name")),
                          ("n_name", col("n_name")),
                          ("p_partkey", col("ps_partkey")),
                          ("p_mfgr", col("p_mfgr")),
                          ("s_address", col("s_address")),
                          ("s_phone", col("s_phone")),
                          ("s_comment", col("s_comment"))]),
        keys=["s_acctbal", "n_name", "s_name", "p_partkey"],
        descending=[True, False, False, False], limit=100)


def _type_endswith_brass():
    # p_type is dictionary encoded; LIKE '%BRASS' = membership in the codes
    # whose decoded string ends with BRASS (planner constant-folds this)
    codes = [i for i, t in enumerate(S.TYPES) if t.endswith("BRASS")]
    return col("p_type").isin(codes)


def q3(catalog) -> P.PlanNode:
    cust = (_t(catalog, "customer")
            .filter(col("c_mktsegment") == lit(_dict_code(
                S.CUSTOMER["c_mktsegment"], "BUILDING"))))
    orders = (_t(catalog, "orders")
              .filter(col("o_orderdate") < _D("1995-03-15"))
              .semi_join(cust, ["o_custkey"], ["c_custkey"]))
    return (
        _t(catalog, "lineitem")
        .filter(col("l_shipdate") > _D("1995-03-15"))
        .join(orders, ["l_orderkey"], ["o_orderkey"],
              payload=["o_orderdate", "o_shippriority"])
        .project("l_orderkey", "o_orderdate", "o_shippriority",
                 rev=col("l_extendedprice") * (lit(1.0) - col("l_discount")))
        .group_by("l_orderkey")
        .agg(revenue=("sum", "rev"),
             o_orderdate=("first", "o_orderdate"),
             o_shippriority=("first", "o_shippriority"))
        .order_by("revenue", "o_orderdate", descending=[True, False], limit=10)
        .to_plan())


def q4(catalog) -> P.PlanNode:
    late = P.Filter(P.TableScan("lineitem"),
                    col("l_commitdate") < col("l_receiptdate"))
    orders = P.Filter(P.TableScan("orders"),
                      col("o_orderdate").between(_D("1993-07-01"),
                                                 lit(_D("1993-10-01").value - 1)))
    semi = P.Join(probe=orders, build=late, probe_keys=["o_orderkey"],
                  build_keys=["l_orderkey"], join_type="left_semi")
    return P.OrderBy(
        P.Aggregation(semi, ["o_orderpriority"],
                      [("order_count", "count", None)]),
        keys=["o_orderpriority"])


def q5(catalog) -> P.PlanNode:
    asia_nation = (_t(catalog, "nation")
                   .semi_join(_t(catalog, "region")
                              .filter(col("r_name") == lit(_region("ASIA"))),
                              ["n_regionkey"], ["r_regionkey"]))
    supp = (_t(catalog, "supplier")
            .join(asia_nation, ["s_nationkey"], ["n_nationkey"],
                  payload=["n_name"]))
    orders = (_t(catalog, "orders")
              .filter(col("o_orderdate").between(
                  _D("1994-01-01"), lit(_D("1995-01-01").value - 1)))
              .join(_t(catalog, "customer"), ["o_custkey"], ["c_custkey"],
                    payload=["c_nationkey"]))
    return (
        _t(catalog, "lineitem")
        .join(orders, ["l_orderkey"], ["o_orderkey"], payload=["c_nationkey"])
        .join(supp, ["l_suppkey"], ["s_suppkey"],
              payload=["s_nationkey", "n_name"])
        .filter(col("c_nationkey") == col("s_nationkey"))
        .project("n_name",
                 rev=col("l_extendedprice") * (lit(1.0) - col("l_discount")))
        .group_by("n_name")
        .agg(revenue=("sum", "rev"))
        .order_by("revenue", descending=[True])
        .to_plan())


def q6(catalog) -> P.PlanNode:
    return (
        _t(catalog, "lineitem")
        .filter(col("l_shipdate").between(_D("1994-01-01"),
                                          lit(_D("1995-01-01").value - 1))
                & col("l_discount").between(0.05, 0.07)
                & (col("l_quantity") < 24.0))
        .project(v=col("l_extendedprice") * col("l_discount"))
        .agg(revenue=("sum", "v"))
        .to_plan())


def _q7_nations():
    return _nation("FRANCE"), _nation("GERMANY")


def q7(catalog) -> P.PlanNode:
    fr, de = _q7_nations()
    npair = P.Filter(P.TableScan("nation"),
                     col("n_nationkey").isin([fr, de]))
    supp = P.Join(probe=P.TableScan("supplier"),
                  build=npair, probe_keys=["s_nationkey"],
                  build_keys=["n_nationkey"], build_payload=["n_name"])
    cust = P.Join(probe=P.TableScan("customer"),
                  build=npair, probe_keys=["c_nationkey"],
                  build_keys=["n_nationkey"], build_payload=["n_name"])
    cust = P.Project(cust, [("c_custkey", col("c_custkey")),
                            ("cust_nation", col("n_name"))])
    orders = P.Join(probe=P.TableScan("orders"),
                    build=cust, probe_keys=["o_custkey"],
                    build_keys=["c_custkey"], build_payload=["cust_nation"])
    li = P.Filter(P.TableScan("lineitem"),
                  col("l_shipdate").between(_D("1995-01-01"), _D("1996-12-31")))
    li_s = P.Join(probe=li, build=supp, probe_keys=["l_suppkey"],
                  build_keys=["s_suppkey"], build_payload=["n_name"])
    li_s = P.Project(li_s, [("l_orderkey", col("l_orderkey")),
                            ("supp_nation", col("n_name")),
                            ("l_shipdate", col("l_shipdate")),
                            ("l_extendedprice", col("l_extendedprice")),
                            ("l_discount", col("l_discount"))])
    both = P.Join(probe=li_s, build=orders, probe_keys=["l_orderkey"],
                  build_keys=["o_orderkey"], build_payload=["cust_nation"])
    matched = P.Filter(
        both,
        ((col("supp_nation") == lit(fr)) & (col("cust_nation") == lit(de)))
        | ((col("supp_nation") == lit(de)) & (col("cust_nation") == lit(fr))))
    return P.OrderBy(
        P.Aggregation(
            P.Project(matched, [("supp_nation", col("supp_nation")),
                                ("cust_nation", col("cust_nation")),
                                ("l_year", year(col("l_shipdate"))),
                                ("volume", col("l_extendedprice")
                                 * (lit(1.0) - col("l_discount")))]),
            group_keys=["supp_nation", "cust_nation", "l_year"],
            aggs=[("revenue", "sum", "volume")]),
        keys=["supp_nation", "cust_nation", "l_year"])


def q8(catalog) -> P.PlanNode:
    target_type = _dict_code(S.PART["p_type"], "ECONOMY ANODIZED STEEL")
    brazil = _nation("BRAZIL")
    part_f = P.Filter(P.TableScan("part"), col("p_type") == lit(target_type))
    am_cust = P.Join(
        probe=P.TableScan("customer"),
        build=P.Join(probe=P.TableScan("nation"),
                     build=P.Filter(P.TableScan("region"),
                                    col("r_name") == lit(_region("AMERICA"))),
                     probe_keys=["n_regionkey"], build_keys=["r_regionkey"],
                     join_type="left_semi"),
        probe_keys=["c_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    orders = P.Join(
        probe=P.Filter(P.TableScan("orders"),
                       col("o_orderdate").between(_D("1995-01-01"),
                                                  _D("1996-12-31"))),
        build=am_cust, probe_keys=["o_custkey"], build_keys=["c_custkey"],
        join_type="left_semi")
    li = P.Join(
        probe=P.TableScan("lineitem"),
        build=part_f, probe_keys=["l_partkey"], build_keys=["p_partkey"],
        join_type="left_semi")
    li_o = P.Join(probe=li, build=orders, probe_keys=["l_orderkey"],
                  build_keys=["o_orderkey"], build_payload=["o_orderdate"])
    li_os = P.Join(probe=li_o,
                   build=P.TableScan("supplier"),
                   probe_keys=["l_suppkey"], build_keys=["s_suppkey"],
                   build_payload=["s_nationkey"])
    vols = P.Project(li_os, [
        ("o_year", year(col("o_orderdate"))),
        ("volume", col("l_extendedprice") * (lit(1.0) - col("l_discount"))),
        ("is_brazil", (col("s_nationkey") == lit(brazil)))])
    vols = P.Project(vols, [
        ("o_year", col("o_year")),
        ("volume", col("volume")),
        ("brazil_volume", col("volume") * col("is_brazil"))])
    agg = P.Aggregation(vols, ["o_year"],
                        [("nat", "sum", "brazil_volume"),
                         ("total", "sum", "volume")])
    return P.OrderBy(
        P.Project(agg, [("o_year", col("o_year")),
                        ("mkt_share", col("nat") / col("total"))]),
        keys=["o_year"])


def q9(catalog) -> P.PlanNode:
    part_f = P.Filter(P.TableScan("part"), col("p_name").contains("green"))
    li = P.Join(probe=P.TableScan("lineitem"),
                build=part_f, probe_keys=["l_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    li_s = P.Join(probe=li,
                  build=P.TableScan("supplier"),
                  probe_keys=["l_suppkey"], build_keys=["s_suppkey"],
                  build_payload=["s_nationkey"])
    # hashed composite key: collision headroom even without catalog key
    # stats (the optimizer re-derives this when stats are declared)
    li_ps = P.Join(probe=li_s,
                   build=P.TableScan("partsupp"),
                   probe_keys=["l_partkey", "l_suppkey"],
                   build_keys=["ps_partkey", "ps_suppkey"],
                   build_payload=["ps_supplycost"],
                   max_matches=4)
    li_o = P.Join(probe=li_ps,
                  build=P.TableScan("orders"),
                  probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
                  build_payload=["o_orderdate"])
    li_n = P.Join(probe=li_o, build=P.TableScan("nation"),
                  probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
                  build_payload=["n_name"])
    amount = (col("l_extendedprice") * (lit(1.0) - col("l_discount"))
              - col("ps_supplycost") * col("l_quantity"))
    return P.OrderBy(
        P.Aggregation(
            P.Project(li_n, [("nation", col("n_name")),
                             ("o_year", year(col("o_orderdate"))),
                             ("amount", amount)]),
            group_keys=["nation", "o_year"],
            aggs=[("sum_profit", "sum", "amount")]),
        keys=["nation", "o_year"], descending=[False, True])


def q10(catalog) -> P.PlanNode:
    orders = (_t(catalog, "orders")
              .filter(col("o_orderdate").between(
                  _D("1993-10-01"), lit(_D("1994-01-01").value - 1))))
    rev = (_t(catalog, "lineitem")
           .filter(col("l_returnflag") == lit(_dict_code(
               S.LINEITEM["l_returnflag"], "R")))
           .join(orders, ["l_orderkey"], ["o_orderkey"],
                 payload=["o_custkey"])
           .project("o_custkey",
                    rev=col("l_extendedprice") * (lit(1.0) - col("l_discount")))
           .group_by("o_custkey")
           .agg(revenue=("sum", "rev")))
    return (
        _t(catalog, "customer")
        .join(rev, ["c_custkey"], ["o_custkey"], payload=["revenue"])
        .join(_t(catalog, "nation"), ["c_nationkey"], ["n_nationkey"],
              payload=["n_name"])
        .project("c_custkey", "c_name", "revenue", "c_acctbal", "n_name",
                 "c_address", "c_phone", "c_comment")
        .order_by("revenue", descending=[True], limit=20)
        .to_plan())


def q11(catalog, fraction: float = None) -> P.PlanNode:
    if fraction is None:
        n_supp = catalog.get("supplier").num_rows()
        fraction = 0.0001 / max(n_supp / 10000.0, 1e-9)
    de_supp = P.Join(
        probe=P.TableScan("supplier"),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("GERMANY"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    ps = P.Join(probe=P.TableScan("partsupp"), build=de_supp,
                probe_keys=["ps_suppkey"], build_keys=["s_suppkey"],
                join_type="left_semi")
    ps = P.Project(ps, [("ps_partkey", col("ps_partkey")),
                        ("value", col("ps_supplycost") * col("ps_availqty"))])
    per_part = P.Aggregation(ps, ["ps_partkey"], [("value", "sum", "value")])
    total = P.Aggregation(P.Project(per_part, [("tval", col("value"))]),
                          [], [("total", "sum", "tval")])
    filtered = P.Filter(
        P.ScalarBroadcast(per_part, total, ["total"]),
        col("value") > col("total") * lit(float(fraction)))
    return P.OrderBy(P.Project(filtered, [("ps_partkey", col("ps_partkey")),
                                          ("value", col("value"))]),
                     keys=["value"], descending=[True])


def q12(catalog) -> P.PlanNode:
    mail = _dict_code(S.LINEITEM["l_shipmode"], "MAIL")
    ship = _dict_code(S.LINEITEM["l_shipmode"], "SHIP")
    urgent = _dict_code(S.ORDERS["o_orderpriority"], "1-URGENT")
    high = _dict_code(S.ORDERS["o_orderpriority"], "2-HIGH")
    li = P.Filter(
        P.TableScan("lineitem"),
        col("l_shipmode").isin([mail, ship])
        & (col("l_commitdate") < col("l_receiptdate"))
        & (col("l_shipdate") < col("l_commitdate"))
        & col("l_receiptdate").between(_D("1994-01-01"),
                                       lit(_D("1995-01-01").value - 1)))
    li_o = P.Join(probe=li,
                  build=P.TableScan("orders"),
                  probe_keys=["l_orderkey"], build_keys=["o_orderkey"],
                  build_payload=["o_orderpriority"])
    flagged = P.Project(li_o, [
        ("l_shipmode", col("l_shipmode")),
        ("is_high", (col("o_orderpriority") == lit(urgent))
         | (col("o_orderpriority") == lit(high)))])
    flagged = P.Project(flagged, [
        ("l_shipmode", col("l_shipmode")),
        ("high", col("is_high") * lit(1)),
        ("low", (~col("is_high")) * lit(1))])
    return P.OrderBy(
        P.Aggregation(flagged, ["l_shipmode"],
                      [("high_line_count", "sum", "high"),
                       ("low_line_count", "sum", "low")]),
        keys=["l_shipmode"])


def q13(catalog) -> P.PlanNode:
    orders = P.Filter(P.TableScan("orders"),
                      ~col("o_comment").contains("special", "requests"))
    per_cust = P.Aggregation(orders, ["o_custkey"],
                             [("c_count", "count", None)])
    cust = P.Join(probe=P.TableScan("customer"), build=per_cust,
                  probe_keys=["c_custkey"],
                  build_keys=["o_custkey"], build_payload=["c_count"],
                  join_type="left_outer")
    cust = P.Project(cust, [("c_count", col("c_count") * col("__matched"))])
    return P.OrderBy(
        P.Aggregation(cust, ["c_count"], [("custdist", "count", None)]),
        keys=["custdist", "c_count"], descending=[True, True])


def q14(catalog) -> P.PlanNode:
    promo_codes = [i for i, t in enumerate(S.TYPES) if t.startswith("PROMO")]
    rev = col("l_extendedprice") * (lit(1.0) - col("l_discount"))
    return (
        _t(catalog, "lineitem")
        .filter(col("l_shipdate").between(_D("1995-09-01"),
                                          lit(_D("1995-10-01").value - 1)))
        .join(_t(catalog, "part"), ["l_partkey"], ["p_partkey"],
              payload=["p_type"])
        .project(rev=rev, is_promo=col("p_type").isin(promo_codes))
        .project("rev", promo_rev=col("rev") * col("is_promo"))
        .agg(promo=("sum", "promo_rev"), total=("sum", "rev"))
        .project(promo_revenue=lit(100.0) * col("promo") / col("total"))
        .to_plan())


def q15(catalog) -> P.PlanNode:
    li = P.Filter(P.TableScan("lineitem"),
                  col("l_shipdate").between(_D("1996-01-01"),
                                            lit(_D("1996-04-01").value - 1)))
    rev = P.Aggregation(
        P.Project(li, [("l_suppkey", col("l_suppkey")),
                       ("rev", col("l_extendedprice")
                        * (lit(1.0) - col("l_discount")))]),
        group_keys=["l_suppkey"], aggs=[("total_revenue", "sum", "rev")])
    maxrev = P.Aggregation(P.Project(rev, [("r", col("total_revenue"))]),
                           [], [("max_rev", "max", "r")])
    best = P.Filter(P.ScalarBroadcast(rev, maxrev, ["max_rev"]),
                    col("total_revenue") == col("max_rev"))
    supp = P.Join(probe=P.TableScan("supplier"),
                  build=best, probe_keys=["s_suppkey"],
                  build_keys=["l_suppkey"], build_payload=["total_revenue"])
    return P.OrderBy(
        P.Project(supp, [("s_suppkey", col("s_suppkey")),
                         ("s_name", col("s_name")),
                         ("s_address", col("s_address")),
                         ("s_phone", col("s_phone")),
                         ("total_revenue", col("total_revenue"))]),
        keys=["s_suppkey"])


def q16(catalog) -> P.PlanNode:
    brand45 = _dict_code(S.PART["p_brand"], "Brand#45")
    med_pol = [i for i, t in enumerate(S.TYPES)
               if t.startswith("MEDIUM POLISHED")]
    sizes = [49, 14, 23, 45, 19, 3, 36, 9]
    part_f = P.Filter(
        P.TableScan("part"),
        (col("p_brand") != lit(brand45))
        & (~col("p_type").isin(med_pol))
        & col("p_size").isin(sizes))
    ps = P.Join(probe=P.TableScan("partsupp"),
                build=part_f, probe_keys=["ps_partkey"],
                build_keys=["p_partkey"],
                build_payload=["p_brand", "p_type", "p_size"])
    bad_supp = P.Filter(P.TableScan("supplier"),
                        col("s_comment").contains("Customer", "Complaints"))
    ps = P.Join(probe=ps, build=bad_supp, probe_keys=["ps_suppkey"],
                build_keys=["s_suppkey"], join_type="left_anti")
    dedup = P.Distinct(ps, ["p_brand", "p_type", "p_size", "ps_suppkey"])
    return P.OrderBy(
        P.Aggregation(dedup, ["p_brand", "p_type", "p_size"],
                      [("supplier_cnt", "count", None)]),
        keys=["supplier_cnt", "p_brand", "p_type", "p_size"],
        descending=[True, False, False, False])


def q17(catalog) -> P.PlanNode:
    brand = _dict_code(S.PART["p_brand"], "Brand#23")
    box = _dict_code(S.PART["p_container"], "MED BOX")
    part_f = P.Filter(P.TableScan("part"),
                      (col("p_brand") == lit(brand))
                      & (col("p_container") == lit(box)))
    li = P.Join(probe=P.TableScan("lineitem"),
                build=part_f, probe_keys=["l_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    avg_q = P.Aggregation(li, ["l_partkey"], [("avg_qty", "avg", "l_quantity")])
    joined = P.Join(probe=li, build=avg_q, probe_keys=["l_partkey"],
                    build_keys=["l_partkey"], build_payload=["avg_qty"])
    small = P.Filter(joined, col("l_quantity") < lit(0.2) * col("avg_qty"))
    agg = P.Aggregation(small, [], [("s", "sum", "l_extendedprice")])
    return P.Project(agg, [("avg_yearly", col("s") / lit(7.0))])


def q18(catalog) -> P.PlanNode:
    per_order = P.Aggregation(
        P.TableScan("lineitem"),
        ["l_orderkey"], [("sum_qty", "sum", "l_quantity")])
    big = P.Filter(per_order, col("sum_qty") > lit(300.0))
    orders = P.Join(probe=P.TableScan("orders"),
                    build=big, probe_keys=["o_orderkey"],
                    build_keys=["l_orderkey"], build_payload=["sum_qty"])
    cust = P.Join(probe=orders,
                  build=P.TableScan("customer"),
                  probe_keys=["o_custkey"], build_keys=["c_custkey"],
                  build_payload=["c_name"])
    return P.OrderBy(
        P.Project(cust, [("o_orderkey", col("o_orderkey")),
                         ("o_custkey", col("o_custkey")),
                         ("o_orderdate", col("o_orderdate")),
                         ("o_totalprice", col("o_totalprice")),
                         ("sum_qty", col("sum_qty")),
                         ("c_name", col("c_name"))]),
        keys=["o_totalprice", "o_orderdate"],
        descending=[True, False], limit=100)


def q19(catalog) -> P.PlanNode:
    sm = S.LINEITEM["l_shipmode"]
    air, reg_air = _dict_code(sm, "AIR"), _dict_code(sm, "REG AIR")
    deliver = _dict_code(S.LINEITEM["l_shipinstruct"], "DELIVER IN PERSON")
    b12 = _dict_code(S.PART["p_brand"], "Brand#12")
    b23 = _dict_code(S.PART["p_brand"], "Brand#23")
    b34 = _dict_code(S.PART["p_brand"], "Brand#34")
    cont = S.PART["p_container"]
    sm_containers = [_dict_code(cont, c) for c in
                     ("SM CASE", "SM BOX", "SM PACK", "SM PKG")]
    med_containers = [_dict_code(cont, c) for c in
                      ("MED BAG", "MED BOX", "MED PKG", "MED PACK")]
    lg_containers = [_dict_code(cont, c) for c in
                     ("LG CASE", "LG BOX", "LG PACK", "LG PKG")]
    li = P.Filter(P.TableScan("lineitem"),
                  col("l_shipmode").isin([air, reg_air])
                  & (col("l_shipinstruct") == lit(deliver)))
    li_p = P.Join(probe=li,
                  build=P.TableScan("part"),
                  probe_keys=["l_partkey"], build_keys=["p_partkey"],
                  build_payload=["p_brand", "p_size", "p_container"])
    bracket1 = ((col("p_brand") == lit(b12))
                & col("p_container").isin(sm_containers)
                & col("l_quantity").between(1.0, 11.0)
                & col("p_size").between(1, 5))
    bracket2 = ((col("p_brand") == lit(b23))
                & col("p_container").isin(med_containers)
                & col("l_quantity").between(10.0, 20.0)
                & col("p_size").between(1, 10))
    bracket3 = ((col("p_brand") == lit(b34))
                & col("p_container").isin(lg_containers)
                & col("l_quantity").between(20.0, 30.0)
                & col("p_size").between(1, 15))
    matched = P.Filter(li_p, bracket1 | bracket2 | bracket3)
    return P.Aggregation(
        P.Project(matched, [("rev", col("l_extendedprice")
                             * (lit(1.0) - col("l_discount")))]),
        group_keys=[], aggs=[("revenue", "sum", "rev")])


def q20(catalog) -> P.PlanNode:
    forest = P.Filter(P.TableScan("part"), col("p_name").startswith("forest"))
    qty94 = P.Aggregation(
        P.Filter(P.TableScan("lineitem"),
                 col("l_shipdate").between(_D("1994-01-01"),
                                           lit(_D("1995-01-01").value - 1))),
        ["l_partkey", "l_suppkey"], [("qty", "sum", "l_quantity")])
    ps = P.Join(probe=P.TableScan("partsupp"),
                build=forest, probe_keys=["ps_partkey"],
                build_keys=["p_partkey"], join_type="left_semi")
    # hashed composite key: collision headroom even without catalog key stats
    ps_q = P.Join(probe=ps, build=qty94,
                  probe_keys=["ps_partkey", "ps_suppkey"],
                  build_keys=["l_partkey", "l_suppkey"],
                  build_payload=["qty"],
                  max_matches=4)
    excess = P.Filter(ps_q, col("ps_availqty") > lit(0.5) * col("qty"))
    supp_keys = P.Distinct(excess, ["ps_suppkey"])
    ca_supp = P.Join(
        probe=P.Join(probe=P.TableScan("supplier"),
                     build=supp_keys, probe_keys=["s_suppkey"],
                     build_keys=["ps_suppkey"], join_type="left_semi"),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("CANADA"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    return P.OrderBy(P.Project(ca_supp, [("s_name", col("s_name")),
                                         ("s_address", col("s_address"))]),
                     keys=["s_name"])


def q21(catalog) -> P.PlanNode:
    li = P.TableScan("lineitem", columns=["l_orderkey", "l_suppkey",
                                          "l_commitdate", "l_receiptdate"])
    all_supp = P.Aggregation(
        P.Distinct(li, ["l_orderkey", "l_suppkey"]),
        ["l_orderkey"], [("nsupp", "count", None)])
    late = P.Filter(li, col("l_receiptdate") > col("l_commitdate"))
    late_supp = P.Aggregation(
        P.Distinct(late, ["l_orderkey", "l_suppkey"]),
        ["l_orderkey"], [("nlate", "count", None)])
    f_orders = P.Filter(P.TableScan("orders"),
                        col("o_orderstatus") == lit(_dict_code(
                            S.ORDERS["o_orderstatus"], "F")))
    l1 = P.Join(probe=late, build=f_orders, probe_keys=["l_orderkey"],
                build_keys=["o_orderkey"], join_type="left_semi")
    sa_supp = P.Join(
        probe=P.TableScan("supplier"),
        build=P.Filter(P.TableScan("nation"),
                       col("n_name") == lit(_nation("SAUDI ARABIA"))),
        probe_keys=["s_nationkey"], build_keys=["n_nationkey"],
        join_type="left_semi")
    l1_s = P.Join(probe=l1, build=sa_supp, probe_keys=["l_suppkey"],
                  build_keys=["s_suppkey"], build_payload=["s_name"])
    l1_c = P.Join(probe=l1_s, build=all_supp, probe_keys=["l_orderkey"],
                  build_keys=["l_orderkey"], build_payload=["nsupp"])
    l1_cc = P.Join(probe=l1_c, build=late_supp, probe_keys=["l_orderkey"],
                   build_keys=["l_orderkey"], build_payload=["nlate"])
    waiting = P.Filter(l1_cc, (col("nsupp") >= lit(2)) & (col("nlate") == lit(1)))
    return P.OrderBy(
        P.Aggregation(waiting, ["s_name"], [("numwait", "count", None)]),
        keys=["numwait", "s_name"], descending=[True, False], limit=100)


def q22(catalog) -> P.PlanNode:
    codes = [13, 31, 23, 29, 30, 18, 17]
    cust = P.Project(P.TableScan("customer"),
                     [("c_custkey", col("c_custkey")),
                      ("cntrycode", prefix_code(col("c_phone"), 2)),
                      ("c_acctbal", col("c_acctbal"))])
    in_codes = P.Filter(cust, col("cntrycode").isin(codes))
    positive = P.Filter(in_codes, col("c_acctbal") > lit(0.0))
    avg_bal = P.Aggregation(positive, [], [("avg_bal", "avg", "c_acctbal")])
    rich = P.Filter(P.ScalarBroadcast(in_codes, avg_bal, ["avg_bal"]),
                    col("c_acctbal") > col("avg_bal"))
    no_orders = P.Join(probe=rich,
                       build=P.TableScan("orders"),
                       probe_keys=["c_custkey"], build_keys=["o_custkey"],
                       join_type="left_anti")
    return P.OrderBy(
        P.Aggregation(no_orders, ["cntrycode"],
                      [("numcust", "count", None),
                       ("totacctbal", "sum", "c_acctbal")]),
        keys=["cntrycode"])


QUERIES: Dict[int, Callable] = {
    1: q1, 2: q2, 3: q3, 4: q4, 5: q5, 6: q6, 7: q7, 8: q8, 9: q9, 10: q10,
    11: q11, 12: q12, 13: q13, 14: q14, 15: q15, 16: q16, 17: q17, 18: q18,
    19: q19, 20: q20, 21: q21, 22: q22,
}


def build_query(qnum: int, catalog, optimized: bool = True,
                num_workers: int = 1) -> P.PlanNode:
    """Logical plan for query ``qnum``, run through the optimizer pipeline
    (pass ``optimized=False`` for the raw tree).

    With ``num_workers > 1`` the optimizer also places physical exchanges:
    the returned tree is a distributed fragment plan whose
    ``Repartition``/``Broadcast`` nodes target that worker count (execute it
    on a session with the same ``num_workers``).
    """
    plan = QUERIES[qnum](catalog)
    if not optimized:
        return plan
    cfg = dataclasses.replace(DEFAULT_CONFIG, num_workers=num_workers)
    return optimize(plan, catalog, config=cfg)
