"""TPC-H table schemas, adapted to the engine's dtypes.

Strings: low-cardinality columns are dictionary-encoded (sorted dictionaries
so code order == lexicographic order); pattern-matched columns (names,
comments) are fixed-width byte matrices; dates are date32.
"""

from __future__ import annotations

from ..core import dtypes as dt

# -- sorted dictionaries (order matters: ORDER BY on codes) -----------------

REGIONS = ("AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST")

NATIONS = (
    "ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "CHINA", "EGYPT", "ETHIOPIA",
    "FRANCE", "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ", "JAPAN",
    "JORDAN", "KENYA", "MOROCCO", "MOZAMBIQUE", "PERU", "ROMANIA",
    "RUSSIA", "SAUDI ARABIA", "UNITED KINGDOM", "UNITED STATES", "VIETNAM",
)
# nation -> region mapping (per TPC-H spec)
NATION_REGION = (0, 1, 1, 1, 2, 0, 0, 3, 3, 2, 2, 4, 4, 2, 4, 0, 0, 0, 1, 3,
                 3, 4, 3, 1, 2)

SEGMENTS = ("AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY")
PRIORITIES = ("1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW")
SHIPMODES = ("AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK")
SHIPINSTRUCT = ("COLLECT COD", "DELIVER IN PERSON", "NONE",
                "TAKE BACK RETURN")
RETURNFLAGS = ("A", "N", "R")
LINESTATUS = ("F", "O")
ORDERSTATUS = ("F", "O", "P")
MFGRS = tuple(f"Manufacturer#{i}" for i in range(1, 6))
BRANDS = tuple(sorted(f"Brand#{m}{b}" for m in range(1, 6) for b in range(1, 6)))

_TYPE_1 = ("ECONOMY", "LARGE", "MEDIUM", "PROMO", "SMALL", "STANDARD")
_TYPE_2 = ("ANODIZED", "BRUSHED", "BURNISHED", "PLATED", "POLISHED")
_TYPE_3 = ("BRASS", "COPPER", "NICKEL", "STEEL", "TIN")
TYPES = tuple(sorted(f"{a} {b} {c}" for a in _TYPE_1 for b in _TYPE_2
                     for c in _TYPE_3))

_CONT_1 = ("JUMBO", "LG", "MED", "SM", "WRAP")
_CONT_2 = ("BAG", "BOX", "CAN", "CASE", "DRUM", "JAR", "PACK", "PKG")
CONTAINERS = tuple(sorted(f"{a} {b}" for a in _CONT_1 for b in _CONT_2))

COLORS = ("almond", "antique", "aquamarine", "azure", "beige", "bisque",
          "black", "blanched", "blue", "blush", "brown", "burlywood",
          "chartreuse", "chiffon", "chocolate", "coral", "cornflower",
          "cornsilk", "cream", "cyan", "dark", "deep", "dim", "dodger",
          "drab", "firebrick", "floral", "forest", "frosted", "gainsboro",
          "ghost", "goldenrod", "green", "grey", "honeydew", "hot", "indian",
          "ivory", "khaki", "lace", "lavender", "lawn", "lemon", "light",
          "lime", "linen", "magenta", "maroon", "medium", "metallic")

# -- schemas -----------------------------------------------------------------

REGION = {
    "r_regionkey": dt.INT32,
    "r_name": dt.dict32(REGIONS),
}

NATION = {
    "n_nationkey": dt.INT32,
    "n_name": dt.dict32(NATIONS),
    "n_regionkey": dt.INT32,
}

SUPPLIER = {
    "s_suppkey": dt.INT32,
    "s_name": dt.bytes_(18),
    "s_address": dt.bytes_(16),
    "s_nationkey": dt.INT32,
    "s_phone": dt.bytes_(15),
    "s_acctbal": dt.FLOAT32,
    "s_comment": dt.bytes_(44),
}

CUSTOMER = {
    "c_custkey": dt.INT32,
    "c_name": dt.bytes_(18),
    "c_address": dt.bytes_(16),
    "c_nationkey": dt.INT32,
    "c_phone": dt.bytes_(15),
    "c_acctbal": dt.FLOAT32,
    "c_mktsegment": dt.dict32(SEGMENTS),
    "c_comment": dt.bytes_(24),
}

PART = {
    "p_partkey": dt.INT32,
    "p_name": dt.bytes_(36),
    "p_mfgr": dt.dict32(MFGRS),
    "p_brand": dt.dict32(BRANDS),
    "p_type": dt.dict32(TYPES),
    "p_size": dt.INT32,
    "p_container": dt.dict32(CONTAINERS),
    "p_retailprice": dt.FLOAT32,
}

PARTSUPP = {
    "ps_partkey": dt.INT32,
    "ps_suppkey": dt.INT32,
    "ps_availqty": dt.INT32,
    "ps_supplycost": dt.FLOAT32,
}

ORDERS = {
    "o_orderkey": dt.INT32,
    "o_custkey": dt.INT32,
    "o_orderstatus": dt.dict32(ORDERSTATUS),
    "o_totalprice": dt.FLOAT32,
    "o_orderdate": dt.DATE32,
    "o_orderpriority": dt.dict32(PRIORITIES),
    "o_shippriority": dt.INT32,
    "o_comment": dt.bytes_(44),
}

LINEITEM = {
    "l_orderkey": dt.INT32,
    "l_partkey": dt.INT32,
    "l_suppkey": dt.INT32,
    "l_linenumber": dt.INT32,
    "l_quantity": dt.FLOAT32,
    "l_extendedprice": dt.FLOAT32,
    "l_discount": dt.FLOAT32,
    "l_tax": dt.FLOAT32,
    "l_returnflag": dt.dict32(RETURNFLAGS),
    "l_linestatus": dt.dict32(LINESTATUS),
    "l_shipdate": dt.DATE32,
    "l_commitdate": dt.DATE32,
    "l_receiptdate": dt.DATE32,
    "l_shipmode": dt.dict32(SHIPMODES),
    "l_shipinstruct": dt.dict32(SHIPINSTRUCT),
}

SCHEMAS = {
    "region": REGION,
    "nation": NATION,
    "supplier": SUPPLIER,
    "customer": CUSTOMER,
    "part": PART,
    "partsupp": PARTSUPP,
    "orders": ORDERS,
    "lineitem": LINEITEM,
}

# primary keys (catalog stats for the optimizer's capacity derivation:
# joins against these columns provably match at most one build row)
PRIMARY_KEYS = {
    "region": ("r_regionkey",),
    "nation": ("n_nationkey",),
    "supplier": ("s_suppkey",),
    "customer": ("c_custkey",),
    "part": ("p_partkey",),
    "partsupp": ("ps_partkey", "ps_suppkey"),
    "orders": ("o_orderkey",),
    "lineitem": ("l_orderkey", "l_linenumber"),
}

# base cardinalities at SF=1
BASE_ROWS = {
    "region": 5,
    "nation": 25,
    "supplier": 10_000,
    "customer": 150_000,
    "part": 200_000,
    "partsupp": 800_000,
    "orders": 1_500_000,
    # lineitem: ~4 lines per order on average
}
