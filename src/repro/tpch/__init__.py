"""TPC-H-like benchmark substrate (paper §2-3).

dbgen-style synthetic data generation, the 8 table schemas, all 22 query
plans in the engine's plan DSL, and a pure-numpy oracle for validation.
As in the paper, queries are "functionally identical to TPC-H" but results
are not audited TPC-H results.
"""

from .dbgen import generate, load_catalog, write_dataset  # noqa: F401
from .queries import QUERIES, build_query  # noqa: F401
