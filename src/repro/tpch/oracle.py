"""Pure-numpy oracle for the 22 TPC-H queries.

Independent implementation (straight from the SQL semantics, not from the
engine's plans) used to validate every engine execution. Operates on the
dict-of-arrays output of dbgen.generate().
"""

from __future__ import annotations


import numpy as np

from ..core import dtypes as dt
from . import schema as S

_D = dt.date_to_i32


def _year(days: np.ndarray) -> np.ndarray:
    d = (np.datetime64("1970-01-01") + days.astype("timedelta64[D]"))
    return d.astype("datetime64[Y]").astype(np.int64) + 1970


def _contains(data: np.ndarray, *parts: str) -> np.ndarray:
    out = np.zeros(len(data), dtype=bool)
    bparts = [p.encode() for p in parts]
    for i in range(len(data)):
        s = data[i].tobytes()
        pos = 0
        ok = True
        for p in bparts:
            j = s.find(p, pos)
            if j < 0:
                ok = False
                break
            pos = j + len(p)
        out[i] = ok
    return out


def _startswith(data: np.ndarray, prefix: str) -> np.ndarray:
    p = np.frombuffer(prefix.encode(), dtype=np.uint8)
    return (data[:, : len(p)] == p).all(axis=1)


def _groupby(keys, aggs):
    """keys: list of 1-D arrays; aggs: list of (name, kind, values).
    Returns (key_arrays, {name: agg_array}) group-sorted."""
    stacked = np.stack([np.asarray(k) for k in keys], axis=1)
    uniq, inverse = np.unique(stacked, axis=0, return_inverse=True)
    n = len(uniq)
    out = {}
    for name, kind, vals in aggs:
        if kind == "count":
            a = np.zeros(n, dtype=np.int64)
            np.add.at(a, inverse, 1)
        elif kind == "sum":
            a = np.zeros(n, dtype=np.float64)
            np.add.at(a, inverse, np.asarray(vals, dtype=np.float64))
        elif kind == "avg":
            s = np.zeros(n, dtype=np.float64)
            c = np.zeros(n, dtype=np.int64)
            np.add.at(s, inverse, np.asarray(vals, dtype=np.float64))
            np.add.at(c, inverse, 1)
            a = s / np.maximum(c, 1)
        elif kind == "min":
            a = np.full(n, np.inf)
            np.minimum.at(a, inverse, np.asarray(vals, dtype=np.float64))
        elif kind == "max":
            a = np.full(n, -np.inf)
            np.maximum.at(a, inverse, np.asarray(vals, dtype=np.float64))
        elif kind == "first":
            a = np.zeros(n, dtype=np.asarray(vals).dtype)
            # first occurrence wins: reverse so earliest write lands last
            a[inverse[::-1]] = np.asarray(vals)[::-1]
        out[name] = a
    return [uniq[:, i] for i in range(len(keys))], out


def _lookup(build_keys: np.ndarray, build_vals, probe_keys: np.ndarray):
    """probe -> (matched mask, gathered values list). build keys unique."""
    if len(build_keys) == 0:
        matched = np.zeros(len(probe_keys), dtype=bool)
        return matched, [np.zeros(len(probe_keys),
                                  dtype=np.asarray(v).dtype)
                         for v in build_vals]
    order = np.argsort(build_keys, kind="stable")
    sk = build_keys[order]
    pos = np.searchsorted(sk, probe_keys)
    pos_c = np.clip(pos, 0, len(sk) - 1)
    matched = sk[pos_c] == probe_keys
    idx = order[pos_c]
    return matched, [np.asarray(v)[idx] for v in build_vals]


def _pack2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return a.astype(np.int64) * 2_000_003 + b.astype(np.int64)


def q1(d):
    li = d["lineitem"]
    m = li["l_shipdate"] <= _D("1998-12-01") - 90
    disc = li["l_extendedprice"] * (1 - li["l_discount"])
    charge = disc * (1 + li["l_tax"])
    keys, out = _groupby(
        [li["l_returnflag"][m], li["l_linestatus"][m]],
        [("sum_qty", "sum", li["l_quantity"][m]),
         ("sum_base_price", "sum", li["l_extendedprice"][m]),
         ("sum_disc_price", "sum", disc[m]),
         ("sum_charge", "sum", charge[m]),
         ("avg_qty", "avg", li["l_quantity"][m]),
         ("avg_price", "avg", li["l_extendedprice"][m]),
         ("avg_disc", "avg", li["l_discount"][m]),
         ("count_order", "count", None)])
    out["l_returnflag"], out["l_linestatus"] = keys
    return out   # unique() returns sorted keys == ORDER BY rf, ls


def q2(d):
    p, ps, s, n, r = (d[k] for k in ("part", "partsupp", "supplier",
                                     "nation", "region"))
    eu = r["r_regionkey"][r["r_name"] == S.REGIONS.index("EUROPE")]
    nat_eu = np.isin(n["n_regionkey"], eu)
    eu_nations = n["n_nationkey"][nat_eu]
    s_in = np.isin(s["s_nationkey"], eu_nations)
    smap = {k: i for i, k in enumerate(s["s_suppkey"])}
    pmask = (p["p_size"] == 15) & np.isin(
        p["p_type"], [i for i, t in enumerate(S.TYPES) if t.endswith("BRASS")])
    pset = {k: i for i, k in enumerate(p["p_partkey"][pmask])}
    rows = []
    for i in range(len(ps["ps_partkey"])):
        pk, sk = int(ps["ps_partkey"][i]), int(ps["ps_suppkey"][i])
        si = smap[sk]
        if pk in pset and s_in[si]:
            rows.append((pk, si, float(ps["ps_supplycost"][i])))
    if not rows:
        return {k: np.zeros(0) for k in ("s_acctbal", "p_partkey")}
    mincost = {}
    for pk, si, cost in rows:
        mincost[pk] = min(mincost.get(pk, np.inf), cost)
    nname = {int(k): int(v) for k, v in zip(n["n_nationkey"], n["n_name"])}
    recs = []
    for pk, si, cost in rows:
        if cost == mincost[pk]:
            recs.append({
                "s_acctbal": float(s["s_acctbal"][si]),
                "s_name": s["s_name"][si].tobytes(),
                "n_name": nname[int(s["s_nationkey"][si])],
                "p_partkey": pk,
                "p_mfgr": int(p["p_mfgr"][list(pset).index(pk) if False else np.searchsorted(p["p_partkey"], pk)]),
                "s_address": s["s_address"][si].tobytes(),
                "s_phone": s["s_phone"][si].tobytes(),
                "s_comment": s["s_comment"][si].tobytes(),
            })
    recs.sort(key=lambda x: (-x["s_acctbal"], x["n_name"], x["s_name"],
                             x["p_partkey"]))
    recs = recs[:100]
    return {k: np.array([r[k] for r in recs]) for k in
            ("s_acctbal", "s_name", "n_name", "p_partkey")}


def q3(d):
    c, o, li = d["customer"], d["orders"], d["lineitem"]
    building = S.SEGMENTS.index("BUILDING")
    cset = set(c["c_custkey"][c["c_mktsegment"] == building].tolist())
    om = (o["o_orderdate"] < _D("1995-03-15")) \
        & np.array([k in cset for k in o["o_custkey"]])
    ok = o["o_orderkey"][om]
    matched, (odate, oprio) = _lookup(ok, [o["o_orderdate"][om],
                                           o["o_shippriority"][om]],
                                      li["l_orderkey"])
    lm = matched & (li["l_shipdate"] > _D("1995-03-15"))
    rev = (li["l_extendedprice"] * (1 - li["l_discount"]))[lm]
    keys, out = _groupby([li["l_orderkey"][lm]],
                         [("revenue", "sum", rev),
                          ("o_orderdate", "first", odate[lm]),
                          ("o_shippriority", "first", oprio[lm])])
    order = np.lexsort((out["o_orderdate"], -out["revenue"]))[:10]
    return {"l_orderkey": keys[0][order], "revenue": out["revenue"][order],
            "o_orderdate": out["o_orderdate"][order],
            "o_shippriority": out["o_shippriority"][order]}


def q4(d):
    o, li = d["orders"], d["lineitem"]
    late = set(li["l_orderkey"][li["l_commitdate"] < li["l_receiptdate"]].tolist())
    om = (o["o_orderdate"] >= _D("1993-07-01")) \
        & (o["o_orderdate"] < _D("1993-10-01")) \
        & np.array([k in late for k in o["o_orderkey"]])
    keys, out = _groupby([o["o_orderpriority"][om]],
                         [("order_count", "count", None)])
    return {"o_orderpriority": keys[0], "order_count": out["order_count"]}


def q5(d):
    c, o, li, s, n, r = (d[k] for k in ("customer", "orders", "lineitem",
                                        "supplier", "nation", "region"))
    asia = r["r_regionkey"][r["r_name"] == S.REGIONS.index("ASIA")]
    nat_asia = n["n_nationkey"][np.isin(n["n_regionkey"], asia)]
    nname = dict(zip(n["n_nationkey"].tolist(), n["n_name"].tolist()))
    om = (o["o_orderdate"] >= _D("1994-01-01")) & (o["o_orderdate"] < _D("1995-01-01"))
    cm, (cnat,) = _lookup(c["c_custkey"], [c["c_nationkey"]], o["o_custkey"])
    om = om & cm
    lm, (lcnat,) = _lookup(o["o_orderkey"][om], [cnat[om]], li["l_orderkey"])
    sm, (snat,) = _lookup(s["s_suppkey"], [s["s_nationkey"]], li["l_suppkey"])
    keep = lm & sm & (lcnat == snat) & np.isin(snat, nat_asia)
    rev = (li["l_extendedprice"] * (1 - li["l_discount"]))[keep]
    names = np.array([nname[k] for k in snat[keep]])
    keys, out = _groupby([names], [("revenue", "sum", rev)])
    order = np.argsort(-out["revenue"])
    return {"n_name": keys[0][order], "revenue": out["revenue"][order]}


def q6(d):
    li = d["lineitem"]
    m = ((li["l_shipdate"] >= _D("1994-01-01"))
         & (li["l_shipdate"] < _D("1995-01-01"))
         & (li["l_discount"] >= 0.05 - 1e-9) & (li["l_discount"] <= 0.07 + 1e-9)
         & (li["l_quantity"] < 24))
    return {"revenue": np.array(
        [(li["l_extendedprice"][m] * li["l_discount"][m]).sum()])}


def q7(d):
    c, o, li, s, n = (d[k] for k in ("customer", "orders", "lineitem",
                                     "supplier", "nation"))
    fr, de = S.NATIONS.index("FRANCE"), S.NATIONS.index("GERMANY")
    sm, (snat,) = _lookup(s["s_suppkey"], [s["s_nationkey"]], li["l_suppkey"])
    cm, (cnat,) = _lookup(c["c_custkey"], [c["c_nationkey"]], o["o_custkey"])
    olm, (ocnat,) = _lookup(o["o_orderkey"][cm], [cnat[cm]], li["l_orderkey"])
    date_m = (li["l_shipdate"] >= _D("1995-01-01")) & (li["l_shipdate"] <= _D("1996-12-31"))
    pair = ((snat == fr) & (ocnat == de)) | ((snat == de) & (ocnat == fr))
    keep = sm & olm & date_m & pair
    vol = (li["l_extendedprice"] * (1 - li["l_discount"]))[keep]
    keys, out = _groupby([snat[keep], ocnat[keep], _year(li["l_shipdate"][keep])],
                         [("revenue", "sum", vol)])
    return {"supp_nation": keys[0], "cust_nation": keys[1],
            "l_year": keys[2], "revenue": out["revenue"]}


def q8(d):
    c, o, li, s, n, r, p = (d[k] for k in ("customer", "orders", "lineitem",
                                           "supplier", "nation", "region",
                                           "part"))
    target = S.TYPES.index("ECONOMY ANODIZED STEEL")
    brazil = S.NATIONS.index("BRAZIL")
    america = r["r_regionkey"][r["r_name"] == S.REGIONS.index("AMERICA")]
    nat_am = n["n_nationkey"][np.isin(n["n_regionkey"], america)]
    pm = set(p["p_partkey"][p["p_type"] == target].tolist())
    cm, (cnat,) = _lookup(c["c_custkey"], [c["c_nationkey"]], o["o_custkey"])
    okm = cm & np.isin(cnat, nat_am) \
        & (o["o_orderdate"] >= _D("1995-01-01")) \
        & (o["o_orderdate"] <= _D("1996-12-31"))
    olm, (odate,) = _lookup(o["o_orderkey"][okm], [o["o_orderdate"][okm]],
                            li["l_orderkey"])
    sm, (snat,) = _lookup(s["s_suppkey"], [s["s_nationkey"]], li["l_suppkey"])
    keep = olm & sm & np.array([k in pm for k in li["l_partkey"]])
    vol = (li["l_extendedprice"] * (1 - li["l_discount"]))[keep]
    yr = _year(odate[keep])
    isbr = (snat[keep] == brazil)
    keys, out = _groupby([yr], [("nat", "sum", vol * isbr),
                                ("total", "sum", vol)])
    return {"o_year": keys[0], "mkt_share": out["nat"] / out["total"]}


def q9(d):
    p, ps, s, o, li, n = (d[k] for k in ("part", "partsupp", "supplier",
                                         "orders", "lineitem", "nation"))
    green = set(p["p_partkey"][_contains(p["p_name"], "green")].tolist())
    sm, (snat,) = _lookup(s["s_suppkey"], [s["s_nationkey"]], li["l_suppkey"])
    om, (odate,) = _lookup(o["o_orderkey"], [o["o_orderdate"]], li["l_orderkey"])
    psk = _pack2(ps["ps_partkey"], ps["ps_suppkey"])
    lik = _pack2(li["l_partkey"], li["l_suppkey"])
    pm_, (cost,) = _lookup(psk, [ps["ps_supplycost"]], lik)
    keep = sm & om & pm_ & np.array([k in green for k in li["l_partkey"]])
    amount = (li["l_extendedprice"] * (1 - li["l_discount"])
              - cost * li["l_quantity"])[keep]
    nname = dict(zip(n["n_nationkey"].tolist(), n["n_name"].tolist()))
    names = np.array([nname[k] for k in snat[keep]])
    keys, out = _groupby([names, _year(odate[keep])],
                         [("sum_profit", "sum", amount)])
    order = np.lexsort((-keys[1], keys[0]))
    return {"nation": keys[0][order], "o_year": keys[1][order],
            "sum_profit": out["sum_profit"][order]}


def q10(d):
    c, o, li, n = (d[k] for k in ("customer", "orders", "lineitem", "nation"))
    om = (o["o_orderdate"] >= _D("1993-10-01")) & (o["o_orderdate"] < _D("1994-01-01"))
    lm, (lcust,) = _lookup(o["o_orderkey"][om], [o["o_custkey"][om]],
                           li["l_orderkey"])
    keep = lm & (li["l_returnflag"] == S.RETURNFLAGS.index("R"))
    rev = (li["l_extendedprice"] * (1 - li["l_discount"]))[keep]
    keys, out = _groupby([lcust[keep]], [("revenue", "sum", rev)])
    cm, (bal, cnat, cname) = _lookup(c["c_custkey"],
                                     [c["c_acctbal"], c["c_nationkey"],
                                      np.arange(len(c["c_custkey"]))],
                                     keys[0])
    order = np.argsort(-out["revenue"], kind="stable")[:20]
    return {"c_custkey": keys[0][order], "revenue": out["revenue"][order],
            "c_acctbal": bal[order]}


def q11(d, fraction=None):
    ps, s, n = d["partsupp"], d["supplier"], d["nation"]
    if fraction is None:
        fraction = 0.0001 / max(len(s["s_suppkey"]) / 10000.0, 1e-9)
    de = n["n_nationkey"][n["n_name"] == S.NATIONS.index("GERMANY")]
    sset = set(s["s_suppkey"][np.isin(s["s_nationkey"], de)].tolist())
    m = np.array([k in sset for k in ps["ps_suppkey"]])
    value = (ps["ps_supplycost"] * ps["ps_availqty"])[m]
    keys, out = _groupby([ps["ps_partkey"][m]], [("value", "sum", value)])
    total = out["value"].sum()
    keep = out["value"] > total * fraction
    order = np.argsort(-out["value"][keep], kind="stable")
    return {"ps_partkey": keys[0][keep][order],
            "value": out["value"][keep][order]}


def q12(d):
    o, li = d["orders"], d["lineitem"]
    modes = [S.SHIPMODES.index("MAIL"), S.SHIPMODES.index("SHIP")]
    m = (np.isin(li["l_shipmode"], modes)
         & (li["l_commitdate"] < li["l_receiptdate"])
         & (li["l_shipdate"] < li["l_commitdate"])
         & (li["l_receiptdate"] >= _D("1994-01-01"))
         & (li["l_receiptdate"] < _D("1995-01-01")))
    _, (oprio,) = _lookup(o["o_orderkey"], [o["o_orderpriority"]],
                          li["l_orderkey"])
    hi = np.isin(oprio, [S.PRIORITIES.index("1-URGENT"),
                         S.PRIORITIES.index("2-HIGH")])
    keys, out = _groupby([li["l_shipmode"][m]],
                         [("high_line_count", "sum", hi[m].astype(np.int64)),
                          ("low_line_count", "sum", (~hi[m]).astype(np.int64))])
    return {"l_shipmode": keys[0], "high_line_count": out["high_line_count"],
            "low_line_count": out["low_line_count"]}


def q13(d):
    c, o = d["customer"], d["orders"]
    om = ~_contains(o["o_comment"], "special", "requests")
    keys, out = _groupby([o["o_custkey"][om]], [("cnt", "count", None)])
    cm, (cnt,) = _lookup(keys[0], [out["cnt"]], c["c_custkey"])
    c_count = np.where(cm, cnt, 0)
    keys2, out2 = _groupby([c_count], [("custdist", "count", None)])
    order = np.lexsort((-keys2[0], -out2["custdist"]))
    return {"c_count": keys2[0][order], "custdist": out2["custdist"][order]}


def q14(d):
    li, p = d["lineitem"], d["part"]
    m = (li["l_shipdate"] >= _D("1995-09-01")) & (li["l_shipdate"] < _D("1995-10-01"))
    _, (ptype,) = _lookup(p["p_partkey"], [p["p_type"]], li["l_partkey"])
    promo = np.isin(ptype, [i for i, t in enumerate(S.TYPES)
                            if t.startswith("PROMO")])
    rev = li["l_extendedprice"] * (1 - li["l_discount"])
    return {"promo_revenue": np.array(
        [100.0 * rev[m & promo].sum() / rev[m].sum()])}


def q15(d):
    li, s = d["lineitem"], d["supplier"]
    m = (li["l_shipdate"] >= _D("1996-01-01")) & (li["l_shipdate"] < _D("1996-04-01"))
    rev = (li["l_extendedprice"] * (1 - li["l_discount"]))[m]
    keys, out = _groupby([li["l_suppkey"][m]], [("total_revenue", "sum", rev)])
    mx = out["total_revenue"].max()
    best = np.isclose(out["total_revenue"], mx)
    sk = np.sort(keys[0][best])
    return {"s_suppkey": sk,
            "total_revenue": np.full(len(sk), mx)}


def q16(d):
    p, ps, s = d["part"], d["partsupp"], d["supplier"]
    b45 = list(S.BRANDS).index("Brand#45")
    medpol = [i for i, t in enumerate(S.TYPES) if t.startswith("MEDIUM POLISHED")]
    sizes = [49, 14, 23, 45, 19, 3, 36, 9]
    pm = ((p["p_brand"] != b45) & ~np.isin(p["p_type"], medpol)
          & np.isin(p["p_size"], sizes))
    bad = set(s["s_suppkey"][_contains(s["s_comment"], "Customer",
                                       "Complaints")].tolist())
    pmm, (brand, ptype, psize) = _lookup(p["p_partkey"][pm],
                                         [p["p_brand"][pm], p["p_type"][pm],
                                          p["p_size"][pm]], ps["ps_partkey"])
    keep = pmm & np.array([k not in bad for k in ps["ps_suppkey"]])
    quad = np.stack([brand[keep], ptype[keep], psize[keep],
                     ps["ps_suppkey"][keep]], axis=1)
    uniq = np.unique(quad, axis=0)
    keys, out = _groupby([uniq[:, 0], uniq[:, 1], uniq[:, 2]],
                         [("supplier_cnt", "count", None)])
    order = np.lexsort((keys[2], keys[1], keys[0], -out["supplier_cnt"]))
    return {"p_brand": keys[0][order], "p_type": keys[1][order],
            "p_size": keys[2][order], "supplier_cnt": out["supplier_cnt"][order]}


def q17(d):
    li, p = d["lineitem"], d["part"]
    b23 = list(S.BRANDS).index("Brand#23")
    box = list(S.CONTAINERS).index("MED BOX")
    pset = set(p["p_partkey"][(p["p_brand"] == b23)
                              & (p["p_container"] == box)].tolist())
    m = np.array([k in pset for k in li["l_partkey"]])
    keys, out = _groupby([li["l_partkey"][m]], [("avg", "avg", li["l_quantity"][m])])
    _, (avg,) = _lookup(keys[0], [out["avg"]], li["l_partkey"])
    keep = m & (li["l_quantity"] < 0.2 * avg)
    return {"avg_yearly": np.array([li["l_extendedprice"][keep].sum() / 7.0])}


def q18(d):
    c, o, li = d["customer"], d["orders"], d["lineitem"]
    keys, out = _groupby([li["l_orderkey"]], [("sum_qty", "sum", li["l_quantity"])])
    bigm = out["sum_qty"] > 300
    om, (sq,) = _lookup(keys[0][bigm], [out["sum_qty"][bigm]], o["o_orderkey"])
    cm, (cname_i,) = _lookup(c["c_custkey"], [np.arange(len(c["c_custkey"]))],
                             o["o_custkey"])
    keep = om & cm
    order = np.lexsort((o["o_orderdate"][keep], -o["o_totalprice"][keep]))[:100]
    return {"o_orderkey": o["o_orderkey"][keep][order],
            "o_totalprice": o["o_totalprice"][keep][order],
            "o_orderdate": o["o_orderdate"][keep][order],
            "sum_qty": sq[keep][order],
            "c_custkey": o["o_custkey"][keep][order]}


def q19(d):
    li, p = d["lineitem"], d["part"]
    sm_ = S.SHIPMODES
    lm = (np.isin(li["l_shipmode"], [sm_.index("AIR"), sm_.index("REG AIR")])
          & (li["l_shipinstruct"] == S.SHIPINSTRUCT.index("DELIVER IN PERSON")))
    _, (brand, size, cont) = _lookup(p["p_partkey"],
                                     [p["p_brand"], p["p_size"],
                                      p["p_container"]], li["l_partkey"])
    def bracket(bname, conts, qlo, qhi, smax):
        b = list(S.BRANDS).index(bname)
        cs = [list(S.CONTAINERS).index(x) for x in conts]
        return ((brand == b) & np.isin(cont, cs)
                & (li["l_quantity"] >= qlo) & (li["l_quantity"] <= qhi)
                & (size >= 1) & (size <= smax))
    m = lm & (bracket("Brand#12", ("SM CASE", "SM BOX", "SM PACK", "SM PKG"), 1, 11, 5)
              | bracket("Brand#23", ("MED BAG", "MED BOX", "MED PKG", "MED PACK"), 10, 20, 10)
              | bracket("Brand#34", ("LG CASE", "LG BOX", "LG PACK", "LG PKG"), 20, 30, 15))
    rev = li["l_extendedprice"] * (1 - li["l_discount"])
    return {"revenue": np.array([rev[m].sum()])}


def q20(d):
    p, ps, s, n, li = (d[k] for k in ("part", "partsupp", "supplier",
                                      "nation", "lineitem"))
    forest = set(p["p_partkey"][_startswith(p["p_name"], "forest")].tolist())
    m94 = (li["l_shipdate"] >= _D("1994-01-01")) & (li["l_shipdate"] < _D("1995-01-01"))
    keys, out = _groupby([_pack2(li["l_partkey"][m94], li["l_suppkey"][m94])],
                         [("qty", "sum", li["l_quantity"][m94])])
    psm, (qty,) = _lookup(keys[0], [out["qty"]],
                          _pack2(ps["ps_partkey"], ps["ps_suppkey"]))
    keep = psm & np.array([k in forest for k in ps["ps_partkey"]]) \
        & (ps["ps_availqty"] > 0.5 * qty)
    sset = set(ps["ps_suppkey"][keep].tolist())
    ca = n["n_nationkey"][n["n_name"] == S.NATIONS.index("CANADA")]
    sm = np.isin(s["s_nationkey"], ca) & np.array(
        [k in sset for k in s["s_suppkey"]])
    names = [s["s_name"][i].tobytes() for i in np.where(sm)[0]]
    order = np.argsort(names)
    return {"s_name": np.array(names)[order],
            "s_suppkey": s["s_suppkey"][sm][order]}


def q21(d):
    s, o, li, n = d["supplier"], d["orders"], d["lineitem"], d["nation"]
    pairs = np.unique(_pack2(li["l_orderkey"], li["l_suppkey"]))
    okeys, ocnt = np.unique(pairs // 2_000_003, return_counts=True)
    late = li["l_receiptdate"] > li["l_commitdate"]
    lpairs = np.unique(_pack2(li["l_orderkey"][late], li["l_suppkey"][late]))
    lkeys, lcnt = np.unique(lpairs // 2_000_003, return_counts=True)
    fstat = set(o["o_orderkey"][o["o_orderstatus"]
                                == S.ORDERSTATUS.index("F")].tolist())
    sa = n["n_nationkey"][n["n_name"] == S.NATIONS.index("SAUDI ARABIA")]
    sm, (snat, sidx) = _lookup(s["s_suppkey"],
                               [s["s_nationkey"], np.arange(len(s["s_suppkey"]))],
                               li["l_suppkey"])
    am, (nsupp,) = _lookup(okeys, [ocnt], li["l_orderkey"])
    bm, (nlate,) = _lookup(lkeys, [lcnt], li["l_orderkey"])
    keep = (late & sm & np.isin(snat, sa) & am & bm
            & np.array([k in fstat for k in li["l_orderkey"]])
            & (nsupp >= 2) & (nlate == 1))
    names = np.array([s["s_name"][i].tobytes() for i in sidx[keep]])
    keys, out = _groupby([names], [("numwait", "count", None)])
    order = np.lexsort((keys[0], -out["numwait"]))[:100]
    return {"s_name": keys[0][order], "numwait": out["numwait"][order]}


def q22(d):
    c, o = d["customer"], d["orders"]
    codes = [13, 31, 23, 29, 30, 18, 17]
    code = (c["c_phone"][:, 0] - ord("0")) * 10 + (c["c_phone"][:, 1] - ord("0"))
    m = np.isin(code, codes)
    avg = c["c_acctbal"][(m) & (c["c_acctbal"] > 0)].mean()
    has_orders = set(o["o_custkey"].tolist())
    keep = m & (c["c_acctbal"] > avg) \
        & np.array([k not in has_orders for k in c["c_custkey"]])
    keys, out = _groupby([code[keep]],
                         [("numcust", "count", None),
                          ("totacctbal", "sum", c["c_acctbal"][keep])])
    return {"cntrycode": keys[0], "numcust": out["numcust"],
            "totacctbal": out["totacctbal"]}


ORACLES = {1: q1, 2: q2, 3: q3, 4: q4, 5: q5, 6: q6, 7: q7, 8: q8, 9: q9,
           10: q10, 11: q11, 12: q12, 13: q13, 14: q14, 15: q15, 16: q16,
           17: q17, 18: q18, 19: q19, 20: q20, 21: q21, 22: q22}
