from .pipeline import TokenPipeline  # noqa: F401
