"""Device-resident training data pipeline (paper H1/H2 applied to training).

Batches are produced from a memory-mapped token store (the column-chunk
format: a corpus is just an int32 column) straight into device memory with
double-buffered prefetch — the input path never materializes an
intermediate host-format copy, mirroring the paper's storage->GPU reads.

Deterministic + stateful: the pipeline position is a pure function of
``step``, so checkpoint restore resumes the exact batch sequence (required
for fault-tolerant deterministic recovery), and a worker's shard can be
reassigned on failure (elastic data reassignment).
"""

from __future__ import annotations

import collections
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    def __init__(self, tokens: np.ndarray, batch: int, seq_len: int,
                 start_step: int = 0, sharding=None, prefetch: int = 2,
                 seed: int = 0):
        self.tokens = np.asarray(tokens, dtype=np.int32)
        self.batch = batch
        self.seq = seq_len
        self.step = start_step
        self.sharding = sharding
        self.prefetch = prefetch
        self.seed = seed
        n_windows = len(self.tokens) // (seq_len + 1)
        assert n_windows >= batch, "corpus too small for one batch"
        self._n_windows = n_windows
        rng = np.random.default_rng(seed)
        self._order = rng.permutation(n_windows)
        self._buf: collections.deque = collections.deque()

    # position is a pure function of step -> deterministic resume
    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        idx = (step * self.batch + np.arange(self.batch)) % self._n_windows
        windows = self._order[idx]
        toks = np.stack([
            self.tokens[w * (self.seq + 1): w * (self.seq + 1) + self.seq + 1]
            for w in windows])
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}

    def _device_batch(self, step: int):
        host = self._host_batch(step)
        if self.sharding is not None:
            return {k: jax.device_put(v, self.sharding) for k, v in host.items()}
        return {k: jnp.asarray(v) for k, v in host.items()}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self):
        # double buffering: keep `prefetch` batches in flight so host->device
        # transfer overlaps the device step (XLA dispatch is async)
        while len(self._buf) < self.prefetch:
            self._buf.append(self._device_batch(self.step + len(self._buf)))
        out = self._buf.popleft()
        self.step += 1
        return out

    # -- checkpoint integration ----------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_state(cls, tokens, batch, seq_len, state: dict, **kw):
        return cls(tokens, batch, seq_len, start_step=state["step"],
                   seed=state["seed"], **kw)
