"""int8 error-feedback gradient compression for the data-parallel all-reduce.

Beyond-paper optimization mirroring the paper's "compact before exchange"
principle (vector compaction, §3.3.2): gradients are quantized to int8 with
a per-tensor scale before crossing the DP axis, and the quantization error
is fed back into the next step so the compression is unbiased over time.

Used inside a shard_map over the dp axes: all-reduce bytes drop 4x
(fp32->int8) at the cost of one extra abs-max pass. See EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """fp -> (int8 payload, fp32 scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, error):
    """(grads + carried error) -> (int8 tree, scales, new error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return q, s, target - deq

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error)
    qs, ss, es = zip(*(one(g, e) for g, e in zip(flat, eflat)))
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, es))


def allreduce_compressed(grads, error, axis_names):
    """Compressed psum over ``axis_names`` (call inside shard_map).

    Quantize -> psum int32 (the wire format; int8 summed across W workers
    needs log2(W) headroom) -> dequantize with the max scale.
    """
    q, s, new_error = compress_tree(grads, error)

    def reduce_one(qt, st):
        total = qt.astype(jnp.int32)
        smax = st
        for ax in axis_names:
            total = jax.lax.psum(total, ax)
            smax = jax.lax.pmax(smax, ax)
        n = 1
        for ax in axis_names:
            # jax.lax.axis_size only exists on newer jaxlibs; psum of a
            # unit is the portable spelling of the axis size
            n *= jax.lax.psum(1, ax)
        return dequantize(total, smax) / n

    out = jax.tree.map(reduce_one, q, s)
    return out, new_error


def compressed_bytes(grads) -> int:
    """Wire bytes with compression (int8 payload + one fp32 scale/tensor)."""
    return sum(g.size + 4 for g in jax.tree.leaves(grads))


def raw_bytes(grads) -> int:
    return sum(g.size * 4 for g in jax.tree.leaves(grads))
