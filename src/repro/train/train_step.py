"""train_step: loss -> grads -> AdamW, with microbatch gradient accumulation.

Distribution comes from pjit + the sharding policy (models/sharding.py):
parameters are FSDP-sharded over dp axes and tensor-sharded over the tp
axis; the batch is dp-sharded. GSPMD inserts the per-layer weight
all-gathers (overlapped with the scan-over-layers compute) and the gradient
reduce-scatters. This is the paper-faithful "keep everything on device"
training loop — host touches nothing but scalars.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..models.model import LMModel
from .optimizer import AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def train_state_init(model: LMModel, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, adamw_init(params))


def make_train_step(model: LMModel, *, microbatches: int = 1,
                    base_lr: float = 3e-4, total_steps: int = 10_000):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            b = x.shape[0]
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_body(carry, mb):
            loss_sum, g_sum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_sum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                 g_sum, g)
            return (loss_sum + loss, g_sum), ()

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc_body,
                                            (jnp.zeros(()), zeros), micro)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        params, opt, info = adamw_update(state.params, grads, state.opt,
                                         base_lr=base_lr,
                                         total_steps=total_steps)
        metrics = {"loss": loss, **info}
        return TrainState(params, opt), metrics

    return train_step
