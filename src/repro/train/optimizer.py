"""AdamW with sharded state + cosine schedule + global-norm clipping.

Optimizer moments inherit the parameter sharding (ZeRO: FSDP-sharded
params => FSDP-sharded m/v, nothing replicated), which is what makes
granite-34b-class models fit 16 GB/chip on the production mesh.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def lr_schedule(step, base_lr: float = 3e-4, warmup: int = 100,
                total: int = 10_000):
    step = step.astype(jnp.float32)
    warm = step / warmup
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return base_lr * jnp.where(step < warmup, warm, 0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, *, base_lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip: float = 1.0,
                 warmup: int = 100, total_steps: int = 10_000):
    step = state.step + 1
    lr = lr_schedule(step, base_lr, warmup, total_steps)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
    t = step.astype(jnp.float32)
    mhat_c = 1.0 / (1 - b1 ** t)
    vhat_c = 1.0 / (1 - b2 ** t)

    def upd(p, mm, vv):
        u = (mm * mhat_c) / (jnp.sqrt(vv * vhat_c) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), {"lr": lr, "grad_norm": gnorm}
