"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, recurrent).

Follows arXiv:2405.04517 with exponential gating and max-state
stabilization. Training/prefill run the recurrence as a rematerialized
nested chunk scan (chunk-boundary states in HBM, within-chunk recompute in
backward) — the same memory shape as the CUDA kernels' SRAM residency.
Decode is the O(1) recurrent step, which is what makes xlstm the assigned
pool's long_500k-capable [ssm] entry.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, _init

CHUNK = 64


def d_inner(cfg) -> int:
    return cfg.mamba_expand * cfg.d_model      # projection factor 2


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, di, nh = cfg.d_model, d_inner(cfg), cfg.n_heads
    keys = jax.random.split(key, 7)
    return {
        "up_proj": _init(keys[0], (d, 2 * di), d),
        "wq": _init(keys[1], (di, di), di),
        "wk": _init(keys[2], (di, di), di),
        "wv": _init(keys[3], (di, di), di),
        "gate_i": _init(keys[4], (di, nh), di).astype(jnp.float32),
        "gate_f": _init(keys[5], (di, nh), di).astype(jnp.float32),
        "down_proj": _init(keys[6], (di, d), di),
    }


class MLSTMState(NamedTuple):
    c: jax.Array   # [B, NH, DH, DH]
    n: jax.Array   # [B, NH, DH]
    m: jax.Array   # [B, NH]


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    nh = cfg.n_heads
    dh = d_inner(cfg) // nh
    return MLSTMState(jnp.zeros((batch, nh, dh, dh), jnp.float32),
                      jnp.zeros((batch, nh, dh), jnp.float32),
                      jnp.full((batch, nh), -1e30, jnp.float32))


def _mlstm_step(state: MLSTMState, qkvif):
    q, k, v, ig, fg = qkvif          # q/k/v [B,NH,DH]; ig/fg [B,NH]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state.m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]
    f_p = jnp.exp(logf + state.m - m_new)[..., None]
    c = f_p[..., None] * state.c + i_p[..., None] * (k[..., :, None]
                                                     * v[..., None, :])
    n = f_p * state.n + i_p * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    h = jnp.einsum("bhde,bhd->bhe", c, q) / denom
    return MLSTMState(c, n, m_new), h


def _split_heads(x, nh):
    b, s, di = x.shape
    return x.reshape(b, s, nh, di // nh)


# 'recurrent' streams the matrix state through every token (baseline,
# paper-faithful port of the CUDA recurrence); 'chunkwise' is the
# beyond-paper optimized form (EXPERIMENTS.md §Perf hillclimb 1): within a
# chunk the contribution is a masked decay-weighted q@k^T matmul (MXU), the
# [DH,DH] state only crosses HBM at chunk boundaries.
MLSTM_MODE = "chunkwise"          # chunkwise | recurrent


def mlstm_forward(params, x, cfg, state: MLSTMState = None,
                  mode: str = None):
    """x [B, S, D] -> [B, S, D] (+ final state if one was passed)."""
    b, s, _ = x.shape
    di, nh = d_inner(cfg), cfg.n_heads
    dh = di // nh
    xz = x @ params["up_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)
    q = _split_heads(xr @ params["wq"], nh).astype(jnp.float32) * dh ** -0.5
    k = _split_heads(xr @ params["wk"], nh).astype(jnp.float32) * dh ** -0.5
    v = _split_heads(xr @ params["wv"], nh).astype(jnp.float32)
    ig = (xr.astype(jnp.float32) @ params["gate_i"])      # [B,S,NH]
    fg = (xr.astype(jnp.float32) @ params["gate_f"])

    s0 = state if state is not None else init_mlstm_state(cfg, b)
    mode = mode or MLSTM_MODE
    if mode == "chunkwise" and s > 1:
        s1, h = _mlstm_chunkwise(q, k, v, ig, fg, s0)
    else:
        s1, h = _mlstm_recurrent(q, k, v, ig, fg, s0)
    h = h.reshape(b, s, di).astype(DTYPE)
    out = (h * jax.nn.silu(z)) @ params["down_proj"]
    return (out, s1) if state is not None else out


def _mlstm_recurrent(q, k, v, ig, fg, s0):
    b, s, nh, dh = q.shape
    chunks = s // CHUNK if (s >= CHUNK and s % CHUNK == 0) else 1
    cs = s // chunks

    def to_heads(a):
        return a  # already [B,S,NH,...]

    def chunk_body(st, args):
        def step(stt, t):
            return _mlstm_step(stt, t)
        st1, hs = jax.lax.scan(step, st,
                               jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1),
                                            args))
        return st1, hs

    args = jax.tree.map(
        lambda a: a.reshape((b, chunks, cs) + a.shape[2:]).swapaxes(0, 1),
        (q, k, v, ig, fg))
    s1, hs = jax.lax.scan(jax.checkpoint(chunk_body), s0, args)
    # hs: [chunks, cs, B, NH, DH] -> [B, S, NH*DH]
    h = hs.transpose(2, 0, 1, 3, 4)
    return s1, h


def _mlstm_chunkwise(q, k, v, ig, fg, s0: MLSTMState):
    """Stabilized chunkwise-parallel mLSTM (beyond-paper optimization).

    Expanding the recurrence within a chunk (cf. arXiv:2405.04517 App. +
    mlstm_kernels): with b_t = cumsum(log f) and chunk-entry state
    (C0, n0, m0),

        m_t   = max(m0 + b_t, max_{s<=t}(b_t - b_s + i_s))
        num_t = sum_{s<=t} e^{b_t-b_s+i_s-m_t} (q_t.k_s) v_s
                + e^{m0+b_t-m_t} q_t @ C0
        den_t = sum_{s<=t} e^{b_t-b_s+i_s-m_t} (q_t.k_s)
                + e^{m0+b_t-m_t} q_t.n0
        h_t   = num_t / max(|den_t|, e^{-m_t})

    and the chunk-exit state is the same expansion at t=L. Verified against
    the recurrent form in tests/test_xlstm_equivalence.py.
    """
    b, s, nh, dh = q.shape
    L = min(CHUNK, s)
    assert s % L == 0
    chunks = s // L

    def resh(a):  # [B,S,...] -> [chunks, B, NH, L, ...]
        a = a.reshape((b, chunks, L) + a.shape[2:])
        if a.ndim == 5:
            return a.transpose(1, 0, 3, 2, 4)     # [C,B,NH,L,DH]
        return a.transpose(1, 0, 3, 2)            # [C,B,NH,L]

    qc, kc, vc = resh(q), resh(k), resh(v)
    igc, fgc = resh(ig), resh(fg)

    def chunk(carry, args):
        c0, n0, m0 = carry                         # [B,NH,DH,DH],[B,NH,DH],[B,NH]
        qk, kk, vk, ik, fk = args                  # [B,NH,L,...]
        lf = jax.nn.log_sigmoid(fk)                # [B,NH,L]
        bcum = jnp.cumsum(lf, axis=-1)             # b_t
        a_s = ik - bcum                            # i_s - b_s
        # running max over s<=t of (b_t - b_s + i_s) = b_t + cummax(a_s)
        run = bcum + jax.lax.cummax(a_s, axis=a_s.ndim - 1)
        m = jnp.maximum(m0[..., None] + bcum, run)             # [B,NH,L]
        # decay matrix W[t,s] = exp(b_t - b_s + i_s - m_t), s<=t
        expo = (bcum[..., :, None] - bcum[..., None, :]
                + ik[..., None, :] - m[..., :, None])          # [B,NH,L,L]
        mask = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(mask, jnp.exp(expo), 0.0)
        g = jnp.einsum("bhtd,bhsd->bhts", qk, kk)              # MXU
        gw = g * w
        inter = jnp.exp(m0[..., None] + bcum - m)              # [B,NH,L]
        num = jnp.einsum("bhts,bhsd->bhtd", gw, vk) \
            + inter[..., None] * jnp.einsum("bhtd,bhde->bhte", qk, c0)
        den = jnp.sum(gw, axis=-1) + inter * jnp.einsum(
            "bhtd,bhd->bht", qk, n0)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

        # chunk-exit state (expansion at t = L)
        bL = bcum[..., -1:]                                    # [B,NH,1]
        m_exit = jnp.maximum(m0 + bL[..., 0],
                             jnp.max(bL - bcum + ik, axis=-1))
        wexit = jnp.exp(bL - bcum + ik - m_exit[..., None])    # [B,NH,L]
        c1 = jnp.exp(m0 + bL[..., 0] - m_exit)[..., None, None] * c0 \
            + jnp.einsum("bhs,bhsd,bhse->bhde", wexit, kk, vk)
        n1 = jnp.exp(m0 + bL[..., 0] - m_exit)[..., None] * n0 \
            + jnp.einsum("bhs,bhsd->bhd", wexit, kk)
        return (c1, n1, m_exit), h

    (c1, n1, m1), hs = jax.lax.scan(
        jax.checkpoint(chunk), (s0.c, s0.n, s0.m), (qc, kc, vc, igc, fgc))
    # hs: [chunks, B, NH, L, DH] -> [B, S, NH, DH]
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, nh, dh)
    return MLSTMState(c1, n1, m1), h


def mlstm_decode(params, x, cfg, state: MLSTMState):
    out, s1 = mlstm_forward(params, x, cfg, state)
    return out, s1


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    keys = jax.random.split(key, 2)
    return {
        "wx": _init(keys[0], (d, 4 * d), d).astype(jnp.float32),
        "rh": (_init(keys[1], (nh, dh, 4 * dh), dh)).astype(jnp.float32),
        "bias": jnp.zeros((4 * d,), jnp.float32),
    }


class SLSTMState(NamedTuple):
    h: jax.Array   # [B, NH, DH]
    c: jax.Array
    n: jax.Array
    m: jax.Array


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return SLSTMState(z, z, z + 1e-6, jnp.full((batch, nh, dh), -1e30))


def _slstm_step(params, cfg, state: SLSTMState, xt):
    """xt [B, D] fp32."""
    b = xt.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    pre = xt @ params["wx"] + params["bias"]
    pre = pre.reshape(b, nh, 4 * dh) \
        + jnp.einsum("bhd,hde->bhe", state.h, params["rh"])
    zg, ig, fg, og = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state.m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + state.m - m_new)
    c = f_p * state.c + i_p * jnp.tanh(zg)
    n = f_p * state.n + i_p
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(h, c, n, m_new), h


def slstm_forward(params, x, cfg, state: SLSTMState = None):
    b, s, d = x.shape
    s0 = state if state is not None else init_slstm_state(cfg, b)
    chunks = max(s // CHUNK, 1)
    cs = s // chunks
    xf = x.astype(jnp.float32).reshape(b, chunks, cs, d).swapaxes(0, 1)

    def chunk_body(st, xk):
        def step(stt, xt):
            return _slstm_step(params, cfg, stt, xt)
        return jax.lax.scan(step, st, jnp.swapaxes(xk, 0, 1))

    s1, hs = jax.lax.scan(jax.checkpoint(chunk_body), s0, xf)
    h = hs.transpose(2, 0, 1, 3, 4).reshape(b, s, d).astype(DTYPE)
    out = h
    return (out, s1) if state is not None else out


def slstm_decode(params, x, cfg, state: SLSTMState):
    out, s1 = slstm_forward(params, x, cfg, state)
    return out, s1
