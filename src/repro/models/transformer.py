"""Decoder LM assembly: embeddings + scan-over-groups of blocks + head.

Layers are stacked in groups of ``cfg.block_period`` (1 for homogeneous
stacks, 8 for jamba, 4 for xlstm) so the compiled HLO contains one group
body inside a scan — essential to keep 88-layer compiles fast at 512-way
SPMD. Group parameter/caches pytrees are uniform across groups and stacked
on a leading axis.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .layers import DTYPE, cross_entropy, init_embed, init_rms, rms_norm
from .sharding import shard_act


# Activation checkpointing for the scan-over-groups (§Perf cell B, iter 2):
# without it the scan stashes per-layer f32 residuals (attention probs,
# pre-norm activations) for backward — the dominant HBM traffic AND >1 GB
# per chip of residency at granite-34b scale. With remat only the group
# inputs are saved and the backward recomputes the rest (+1/3 flops).
REMAT_BLOCKS = True


def n_groups(cfg) -> int:
    assert cfg.n_layers % cfg.block_period == 0
    return cfg.n_layers // cfg.block_period


def _group_layer_indices(cfg, g: int):
    return range(g * cfg.block_period, (g + 1) * cfg.block_period)


def init_params(key, cfg) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    groups = []
    for g in range(n_groups(cfg)):
        gp = {f"pos{j}": blocks.init_layer(keys[i], cfg, i)
              for j, i in enumerate(_group_layer_indices(cfg, g))}
        groups.append(gp)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    p = {"embed": init_embed(keys[-1], cfg.vocab, cfg.d_model),
         "final_norm": init_rms(None, cfg.d_model),
         "blocks": stacked}
    if not cfg.tie_embeddings:
        p["lm_head"] = init_embed(keys[-2], cfg.vocab, cfg.d_model)
    return p


def _embed_in(params, cfg, batch) -> jax.Array:
    """tokens or (frontend stub) precomputed embeddings -> [B, S, D]."""
    if cfg.embed_frontend_stub and "embeds" in batch:
        return shard_act(batch["embeds"].astype(DTYPE), "hidden")
    tok = shard_act(batch["tokens"], "tokens")
    return shard_act(jnp.take(params["embed"], tok, axis=0), "hidden")


def _logits(params, cfg, x) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return shard_act(jnp.einsum("bsd,vd->bsv", x, head), "logits")


def forward(params, cfg, batch) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits [B,S,V], aux_loss)."""
    x = _embed_in(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_body(carry, gparams):
        x, aux = carry
        for j in range(cfg.block_period):
            i_static = j    # kind depends on i % periods only; offset-safe
            x, a = blocks.apply_train(gparams[f"pos{j}"], x, cfg, i_static,
                                      positions)
            aux = aux + a
        x = shard_act(x, "hidden")
        return (x, aux), ()

    body = jax.checkpoint(group_body) if REMAT_BLOCKS else group_body
    (x, aux), _ = jax.lax.scan(body,
                               (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return _logits(params, cfg, x), aux


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, aux = forward(params, cfg, batch)
    mask = batch.get("mask")
    return cross_entropy(logits, batch["labels"], mask) + 0.01 * aux


def init_caches(cfg, batch: int, max_len: int):
    groups = []
    for g in range(n_groups(cfg)):
        gc = {f"pos{j}": blocks.init_layer_cache(cfg, i, batch, max_len)
              for j, i in enumerate(_group_layer_indices(cfg, g))}
        groups.append(gc)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)


def prefill(params, cfg, batch, max_len: Optional[int] = None):
    """Run the prompt, return (last-token logits, caches)."""
    x = _embed_in(params, cfg, batch)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_body(carry, gparams):
        x, aux = carry
        caches = {}
        for j in range(cfg.block_period):
            x, a, cache = blocks.apply_prefill(gparams[f"pos{j}"], x, cfg, j,
                                               positions, max_len)
            caches[f"pos{j}"] = cache
            aux = aux + a
        x = shard_act(x, "hidden")
        return (x, aux), caches

    (x, _), caches = jax.lax.scan(group_body,
                                  (x, jnp.zeros((), jnp.float32)),
                                  params["blocks"])
    return _logits(params, cfg, x[:, -1:, :]), caches


def decode_step(params, cfg, tokens, caches, pos):
    """One decode step: tokens [B, 1] int32, pos scalar -> (logits, caches)."""
    x = shard_act(jnp.take(params["embed"], tokens, axis=0), "hidden")

    def group_body(x, scanned):
        gparams, gcaches = scanned
        new = {}
        for j in range(cfg.block_period):
            x, c = blocks.apply_decode(gparams[f"pos{j}"], x, cfg, j,
                                       gcaches[f"pos{j}"], pos)
            new[f"pos{j}"] = c
        return x, new

    x, caches = jax.lax.scan(group_body, x, (params["blocks"], caches))
    return _logits(params, cfg, x), caches
