"""Shared layers: norms, SwiGLU MLP, embeddings, RoPE."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import shard_act

DTYPE = jnp.bfloat16


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_rms(key, d):
    del key
    return jnp.ones((d,), dtype=jnp.float32)


def _init(key, shape, fan_in):
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * (fan_in ** -0.5)).astype(DTYPE)


def init_mlp(key, d, f, gelu: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": _init(k1, (d, f), d), "w2": _init(k3, (f, d), f)}
    if not gelu:
        p["w3"] = _init(k2, (d, f), d)
    return p


def mlp(params, x):
    if "w3" in params:       # SwiGLU
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    else:                    # 2-matrix GeLU (gpt-bigcode style)
        h = jax.nn.gelu(x @ params["w1"])
    h = shard_act(h, "ffn")
    return h @ params["w2"]


def init_embed(key, vocab, d):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02) \
        .astype(DTYPE)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, dh]; positions: [B, S] int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [B,S,dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL in fp32. logits [B,S,V] (possibly vocab-sharded)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
