"""Model zoo for the assigned architecture pool."""

from .model import LMModel, build_model  # noqa: F401
