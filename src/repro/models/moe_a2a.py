"""Explicit expert-parallel MoE dispatch via shard_map (§Perf hillclimb 3).

This is the paper's UcxExchange discipline applied to MoE: instead of
letting GSPMD re-layout the capacity-padded [E, C, D] bucket tensor across
the whole mesh (a full all-to-all of padded buckets, twice), each (dp, tp)
program selects the tokens destined for ITS local experts directly —
activations are tp-replicated, so dispatch needs no collective at all —
and a single psum over the tp axis combines the expert outputs.

Collective volume per MoE layer:
    GSPMD buckets:  2 x E*C*D        (dispatch + combine, padding included)
    explicit psum:  ~2 x B*S*D       (one ring all-reduce of the output)
For dbrx (E=16, top-4, cap 1.25): E*C*D = 5*B*S*D per direction -> the
explicit path moves ~5x fewer bytes. Verified numerically equivalent to
the gspmd path in tests/test_moe_dispatch.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .layers import DTYPE
from .sharding import current_axes, current_mesh


def _local_moe(flat, params, cfg, e_lo, e_local: int, cap: int):
    """Compute this shard's experts' contribution for ALL tokens.

    flat [N, D]; expert weights are the local slices [E_loc, D, F]; e_lo may
    be traced (lax.axis_index). Routing is computed redundantly on every tp
    shard (cheap: one [N, E] matmul) — the paper's 'metadata is cheap, move
    no data' tradeoff."""
    n, d = flat.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = flat.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    assign = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * assign)

    eid = topi.reshape(-1)
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    w = topw.reshape(-1).astype(DTYPE)
    # keep only copies routed to local experts: rel in [0, e_local)
    rel = eid - e_lo
    local = (rel >= 0) & (rel < e_local)
    sort_key = jnp.where(local, rel, e_local)
    order = jnp.argsort(sort_key, stable=True).astype(jnp.int32)
    sorted_rel = jnp.take(sort_key, order)
    first = jnp.searchsorted(sorted_rel,
                             jnp.arange(e_local + 1, dtype=jnp.int32),
                             side="left")
    rank = jnp.arange(n * k, dtype=jnp.int32) - jnp.take(
        first, jnp.clip(sorted_rel, 0, e_local))
    keep = (sorted_rel < e_local) & (rank < cap)
    slot = jnp.where(keep, sorted_rel * cap + rank, e_local * cap)
    slot_tok = jnp.zeros((e_local * cap,), jnp.int32).at[slot].set(
        jnp.take(tok, order), mode="drop")
    slot_w = jnp.zeros((e_local * cap,), DTYPE).at[slot].set(
        jnp.take(w, order), mode="drop")

    buckets = jnp.take(flat, slot_tok, axis=0).reshape(e_local, cap, d)
    buckets = buckets * (slot_w.reshape(e_local, cap, 1) != 0)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buckets, params["experts_w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buckets, params["experts_w3"])
    y = jnp.einsum("ecf,efd->ecd", h, params["experts_w2"])
    y_flat = y.reshape(e_local * cap, d) * slot_w[:, None]
    out = jnp.zeros((n, d), DTYPE).at[slot_tok].add(y_flat)
    return out, aux


def moe_ffn_a2a(params, x, cfg):
    """x [B, S, D] -> (y, aux). Requires an active mesh+axes context with a
    tp axis dividing n_experts; falls back to local compute otherwise."""
    from .moe import _capacity

    axes, mesh = current_axes(), current_mesh()
    b, s, d = x.shape
    cap = _capacity(b * s, cfg)

    if (axes is None or mesh is None or axes.tp is None
            or cfg.n_experts % axes.tp_size != 0):
        out, aux = _local_moe(x.reshape(b * s, d), params, cfg,
                              jnp.int32(0), cfg.n_experts, cap)
        out = out.reshape(b, s, d)
        if cfg.n_shared_experts:
            out = out + _shared(params, x)
        return out, aux

    tp = axes.tp
    e_local = cfg.n_experts // axes.tp_size
    # batch shards over dp when divisible; tiny decode batches replicate
    # (every dp row computes the same tokens — correct, just redundant)
    dp_splits = b % axes.dp_size == 0 and b >= axes.dp_size
    dp_spec = axes.dp_spec if dp_splits else None
    # per-dp-shard capacity: each program routes only its local tokens
    local_tokens = (b * s // axes.dp_size) if dp_splits else (b * s)
    cap = _capacity(max(local_tokens, 1), cfg)

    def body(xs, router, w1, w3, w2):
        n_loc = xs.shape[0] * xs.shape[1]
        flat = xs.reshape(n_loc, d)
        rank = jax.lax.axis_index(tp)
        e_lo = (rank * e_local).astype(jnp.int32)
        p_local = {"router": router, "experts_w1": w1, "experts_w3": w3,
                   "experts_w2": w2}
        out, aux = _local_moe(flat, p_local, cfg, e_lo, e_local, cap)
        # combine: one ring all-reduce of the output (the return exchange)
        out = jax.lax.psum(out, tp)
        aux = jax.lax.psum(aux, tp) / axes.tp_size
        return out.reshape(xs.shape), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False)
    out, aux = fn(x, params["router"], params["experts_w1"],
                  params["experts_w3"], params["experts_w2"])
    if cfg.n_shared_experts:
        out = out + _shared(params, x)
    return out, aux


def _shared(params, x):
    h = jax.nn.silu(x @ params["shared_w1"]) * (x @ params["shared_w3"])
    return h @ params["shared_w2"]
