"""Layer blocks: uniform interface over attention / Mamba / mLSTM / sLSTM
token mixers and MLP / MoE channel mixers, so a periodic pattern (jamba's
1:7 attention:mamba with MoE every 2nd layer; xlstm's mLSTM/sLSTM mix) can
run under one scan-over-groups.

Block kind per layer index is static (from ArchConfig); caches are a pytree
per layer whose structure depends only on the kind, so group cache trees are
uniform and stack cleanly across scan steps.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import moe as moe_mod
from . import xlstm as xl
from .layers import init_mlp, init_rms, mlp, rms_norm


def layer_kind(cfg, i: int) -> Tuple[str, str]:
    """(mixer, channel) for layer i."""
    if cfg.family == "ssm":
        mixer = "slstm" if cfg.is_slstm_layer(i) else "mlstm"
        channel = "none" if cfg.d_ff == 0 else "mlp"
        return mixer, channel
    if cfg.family == "hybrid":
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
    else:
        mixer = "attn"
    channel = "moe" if cfg.is_moe_layer(i) else "mlp"
    return mixer, channel


def init_layer(key, cfg, i: int):
    mixer, channel = layer_kind(cfg, i)
    k1, k2 = jax.random.split(key)
    p: dict = {"ln1": init_rms(None, cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = attn.init_attention(k1, cfg)
    elif mixer == "mamba":
        p["mixer"] = mb.init_mamba(k1, cfg)
    elif mixer == "mlstm":
        p["mixer"] = xl.init_mlstm(k1, cfg)
    else:
        p["mixer"] = xl.init_slstm(k1, cfg)
    if channel != "none":
        p["ln2"] = init_rms(None, cfg.d_model)
        p["ffn"] = (moe_mod.init_moe(k2, cfg) if channel == "moe"
                    else init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gelu))
    return p


def init_layer_cache(cfg, i: int, batch: int, max_len: int):
    mixer, _ = layer_kind(cfg, i)
    if mixer == "attn":
        return attn.init_kv_cache(cfg, batch, max_len)
    if mixer == "mamba":
        return mb.init_mamba_state(cfg, batch)
    if mixer == "mlstm":
        return xl.init_mlstm_state(cfg, batch)
    return xl.init_slstm_state(cfg, batch)


# -- forward paths -----------------------------------------------------------

def apply_train(p, x, cfg, i: int, positions):
    """Full-sequence path (train / logits-over-sequence)."""
    mixer, channel = layer_kind(cfg, i)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        h = attn.full_attention(p["mixer"], h, cfg, positions)
    elif mixer == "mamba":
        h = mb.mamba_forward(p["mixer"], h, cfg)
    elif mixer == "mlstm":
        h = xl.mlstm_forward(p["mixer"], h, cfg)
    else:
        h = xl.slstm_forward(p["mixer"], h, cfg)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if channel != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if channel == "moe":
            h, aux = moe_mod.moe_ffn(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h)
        x = x + h
    return x, aux


def apply_prefill(p, x, cfg, i: int, positions, max_len: int):
    """Full-sequence forward that also materializes the decode cache."""
    mixer, channel = layer_kind(cfg, i)
    b, s, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        # compute k/v once: reuse the qkv path via full attention plus an
        # explicit cache write (pad to max_len)
        q, k, v = attn._qkv(p["mixer"], h, cfg, positions)
        cache = attn.init_kv_cache(cfg, b, max_len)
        cache = attn.KVCache(
            jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0)))
        scores = attn._gqa_scores(q, k, cfg).astype(jnp.float32)
        maskv = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(maskv[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
        h = ctx.reshape(b, s, -1) @ p["mixer"]["wo"]
    elif mixer == "mamba":
        h, cache = _mamba_prefill(p["mixer"], h, cfg)
    elif mixer == "mlstm":
        h, cache = xl.mlstm_forward(p["mixer"], h, cfg,
                                    xl.init_mlstm_state(cfg, b))
    else:
        h, cache = xl.slstm_forward(p["mixer"], h, cfg,
                                    xl.init_slstm_state(cfg, b))
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if channel != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if channel == "moe":
            h, aux = moe_mod.moe_ffn(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h)
        x = x + h
    return x, aux, cache


def _mamba_prefill(params, x, cfg):
    """mamba_forward + final (conv window, ssm state) for decode handoff."""
    out = mb.mamba_forward(params, x, cfg)
    # final conv window = last (d_conv-1) pre-conv activations
    xz = x @ params["in_proj"]
    xr, _ = jnp.split(xz, 2, axis=-1)
    window = xr[:, -(cfg.mamba_d_conv - 1):, :]
    # final ssm state: recompute from the last chunk boundary is what the
    # kernel does; here we rerun the scan on the tail for the state only
    state = _mamba_tail_state(params, xr, cfg)
    return out, mb.MambaState(window, state)


def _mamba_tail_state(params, xr, cfg):
    xc = jax.nn.silu(mb._conv(params, xr, cfg)).astype(jnp.float32)
    dt, bmat, _ = mb._ssm_params(params, xc.astype(x_dtype(xr)), cfg)
    a = -jnp.exp(params["a_log"])

    def step(h, t):
        xt, dtt, bt = t
        da = jnp.exp(dtt[:, :, None] * a)
        h = da * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        return h, ()

    b = xr.shape[0]
    h0 = jnp.zeros((b, mb.d_inner(cfg), cfg.mamba_d_state), jnp.float32)
    h1, _ = jax.lax.scan(step, h0, (xc.transpose(1, 0, 2),
                                    dt.transpose(1, 0, 2),
                                    bmat.transpose(1, 0, 2)))
    return h1


def x_dtype(x):
    return x.dtype


def apply_decode(p, x, cfg, i: int, cache, pos):
    """One-token step against the layer cache."""
    mixer, channel = layer_kind(cfg, i)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        h, cache = attn.decode_attention(p["mixer"], h, cfg, cache, pos)
    elif mixer == "mamba":
        h, cache = mb.mamba_decode(p["mixer"], h, cfg, cache)
    elif mixer == "mlstm":
        h, cache = xl.mlstm_decode(p["mixer"], h, cfg, cache)
    else:
        h, cache = xl.slstm_decode(p["mixer"], h, cfg, cache)
    x = x + h
    if channel != "none":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if channel == "moe":
            h, _ = moe_mod.moe_ffn(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h)
        x = x + h
    return x, cache
