"""LMModel facade: uniform init / loss / prefill / decode over all assigned
architectures, plus ShapeDtypeStruct input specs for the dry-run."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, transformer
from .layers import DTYPE


@dataclasses.dataclass
class LMModel:
    cfg: ArchConfig

    @property
    def is_encdec(self) -> bool:
        return self.cfg.family == "encdec"

    # -- params ---------------------------------------------------------------
    def init(self, key) -> dict:
        if self.is_encdec:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    # -- train ------------------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        if self.is_encdec:
            return encdec.loss_fn(params, self.cfg, batch)
        return transformer.loss_fn(params, self.cfg, batch)

    def forward(self, params, batch):
        mod = encdec if self.is_encdec else transformer
        return mod.forward(params, self.cfg, batch)

    # -- serve ------------------------------------------------------------------
    def prefill(self, params, batch, max_len: Optional[int] = None):
        if self.is_encdec:
            return encdec.prefill(params, self.cfg, batch)
        return transformer.prefill(params, self.cfg, batch, max_len)

    def decode_step(self, params, tokens, caches, pos):
        mod = encdec if self.is_encdec else transformer
        return mod.decode_step(params, self.cfg, tokens, caches, pos)

    def init_caches(self, batch: int, max_len: int):
        assert not self.is_encdec, "encdec caches come from prefill()"
        return transformer.init_caches(self.cfg, batch, max_len)

    # -- dry-run input specs -------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation).

        train  -> the train_step batch
        prefill-> the prompt batch
        decode -> (tokens [B,1], pos) -- caches come from cache_specs().
        """
        b, s = shape.global_batch, shape.seq_len
        cfg = self.cfg
        i32 = jnp.int32
        if self.is_encdec:
            s_dec = max(s // 4, 16)     # text shorter than audio frames
            if shape.kind == "train":
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE),
                        "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
                        "labels": jax.ShapeDtypeStruct((b, s_dec), i32)}
            if shape.kind == "prefill":
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE)}
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.embed_frontend_stub:     # vlm backbone: patch embeddings
            if shape.kind == "train":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE),
                        "labels": jax.ShapeDtypeStruct((b, s), i32)}
            if shape.kind == "prefill":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), DTYPE)}
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    def cache_specs(self, shape: ShapeSpec):
        """ShapeDtypeStructs for decode caches (KV of seq_len per shape)."""
        b, s = shape.global_batch, shape.seq_len
        if self.is_encdec:
            t = s
            k, dh = self.cfg.n_kv, self.cfg.head_dim
            self_spec = jax.eval_shape(
                lambda: encdec_attention_caches(self.cfg, b))
            return {"self": self_spec,
                    "cross_k": jax.ShapeDtypeStruct(
                        (self.cfg.n_layers, b, t, k, dh), DTYPE),
                    "cross_v": jax.ShapeDtypeStruct(
                        (self.cfg.n_layers, b, t, k, dh), DTYPE)}
        return jax.eval_shape(
            lambda: transformer.init_caches(self.cfg, b, s))


def encdec_attention_caches(cfg, b):
    from . import attention as attn
    return jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[attn.init_kv_cache(cfg, b, encdec.SELF_BUFFER)
          for _ in range(cfg.n_layers)])


def build_model(cfg: ArchConfig) -> LMModel:
    return LMModel(cfg)


def synthetic_batch(model: LMModel, shape: ShapeSpec, seed: int = 0):
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in model.input_specs(shape).items():
        if spec.dtype == jnp.int32:
            out[name] = jnp.asarray(
                rng.integers(0, model.cfg.vocab, spec.shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(
                rng.normal(0, 1, spec.shape).astype(np.float32), dtype=spec.dtype)
    return out
