"""Encoder-decoder backbone (seamless-m4t-large-v2).

Encoder input is the audio frontend STUB per the assignment: precomputed
frame embeddings [B, S_enc, D]. Decoder is a causal LM with per-layer cross
attention over the encoder output. Decode-shape cells attend over a cross
KV of seq_len frames (the dominant cache) plus a small self-attention
generation buffer.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import (DTYPE, cross_entropy, init_embed, init_mlp, init_rms,
                     mlp, rms_norm)
from .sharding import shard_act

SELF_BUFFER = 1024      # decoder self-attention generation window


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms(None, cfg.d_model),
            "mixer": attn.init_attention(k1, cfg),
            "ln2": init_rms(None, cfg.d_model),
            "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff)}


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_rms(None, cfg.d_model),
            "mixer": attn.init_attention(k1, cfg),
            "ln_x": init_rms(None, cfg.d_model),
            "cross": attn.init_attention(k2, cfg),
            "ln2": init_rms(None, cfg.d_model),
            "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff)}


def init_params(key, cfg) -> dict:
    n = cfg.n_enc_layers + cfg.n_layers
    keys = jax.random.split(key, n + 2)
    enc = [init_enc_layer(keys[i], cfg) for i in range(cfg.n_enc_layers)]
    dec = [init_dec_layer(keys[cfg.n_enc_layers + i], cfg)
           for i in range(cfg.n_layers)]
    return {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model),
        "final_norm": init_rms(None, cfg.d_model),
        "enc_norm": init_rms(None, cfg.d_model),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "lm_head": init_embed(keys[-2], cfg.vocab, cfg.d_model),
    }


def encode(params, cfg, frames) -> jax.Array:
    x = shard_act(frames.astype(DTYPE), "hidden")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.full_attention(p["mixer"], h, cfg, positions,
                                    causal=False)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = shard_act(x + mlp(p["ffn"], h), "hidden")
        return x, ()

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg, batch) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced train path -> (logits, aux=0)."""
    memory = encode(params, cfg, batch["frames"])
    tok = shard_act(batch["tokens"], "tokens")
    x = shard_act(jnp.take(params["embed"], tok, axis=0), "hidden")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.full_attention(p["mixer"], h, cfg, positions)
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attention(p["cross"], h, memory, cfg)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = shard_act(x + mlp(p["ffn"], h), "hidden")
        return x, ()

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, params["lm_head"]), \
        jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch) -> jax.Array:
    logits, _ = forward(params, cfg, batch)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


def prefill(params, cfg, batch):
    """Encode + project per-layer cross K/V + empty self caches."""
    memory = encode(params, cfg, batch["frames"])
    b, t, _ = memory.shape
    k, dh = cfg.n_kv, cfg.head_dim

    def project(_, p):
        ck = (memory @ p["cross"]["wk"]).reshape(b, t, k, dh)
        cv = (memory @ p["cross"]["wv"]).reshape(b, t, k, dh)
        return (), (shard_act(ck, "kv_cache"), shard_act(cv, "kv_cache"))

    _, (cross_k, cross_v) = jax.lax.scan(project, (), params["dec_blocks"])
    self_cache = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[attn.init_kv_cache(cfg, b, SELF_BUFFER)
          for _ in range(cfg.n_layers)])
    return {"self": self_cache, "cross_k": cross_k, "cross_v": cross_v}


def decode_step(params, cfg, tokens, caches, pos):
    x = jnp.take(params["embed"], tokens, axis=0)
    b = x.shape[0]

    def body(x, scanned):
        p, self_c, ck, cv = scanned
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        h_attn, self_c = attn.decode_attention(p["mixer"], h, cfg, self_c,
                                               jnp.minimum(pos, SELF_BUFFER - 1))
        x = x + h_attn
        # cross attention against the precomputed memory projection
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        q = (h @ p["cross"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        scores = attn._gqa_scores(q, ck, cfg).astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        ctx = jnp.einsum("bkgst,btkd->bskgd", probs, cv)
        x = x + ctx.reshape(b, 1, -1) @ p["cross"]["wo"]
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h)
        return x, self_c

    x, self_cache = jax.lax.scan(
        body, x, (params["dec_blocks"], caches["self"],
                  caches["cross_k"], caches["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    return logits, {"self": self_cache, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}
