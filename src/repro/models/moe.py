"""Mixture-of-Experts with expert parallelism.

The dispatch/combine here is the paper's exchange problem in LM form
(DESIGN.md §4): tokens are partitioned by destination expert exactly like
rows are partitioned by hash in the query engine, with a static capacity
per expert (the receive-buffer sizing of the exchange's metadata phase).

Two dispatch modes:
* 'gspmd'  -- buckets are laid out [E, C, D] and constrained to the tp axis;
  the partitioner inserts the all-to-all (like GSPMD-planned exchange).
* 'a2a'    -- explicit shard_map all_to_all dispatch (the UcxExchange-
  faithful path; see moe_a2a.py). Selected via MOE_DISPATCH.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import DTYPE, _init
from .sharding import shard_act

# Dispatch mode (§Perf hillclimb C): 'a2a' (production default) selects
# tokens for local experts inside a shard_map and combines with one psum —
# 5x less collective volume than letting GSPMD relayout padded buckets.
# 'gspmd' is the planner-implicit baseline. Without an active mesh both
# compute identical results locally.
MOE_DISPATCH = "a2a"        # gspmd | a2a
CAPACITY_FACTOR = 1.25      # expert bucket slack (1.0 = compacted, §Perf C)


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(keys[0], (d, e), jnp.float32) * 0.02),
        "experts_w1": _init(keys[1], (e, d, f), d),
        "experts_w3": _init(keys[2], (e, d, f), d),
        "experts_w2": _init(keys[3], (e, f, d), f),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        sk = jax.random.split(keys[4], 3)
        p["shared_w1"] = _init(sk[0], (d, fs), d)
        p["shared_w3"] = _init(sk[1], (d, fs), d)
        p["shared_w2"] = _init(sk[2], (fs, d), fs)
    return p


def _capacity(n_tokens: int, cfg, factor: float = None) -> int:
    factor = CAPACITY_FACTOR if factor is None else factor
    c = int(n_tokens * cfg.top_k / cfg.n_experts * factor) + 1
    return max(((c + 127) // 128) * 128, 128)   # lane-aligned


def moe_ffn(params, x, cfg):
    """x: [B, S, D] -> (y, aux_loss). Sort-based static-capacity dispatch."""
    if MOE_DISPATCH == "a2a":
        from . import moe_a2a
        return moe_a2a.moe_ffn_a2a(params, x, cfg)
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    flat = x.reshape(n, d)

    logits = (flat.astype(jnp.float32) @ params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                      # [N, k]
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # aux load-balance loss (Switch): E * <f_e, p_e>
    me = jnp.mean(probs, axis=0)
    assign = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * assign)

    # -- dispatch: partition token copies by expert (the exchange) ---------
    cap = _capacity(n, cfg)
    eid = topi.reshape(-1)                                    # [N*k]
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    w = topw.reshape(-1).astype(DTYPE)
    order = jnp.argsort(eid, stable=True).astype(jnp.int32)
    sorted_eid = jnp.take(eid, order)
    first = jnp.searchsorted(sorted_eid, jnp.arange(e + 1, dtype=jnp.int32),
                             side="left")
    rank = jnp.arange(n * k, dtype=jnp.int32) - jnp.take(first, sorted_eid)
    keep = rank < cap                                         # capacity drop
    slot = jnp.where(keep, sorted_eid * cap + rank, e * cap)
    slot_tok = jnp.zeros((e * cap,), jnp.int32).at[slot].set(
        jnp.take(tok, order), mode="drop")
    slot_w = jnp.zeros((e * cap,), DTYPE).at[slot].set(
        jnp.take(w, order), mode="drop")

    buckets = jnp.take(flat, slot_tok, axis=0).reshape(e, cap, d)
    buckets = buckets * (slot_w.reshape(e, cap, 1) != 0)
    buckets = shard_act(buckets, "experts")                   # -> a2a on ICI

    # -- expert compute (each expert local to one tp shard) ----------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buckets, params["experts_w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buckets, params["experts_w3"])
    y = jnp.einsum("ecf,efd->ecd", h, params["experts_w2"])
    y = shard_act(y, "experts")

    # -- combine: weighted scatter back to token order (return exchange) ---
    y_flat = y.reshape(e * cap, d) * slot_w[:, None]
    out = jnp.zeros((n, d), DTYPE).at[slot_tok].add(y_flat)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(flat @ params["shared_w1"]) * (flat @ params["shared_w3"])
        out = out + hs @ params["shared_w2"]
    return out.reshape(b, s, d), aux
