"""Mamba-1 selective SSM block (jamba's token mixer).

TPU adaptation (DESIGN.md §2): the CUDA kernel's SRAM-resident selective
scan becomes a nested scan — outer lax.scan over chunks carries the
[B, DI, N] state (only chunk-boundary states live in HBM), the rematerialized
inner scan recomputes within-chunk states in the backward pass. This bounds
activation memory at seq_len/chunk boundary states instead of seq_len.

Decode is the O(1) recurrent step on (conv window, ssm state).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, _init
from .sharding import shard_act

CHUNK = 64


def d_inner(cfg) -> int:
    return cfg.mamba_expand * cfg.d_model


def dt_rank(cfg) -> int:
    return math.ceil(cfg.d_model / 16)


def init_mamba(key, cfg):
    d, di, n, r = cfg.d_model, d_inner(cfg), cfg.mamba_d_state, dt_rank(cfg)
    keys = jax.random.split(key, 6)
    return {
        "in_proj": _init(keys[0], (d, 2 * di), d),
        "conv_w": _init(keys[1], (cfg.mamba_d_conv, di), cfg.mamba_d_conv),
        "conv_b": jnp.zeros((di,), DTYPE),
        "x_proj": _init(keys[2], (di, r + 2 * n), di),
        "dt_proj": _init(keys[3], (r, di), r),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).copy(),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _init(keys[4], (di, d), di),
    }


def _ssm_params(params, xc, cfg):
    """xc [..., DI] -> (dt [...,DI], B [...,N], C [...,N]) selective params."""
    n, r = cfg.mamba_d_state, dt_rank(cfg)
    proj = xc @ params["x_proj"]
    dt = jax.nn.softplus(
        (proj[..., :r] @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])
    b = proj[..., r: r + n].astype(jnp.float32)
    c = proj[..., r + n:].astype(jnp.float32)
    return dt, b, c


def _conv(params, x, cfg):
    """Causal depthwise conv over seq. x [B, S, DI]."""
    kw = cfg.mamba_d_conv
    pad = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(kw):   # small static unroll (kw = 4)
        out = out + pad[:, i: i + x.shape[1], :] * params["conv_w"][i]
    return out + params["conv_b"]


def mamba_forward(params, x, cfg):
    """Train/prefill: nested chunk scan. x [B, S, D] -> [B, S, D]."""
    bsz, s, _ = x.shape
    di, n = d_inner(cfg), cfg.mamba_d_state
    xz = x @ params["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv(params, xr, cfg))

    dt, bmat, cmat = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["a_log"])                      # [DI, N]
    # discretize: da [B,S,DI,N], db·x [B,S,DI,N]
    chunks = max(s // CHUNK, 1)
    csize = s // chunks
    xc_f32 = xc.astype(jnp.float32)

    def chunk_body(h0, args):
        xck, dtk, bk, ck = args                        # [csize, ...] per batch

        def step(h, t):
            xt, dtt, bt, ct = t
            da = jnp.exp(dtt[:, :, None] * a)          # [B, DI, N]
            h = da * h + (dtt * xt)[:, :, None] * bt[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, ct)
            return h, y

        h1, ys = jax.lax.scan(step, h0,
                              (xck.transpose(1, 0, 2), dtk.transpose(1, 0, 2),
                               bk.transpose(1, 0, 2), ck.transpose(1, 0, 2)))
        return h1, ys

    h0 = shard_act(jnp.zeros((bsz, di, n), jnp.float32), "mamba_state")
    xs = (xc_f32.reshape(bsz, chunks, csize, di).transpose(1, 0, 2, 3),
          dt.reshape(bsz, chunks, csize, di).transpose(1, 0, 2, 3),
          bmat.reshape(bsz, chunks, csize, n).transpose(1, 0, 2, 3),
          cmat.reshape(bsz, chunks, csize, n).transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.transpose(2, 0, 1, 3).reshape(bsz, s, di)   # [B, S, DI]
    y = y + xc_f32 * params["d_skip"]
    y = (y.astype(DTYPE) * jax.nn.silu(z))
    return y @ params["out_proj"]


class MambaState(NamedTuple):
    conv: jax.Array   # [B, d_conv-1, DI] rolling conv window
    ssm: jax.Array    # [B, DI, N]


def init_mamba_state(cfg, batch: int) -> MambaState:
    di, n = d_inner(cfg), cfg.mamba_d_state
    return MambaState(
        jnp.zeros((batch, cfg.mamba_d_conv - 1, di), DTYPE),
        shard_act(jnp.zeros((batch, di, n), jnp.float32), "mamba_state"))


def mamba_decode(params, x, cfg, state: MambaState):
    """One-token step. x [B, 1, D] -> ([B, 1, D], new state)."""
    xz = x @ params["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                  # [B,1,DI]
    window = jnp.concatenate([state.conv, xr], axis=1)  # [B, kw, DI]
    xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]                   # [B,1,DI]
    dt, bmat, cmat = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[:, 0, :, None] * a)                # [B,DI,N]
    h = da * state.ssm + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[:, :, None] \
        * bmat[:, 0][:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * params["d_skip"]
    out = (y[:, None, :].astype(DTYPE) * jax.nn.silu(z)) @ params["out_proj"]
    return out, MambaState(window[:, 1:], h)
