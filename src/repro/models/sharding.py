"""Sharding policy: maps tensor roles to PartitionSpecs on the production
mesh (DESIGN.md §5).

* ``dp`` axes shard the batch (and FSDP-shard parameters/optimizer state),
* ``tp`` axis shards heads / ffn-hidden / vocab / experts (and the KV-cache
  sequence dimension during decode).

The policy is applied two ways:
* parameter specs: path-based matching over the param pytree (for pjit
  in_shardings),
* activation constraints: ``shard_act(x, role)`` inside model code, a no-op
  unless a policy is active (so smoke tests run without any mesh).
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Axes:
    dp: Tuple[str, ...] = ()          # e.g. ("pod", "data")
    tp: Optional[str] = None          # e.g. "model"
    dp_size: int = 1
    tp_size: int = 1
    # ZeRO stage for the dp axes: 3 = params + optimizer dp-sharded (per-
    # layer weight all-gathers, lowest memory); 1 = params replicated on dp
    # (only optimizer state dp-sharded; one param all-gather per step).
    # §Perf hillclimb 2 trades these off for granite_34b.
    zero_stage: int = 3

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) != 1 else self.dp[0]


_ACTIVE: list = []


@contextlib.contextmanager
def use_axes(axes: Optional[Axes], mesh=None):
    _ACTIVE.append((axes, mesh))
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_axes() -> Optional[Axes]:
    return _ACTIVE[-1][0] if _ACTIVE else None


def current_mesh():
    return _ACTIVE[-1][1] if _ACTIVE else None


def _maybe(x, spec):
    ax = current_axes()
    if ax is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def shard_act(x, role: str):
    """Constrain an activation. Roles:
    tokens [B,S] | hidden [B,S,D] | heads [B,S,H,dh] | ffn [B,S,F] |
    logits [B,S,V] | experts [E,C,D] | kv_cache [B,S,K,dh]"""
    ax = current_axes()
    if ax is None:
        return x
    dp, tp = ax.dp_spec, ax.tp
    if role == "tokens":
        return _maybe(x, (dp, None))
    if role == "hidden":
        return _maybe(x, (dp, None, None))
    if role == "heads":
        if _div(x.shape[2], ax.tp_size):
            return _maybe(x, (dp, None, tp, None))
        return _maybe(x, (dp, None, None, None))
    if role == "ffn":
        return _maybe(x, (dp, None, tp))
    if role == "logits":
        return _maybe(x, (dp, None, tp))
    if role == "experts":                 # [E, C, D]
        if _div(x.shape[0], ax.tp_size):
            return _maybe(x, (tp, None, None))
        return x
    if role == "kv_cache":                # [B, S, K, dh]: seq on tp
        b, s = x.shape[0], x.shape[1]
        if _div(b, ax.dp_size) and b > 1:
            return _maybe(x, (dp, tp, None, None))
        # batch too small (long-context decode): shard seq over everything
        return _maybe(x, (None, tuple(ax.dp) + ((tp,) if tp else ()), None, None))
    if role == "mamba_state":             # [B, DI, N]
        if _div(x.shape[0], ax.dp_size) and x.shape[0] > 1:
            return _maybe(x, (dp, tp, None))
        return _maybe(x, (None, tp, None))
    raise ValueError(role)


# -- parameter specs ---------------------------------------------------------

# path-regex -> spec builder. Leaf shapes have a leading stack dim [G, ...]
# for block params. fsdp = first dp axis (ZeRO-3 storage sharding).
def param_spec(path: str, shape: Tuple[int, ...], axes: Axes):
    tp = axes.tp
    fsdp = axes.dp[-1] if axes.dp else None   # innermost dp axis
    # ZeRO-1: optimizer moments stay dp-sharded, parameters do not
    if axes.zero_stage == 1 and "opt" not in path:
        fsdp = None

    def ok(dim, size):
        return size and _div(shape[dim], size)

    d = {  # (regex, lambda -> spec); most specific patterns first
        r"experts_(w1|w2|w3)$":   # [G, E, D, F] / [G, E, F, D]: EP on tp
            lambda: (None, tp if ok(1, axes.tp_size) else None,
                     fsdp if ok(2, axes.dp_size) else None, None),
        r"router$": lambda: (None,) * len(shape),
        r"(bias|b_q|b_k|b_v|scale|norm.*|ln.*|a_log|d_skip|dt_bias|gate.*)$":
            lambda: (None,) * len(shape),
        r"embed$": lambda: (tp if ok(0, axes.tp_size) else None, None),
        r"(lm_head)$": lambda: (tp if ok(0, axes.tp_size) else None, None),
        r"(wq|wk|wv|w1|w3|in_proj|up_proj)$":
            lambda: (None,) * (len(shape) - 2)
            + (fsdp if ok(len(shape) - 2, axes.dp_size) else None,
               tp if ok(len(shape) - 1, axes.tp_size) else None),
        r"(wo|w2|out_proj|down_proj)$":
            lambda: (None,) * (len(shape) - 2)
            + (tp if ok(len(shape) - 2, axes.tp_size) else None,
               fsdp if ok(len(shape) - 1, axes.dp_size) else None),
    }
    for pat, fn in d.items():
        if re.search(pat, path):
            return P(*fn())
    return P(*((None,) * len(shape)))


def _norm_path(keystr_path: str) -> str:
    """".params['blocks']['wq']" -> ".params.blocks.wq" so the role regexes
    can anchor on name ends."""
    return re.sub(r"\['?([^'\]]+)'?\]", r".\1", keystr_path)


def params_shardings(params, axes: Axes, mesh):
    """NamedSharding tree for any param-bearing pytree (pjit in_shardings).
    Works over dicts, NamedTuples (TrainState/AdamWState), lists."""
    from jax.sharding import NamedSharding

    def leaf_spec(path, leaf):
        p = _norm_path(jax.tree_util.keystr(path))
        return NamedSharding(mesh, param_spec(p, leaf.shape, axes))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_shardings(batch_specs, axes: Axes, mesh):
    """Shardings for a train/prefill batch: leading batch dim on dp."""
    from jax.sharding import NamedSharding

    def one(spec):
        b = spec.shape[0]
        if _div(b, axes.dp_size) and b > 1:
            return NamedSharding(mesh, P(*( (axes.dp_spec,)
                                           + (None,) * (len(spec.shape) - 1))))
        return NamedSharding(mesh, P(*((None,) * len(spec.shape))))

    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, seq_len: int, axes: Axes, mesh):
    """Shardings for decode caches by leaf-shape heuristics.

    KV caches carry the seq_len dimension -> shard it on tp (and on dp too
    when the batch can't shard); recurrent states shard their big inner dim
    on tp. Leading group-stack dims are never sharded."""
    from jax.sharding import NamedSharding

    def one(spec):
        shape = spec.shape
        spec_axes = [None] * len(shape)
        # find the sequence axis (== seq_len or the encdec self buffer)
        seq_dims = [i for i, d in enumerate(shape) if d == seq_len and i > 0]
        batch_dims = [i for i, d in enumerate(shape)
                      if _div(d, axes.dp_size) and d > 1]
        if seq_dims:
            sd = seq_dims[-1] if len(shape) >= 4 else seq_dims[0]
            if batch_dims and batch_dims[0] < sd:
                spec_axes[batch_dims[0]] = axes.dp_spec
                spec_axes[sd] = axes.tp
            else:
                spec_axes[sd] = tuple(axes.dp) + ((axes.tp,) if axes.tp else ())
        else:
            # recurrent state: shard batch if possible, else biggest tp-divisible dim
            if batch_dims:
                spec_axes[batch_dims[0]] = axes.dp_spec
            for i in range(len(shape) - 1, 0, -1):
                if i != (batch_dims[0] if batch_dims else -1) \
                        and _div(shape[i], axes.tp_size):
                    spec_axes[i] = axes.tp
                    break
        return NamedSharding(mesh, P(*spec_axes))

    return jax.tree.map(one, cache_specs)
