"""GQA attention: train/prefill (full causal) and decode (KV cache).

TPU notes: head_dim is 128 on most assigned archs (MXU-lane aligned); GQA is
computed by reshaping Q to [B, S, K, H/K, dh] so the KV tensors are never
materialized repeated. The KV cache keeps its sequence axis shardable (see
sharding.shard_act('kv_cache')): decode attention over a sharded cache
reduces with a global max/sum, the flash-style distributed softmax.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, _init, apply_rope
from .sharding import shard_act


def init_attention(key, cfg):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    keys = jax.random.split(key, 4)
    p = {"wq": _init(keys[0], (d, h * dh), d),
         "wk": _init(keys[1], (d, k * dh), d),
         "wv": _init(keys[2], (d, k * dh), d),
         "wo": _init(keys[3], (h * dh, d), h * dh)}
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * dh,), DTYPE)
        p["b_k"] = jnp.zeros((k * dh,), DTYPE)
        p["b_v"] = jnp.zeros((k * dh,), DTYPE)
    return p


def _qkv(params, x, cfg, positions, rope: bool = True):
    b, s, _ = x.shape
    h, k, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = x @ params["wq"]
    kk = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, kk, v = q + params["b_q"], kk + params["b_k"], v + params["b_v"]
    q = q.reshape(b, s, h, dh)
    kk = kk.reshape(b, s, k, dh)
    v = v.reshape(b, s, k, dh)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)
    return shard_act(q, "heads"), kk, v


def _gqa_scores(q, k, cfg):
    """q [B,S,H,dh], k [B,T,K,dh] -> scores [B,K,H/K,S,T] without repeat."""
    b, s, h, dh = q.shape
    g = h // cfg.n_kv
    qg = q.reshape(b, s, cfg.n_kv, g, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) * (dh ** -0.5)


def full_attention(params, x, cfg, positions, causal: bool = True):
    """Train/prefill path."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    ctx = shard_act(ctx.reshape(b, s, cfg.n_heads, cfg.head_dim), "heads")
    return ctx.reshape(b, s, -1) @ params["wo"]


def cross_attention(params, x, memory, cfg):
    """Decoder-side attention over encoder output (no causal mask/rope)."""
    b, s, _ = x.shape
    t = memory.shape[1]
    h, kn, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (memory @ params["wk"]).reshape(b, t, kn, dh)
    v = (memory @ params["wv"]).reshape(b, t, kn, dh)
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return ctx.reshape(b, s, -1) @ params["wo"]


class KVCache(NamedTuple):
    k: jax.Array   # [B, S_max, K, dh]
    v: jax.Array


def init_kv_cache(cfg, batch: int, max_len: int) -> KVCache:
    shape = (batch, max_len, cfg.n_kv, cfg.head_dim)
    return KVCache(shard_act(jnp.zeros(shape, DTYPE), "kv_cache"),
                   shard_act(jnp.zeros(shape, DTYPE), "kv_cache"))


def decode_attention(params, x, cfg, cache: KVCache, pos):
    """One-token decode: update cache at ``pos``, attend over the prefix.

    x: [B, 1, D]; pos: scalar int32. Static shapes: attention runs over the
    whole cache with an index mask (memory-bound, the decode roofline)."""
    b = x.shape[0]
    q, k_new, v_new = _qkv(params, x, cfg,
                           jnp.full((b, 1), pos, dtype=jnp.int32))
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, pos, 0, 0))
    k, v = shard_act(k, "kv_cache"), shard_act(v, "kv_cache")
    scores = _gqa_scores(q, k, cfg).astype(jnp.float32)   # [B,K,G,1,S]
    smax = k.shape[1]
    live = (jnp.arange(smax) <= pos)[None, None, None, None, :]
    scores = jnp.where(live, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    out = ctx.reshape(b, 1, -1) @ params["wo"]
    return out, KVCache(k, v)
