"""Storage layer (paper §2.2).

Two formats:

* ``colchunk`` — the paper's custom minimal format: one raw binary file per
  (column, chunk), all metadata encoded in the file name, strings as
  dictionary sidecars. Reads are a single memmap -> device transfer with no
  interpretation (the KvikIO/GDS read path).
* ``paged``   — a Parquet-shaped baseline: one file per table with nested
  file/row-group/page metadata that must be interpreted during the read.
  Exists to quantify the format-overhead gap the paper measures (10x).
"""

from .colchunk import ColumnChunkTable, read_column_chunk, write_table  # noqa: F401
from .paged import PagedTable, PagedTableSource, write_paged_table  # noqa: F401
from .zonemap import eval_range, may_match  # noqa: F401
