"""Column-chunk storage format — the paper's §2.2 minimal format.

Layout on disk, for table ``t`` with C columns split into K chunks:

    <root>/t/<column>.<chunk>.<rows>.<dtypecode>.bin     (C x K files)
    <root>/t/<column>.dict                               (dict32 columns)
    <root>/t/_stats.json                                 (optional min/max)

Exactly like the paper: the file name carries the minimal metadata (column
name, type, size); the payload is the raw little-endian buffer, so a read is
memmap + device_put with zero interpretation. The paper "decided not to
allow the reading of only parts of a file": a chunk is the unit of I/O, and
the partition count (chunks) is the experiment knob of Table 1.

The optional _stats.json (per-chunk min/max) powers zone-map data skipping
(a measured beyond-paper extension; the paper's barebones runs had "no
capacity to skip data"). Skipping uses only provable chunk-level refutation
of the pushed-down predicate, so results are identical with it on or off.
"""

from __future__ import annotations

import json
import math
import os
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core import dtypes as dt
from ..core.expr import Expr
from ..core.session import TableSource
from ..core.streaming import (HostMorsel, ScanStats, empty_morsel,
                              stacked_morsel)
from .zonemap import may_match

_CODE = {"int32": "i4", "int64": "i8", "float32": "f4", "float64": "f8",
         "bool": "b1", "date32": "d4", "dict32": "c4"}
_RCODE = {v: k for k, v in _CODE.items()}


def _dtype_code(d: dt.DType) -> str:
    if d.name == "bytes":
        return f"s{d.width}"
    return _CODE[d.name]


def _decode_dtype(code: str, dictionary=None) -> dt.DType:
    if code.startswith("s"):
        return dt.bytes_(int(code[1:]))
    name = _RCODE[code]
    if name == "dict32":
        return dt.DType("dict32", dictionary=tuple(dictionary or ()))
    return dt.DType(name)


def write_table(root: str, name: str, data: Dict[str, np.ndarray],
                schema: Dict[str, dt.DType], chunks: int = 1,
                stats: bool = True) -> None:
    """Persist a table as one binary file per (column, chunk), min/max
    stats in the filename (the paper's minimal column-chunk format)."""
    tdir = os.path.join(root, name)
    os.makedirs(tdir, exist_ok=True)
    n = len(next(iter(data.values())))
    per = math.ceil(n / chunks)
    stat_entries: Dict[str, List] = {}
    for col, d in schema.items():
        arr = np.ascontiguousarray(np.asarray(data[col], dtype=d.np_dtype()))
        if d.name == "dict32":
            with open(os.path.join(tdir, f"{col}.dict"), "w") as f:
                json.dump(list(d.dictionary), f)
        col_stats = []
        for k in range(chunks):
            part = arr[k * per: min((k + 1) * per, n)]
            fname = f"{col}.{k}.{len(part)}.{_dtype_code(d)}.bin"
            part.tofile(os.path.join(tdir, fname))
            if stats and d.name in ("int32", "int64", "date32", "dict32",
                                    "float32", "float64") and len(part):
                col_stats.append([float(part.min()), float(part.max())])
            else:
                col_stats.append(None)
        stat_entries[col] = col_stats
    if stats:
        with open(os.path.join(tdir, "_stats.json"), "w") as f:
            json.dump({"rows": n, "chunks": chunks, "stats": stat_entries}, f)


def read_column_chunk(root: str, table: str, column: str, chunk: int,
                      fname: Optional[str] = None):
    """One chunk of one column: memmap -> array (the GDS-style direct read).

    ``fname`` skips the directory scan when the caller already indexed the
    chunk files (``ColumnChunkTable`` does; a per-read listdir is O(C x K)
    and dominates scan time at high chunk counts).
    """
    tdir = os.path.join(root, table)
    if fname is None:
        prefix = f"{column}.{chunk}."
        fname = next(f for f in os.listdir(tdir) if f.startswith(prefix)
                     and f.endswith(".bin"))
    _, _, rows, code, _ = fname.split(".")
    rows = int(rows)
    if code.startswith("s"):
        width = int(code[1:])
        mm = np.memmap(os.path.join(tdir, fname), dtype=np.uint8, mode="r")
        return mm.reshape(rows, width) if rows else mm.reshape(0, width)
    d = _decode_dtype(code)
    return np.memmap(os.path.join(tdir, fname), dtype=d.np_dtype(), mode="r")


class ColumnChunkTable(TableSource):
    """TableSource over the column-chunk format.

    Chunks are assigned to workers round-robin (the paper's per-MPI-process
    data fraction); each scan batch is one chunk per worker, loaded straight
    into device memory. ``skip_with_stats`` enables min/max (zone-map) chunk
    skipping against the pushed-down scan predicate: skipped chunks are
    never read from storage and never transferred to the device.
    """

    def __init__(self, root: str, name: str, skip_with_stats: bool = True):
        self.root = root
        self.name = name
        self.skip_with_stats = skip_with_stats
        tdir = os.path.join(root, name)
        self.schema: Dict[str, dt.DType] = {}
        self._chunks = 0
        self._chunk_rows: List[int] = []
        dicts = {}
        for f in sorted(os.listdir(tdir)):
            if f.endswith(".dict"):
                with open(os.path.join(tdir, f)) as fh:
                    dicts[f[:-5]] = json.load(fh)
        self._files: Dict[tuple, str] = {}       # (column, chunk) -> filename
        for f in sorted(os.listdir(tdir)):
            if not f.endswith(".bin"):
                continue
            col, chunk, rows, code, _ = f.split(".")
            self.schema.setdefault(col, _decode_dtype(code, dicts.get(col)))
            self._chunks = max(self._chunks, int(chunk) + 1)
            self._files[(col, int(chunk))] = f
        first = next(iter(self.schema))
        self._chunk_rows = [0] * self._chunks
        for f in os.listdir(tdir):
            if f.endswith(".bin") and f.split(".")[0] == first:
                _, chunk, rows, _, _ = f.split(".")
                self._chunk_rows[int(chunk)] = int(rows)
        self._stats = None
        spath = os.path.join(tdir, "_stats.json")
        if os.path.exists(spath):
            with open(spath) as fh:
                self._stats = json.load(fh)
        self.bytes_read = 0
        self.chunks_skipped = 0

    def num_rows(self) -> int:
        return sum(self._chunk_rows)

    @property
    def num_chunks(self) -> int:
        return self._chunks

    # -- data skipping (beyond-paper; driven by pushed-down filter) ---------
    def _chunk_survives(self, chunk: int, filter_expr: Optional[Expr]) -> bool:
        if not (self.skip_with_stats and self._stats and filter_expr is not None):
            return True

        def get_range(col: str):
            entry = self._stats["stats"].get(col)
            if not entry or entry[chunk] is None:
                return None
            return tuple(entry[chunk])

        return may_match(filter_expr, get_range)

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        cols = list(columns) if columns else list(self.schema.keys())
        w = num_workers
        schema = {c: self.schema[c] for c in cols}
        live = [k for k in range(self._chunks)
                if self._chunk_survives(k, filter_expr)]
        skipped = self._chunks - len(live)
        self.chunks_skipped += skipped
        if stats is not None:
            stats.chunks_total += self._chunks
            stats.chunks_skipped += skipped
        if not live:
            # every chunk pruned: one all-invalid morsel keeps downstream
            # operator shapes alive (static-shape engines need >= 1 batch)
            yield empty_morsel(schema, w)
            return

        def read(c, k):
            arr = read_column_chunk(self.root, self.name, c, k,
                                    fname=self._files[(c, k)])
            self.bytes_read += arr.nbytes
            if stats is not None:
                stats.bytes_read += arr.nbytes
            return arr

        rounds = math.ceil(len(live) / w)
        for r in range(rounds):
            assigned = live[r * w: (r + 1) * w]
            cap = max(self._chunk_rows[k] for k in assigned)
            yield stacked_morsel(cols, self.schema, w, assigned, cap, read)
