"""Paged table format — the Parquet-shaped baseline of §2.2.

One file per table with the same *hierarchical metadata* structure that
makes Parquet slow to read at device speed: a file footer, per-row-group
metadata, and per-page headers that must be parsed and interpreted
sequentially, with data and decode interleaved. Values are additionally
delta-encoded per page so the read path has real decode work, like
Parquet's encodings.

This format exists to measure the gap the paper quantifies (their Parquet
read ran 10x below the hardware I/O bound; their minimal format hit 95%).
"""

from __future__ import annotations

import json
import math
import os
import struct
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..core import dtypes as dt
from ..core.session import TableSource
from ..core.streaming import (HostMorsel, ScanStats, empty_morsel,
                              stacked_morsel)
from .zonemap import may_match

_MAGIC = b"PGD1"
_PAGE_ROWS = 1024


def write_paged_table(root: str, name: str, data: Dict[str, np.ndarray],
                      schema: Dict[str, dt.DType], row_groups: int = 4) -> None:
    """Persist a table in the paged format: magic, delta-encoded pages with
    JSON headers, per-row-group metadata, JSON footer + trailing offset."""
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"{name}.paged")
    n = len(next(iter(data.values())))
    per_rg = max(1, (n + row_groups - 1) // row_groups)
    rg_meta = []
    with open(path, "wb") as f:
        f.write(_MAGIC)
        for rg in range(row_groups):
            lo, hi = rg * per_rg, min((rg + 1) * per_rg, n)
            col_meta = {}
            for col, d in schema.items():
                arr = np.asarray(data[col][lo:hi], dtype=d.np_dtype())
                pages = []
                for p0 in range(0, max(hi - lo, 1), _PAGE_ROWS):
                    page = arr[p0: p0 + _PAGE_ROWS]
                    if d.name == "bytes":
                        payload = page.tobytes()
                        enc = "plain"
                    elif d.name in ("float32", "float64", "bool"):
                        payload = page.tobytes()
                        enc = "plain"
                    else:
                        # delta encoding: first value + int32 deltas
                        flat = page.astype(np.int64)
                        first = int(flat[0]) if len(flat) else 0
                        deltas = np.diff(flat, prepend=first).astype(np.int32)
                        payload = deltas.tobytes()
                        enc = "delta"
                    header = json.dumps({
                        "rows": int(len(page)), "enc": enc, "col": col,
                        "dtype": d.name, "width": d.width,
                        "first": int(page[0]) if (enc == "delta" and len(page)) else 0,
                        "min": float(page.min()) if (len(page) and d.name != "bytes") else 0,
                        "max": float(page.max()) if (len(page) and d.name != "bytes") else 0,
                    }).encode()
                    off = f.tell()
                    f.write(struct.pack("<I", len(header)))
                    f.write(header)
                    f.write(struct.pack("<I", len(payload)))
                    f.write(payload)
                    pages.append(off)
                col_meta[col] = pages
            rg_meta.append({"rows": hi - lo, "columns": col_meta})
        footer = json.dumps({
            "rows": n,
            "row_groups": rg_meta,
            "schema": {c: {"name": d.name, "width": d.width,
                           "dict": list(d.dictionary) if d.dictionary else None}
                       for c, d in schema.items()},
        }).encode()
        foff = f.tell()
        f.write(footer)
        f.write(struct.pack("<Q", foff))


class PagedTable:
    """Reader that must walk footer -> row group -> page headers, parsing
    and decoding as it goes (the interpretation overhead under study)."""

    def __init__(self, root: str, name: str):
        self.path = os.path.join(root, f"{name}.paged")
        with open(self.path, "rb") as f:
            f.seek(-8, os.SEEK_END)
            (foff,) = struct.unpack("<Q", f.read(8))
            end = f.tell() - 8
            f.seek(foff)
            self.footer = json.loads(f.read(end - foff))
        sch = {}
        for c, meta in self.footer["schema"].items():
            if meta["name"] == "bytes":
                sch[c] = dt.bytes_(meta["width"])
            elif meta["name"] == "dict32":
                sch[c] = dt.DType("dict32", dictionary=tuple(meta["dict"]))
            else:
                sch[c] = dt.DType(meta["name"])
        self.schema = sch
        self.pages_read = 0
        self.bytes_read = 0

    def _read_page(self, f, off: int, d: dt.DType) -> np.ndarray:
        f.seek(off)
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))          # metadata interpret
        (plen,) = struct.unpack("<I", f.read(4))
        payload = f.read(plen)
        self.pages_read += 1
        self.bytes_read += plen
        rows = header["rows"]
        if header["enc"] == "delta":               # decode interleaved
            deltas = np.frombuffer(payload, dtype=np.int32).astype(np.int64)
            vals = header["first"] + np.cumsum(deltas)
            return vals.astype(d.np_dtype())
        if d.name == "bytes":
            return np.frombuffer(payload, dtype=np.uint8).reshape(rows, d.width)
        return np.frombuffer(payload, dtype=d.np_dtype())

    def _read_page_header(self, f, off: int) -> dict:
        """Header only (min/max zone map), payload left unread."""
        f.seek(off)
        (hlen,) = struct.unpack("<I", f.read(4))
        return json.loads(f.read(hlen))

    def read_rowgroup_column(self, rg_index: int, col: str) -> np.ndarray:
        """Decode every page of one column within one row group."""
        d = self.schema[col]
        out = []
        with open(self.path, "rb") as f:
            for off in self.footer["row_groups"][rg_index]["columns"][col]:
                out.append(self._read_page(f, off, d))
        return np.concatenate(out) if out else np.zeros(0, d.np_dtype())

    def read_column(self, col: str) -> np.ndarray:
        """Decode one column across all row groups (full-table read)."""
        d = self.schema[col]
        out = []
        with open(self.path, "rb") as f:
            for rg in self.footer["row_groups"]:
                for off in rg["columns"][col]:
                    out.append(self._read_page(f, off, d))
        return np.concatenate(out) if out else np.zeros(0, d.np_dtype())

    def rowgroup_range(self, rg_index: int,
                       col: str) -> Optional[Tuple[float, float]]:
        """Row-group min/max for ``col`` from its page headers (the paged
        format's zone map), or None for stat-less (bytes) columns."""
        d = self.schema[col]
        if d.name == "bytes":
            return None
        lo, hi = math.inf, -math.inf
        with open(self.path, "rb") as f:
            for off in self.footer["row_groups"][rg_index]["columns"][col]:
                h = self._read_page_header(f, off)
                if h["rows"]:
                    lo, hi = min(lo, h["min"]), max(hi, h["max"])
        if lo > hi:
            return None
        return (lo, hi)


class PagedTableSource(TableSource):
    """TableSource over the paged format: one row group per worker per
    morsel, page-header min/max acting as the zone map for data skipping.

    Exists so the streaming executor can A/B the two formats end-to-end:
    the same prefetch pipeline runs over either backend, and the extra
    metadata interpretation + decode of this format shows up directly in
    ``ScanStats.read_seconds``.
    """

    def __init__(self, root: str, name: str, skip_with_stats: bool = True):
        self.reader = PagedTable(root, name)
        self.name = name
        self.schema = self.reader.schema
        self.skip_with_stats = skip_with_stats
        self.chunks_skipped = 0
        self._range_cache: Dict[Tuple[int, str], object] = {}

    def num_rows(self) -> int:
        return int(self.footer["rows"])

    @property
    def footer(self) -> dict:
        """The file footer (row counts, row-group + schema metadata)."""
        return self.reader.footer

    @property
    def num_chunks(self) -> int:
        return len(self.footer["row_groups"])

    def _get_range(self, rg: int, col: str):
        key = (rg, col)
        if key not in self._range_cache:
            self._range_cache[key] = self.reader.rowgroup_range(rg, col)
        return self._range_cache[key]

    def _rg_survives(self, rg: int, filter_expr) -> bool:
        if not (self.skip_with_stats and filter_expr is not None):
            return True
        return may_match(filter_expr, lambda col: self._get_range(rg, col))

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        cols = list(columns) if columns else list(self.schema.keys())
        w = num_workers
        schema = {c: self.schema[c] for c in cols}
        groups = self.footer["row_groups"]
        live = [g for g in range(len(groups))
                if self._rg_survives(g, filter_expr)]
        skipped = len(groups) - len(live)
        self.chunks_skipped += skipped
        if stats is not None:
            stats.chunks_total += len(groups)
            stats.chunks_skipped += skipped
        if not live:
            yield empty_morsel(schema, w)
            return

        def read(c, g):
            before = self.reader.bytes_read
            arr = self.reader.read_rowgroup_column(g, c)
            if stats is not None:
                stats.bytes_read += self.reader.bytes_read - before
            return arr

        rounds = math.ceil(len(live) / w)
        for r in range(rounds):
            assigned = live[r * w: (r + 1) * w]
            cap = max(int(groups[g]["rows"]) for g in assigned)
            yield stacked_morsel(cols, self.schema, w, assigned, cap, read)
