"""Paged table format — the Parquet-shaped baseline of §2.2.

One file per table with the same *hierarchical metadata* structure that
makes Parquet slow to read at device speed: a file footer, per-row-group
metadata, and per-page headers that must be parsed and interpreted
sequentially, with data and decode interleaved. Values are additionally
delta-encoded per page so the read path has real decode work, like
Parquet's encodings.

This format exists to measure the gap the paper quantifies (their Parquet
read ran 10x below the hardware I/O bound; their minimal format hit 95%).
"""

from __future__ import annotations

import json
import os
import struct
from typing import Dict, Iterator

import numpy as np

from ..core import dtypes as dt

_MAGIC = b"PGD1"
_PAGE_ROWS = 1024


def write_paged_table(root: str, name: str, data: Dict[str, np.ndarray],
                      schema: Dict[str, dt.DType], row_groups: int = 4) -> None:
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"{name}.paged")
    n = len(next(iter(data.values())))
    per_rg = max(1, (n + row_groups - 1) // row_groups)
    rg_meta = []
    with open(path, "wb") as f:
        f.write(_MAGIC)
        for rg in range(row_groups):
            lo, hi = rg * per_rg, min((rg + 1) * per_rg, n)
            col_meta = {}
            for col, d in schema.items():
                arr = np.asarray(data[col][lo:hi], dtype=d.np_dtype())
                pages = []
                for p0 in range(0, max(hi - lo, 1), _PAGE_ROWS):
                    page = arr[p0: p0 + _PAGE_ROWS]
                    if d.name == "bytes":
                        payload = page.tobytes()
                        enc = "plain"
                    elif d.name in ("float32", "float64", "bool"):
                        payload = page.tobytes()
                        enc = "plain"
                    else:
                        # delta encoding: first value + int32 deltas
                        flat = page.astype(np.int64)
                        first = int(flat[0]) if len(flat) else 0
                        deltas = np.diff(flat, prepend=first).astype(np.int32)
                        payload = deltas.tobytes()
                        enc = "delta"
                    header = json.dumps({
                        "rows": int(len(page)), "enc": enc, "col": col,
                        "dtype": d.name, "width": d.width,
                        "first": int(page[0]) if (enc == "delta" and len(page)) else 0,
                        "min": float(page.min()) if (len(page) and d.name != "bytes") else 0,
                        "max": float(page.max()) if (len(page) and d.name != "bytes") else 0,
                    }).encode()
                    off = f.tell()
                    f.write(struct.pack("<I", len(header)))
                    f.write(header)
                    f.write(struct.pack("<I", len(payload)))
                    f.write(payload)
                    pages.append(off)
                col_meta[col] = pages
            rg_meta.append({"rows": hi - lo, "columns": col_meta})
        footer = json.dumps({
            "rows": n,
            "row_groups": rg_meta,
            "schema": {c: {"name": d.name, "width": d.width,
                           "dict": list(d.dictionary) if d.dictionary else None}
                       for c, d in schema.items()},
        }).encode()
        foff = f.tell()
        f.write(footer)
        f.write(struct.pack("<Q", foff))


class PagedTable:
    """Reader that must walk footer -> row group -> page headers, parsing
    and decoding as it goes (the interpretation overhead under study)."""

    def __init__(self, root: str, name: str):
        self.path = os.path.join(root, f"{name}.paged")
        with open(self.path, "rb") as f:
            f.seek(-8, os.SEEK_END)
            (foff,) = struct.unpack("<Q", f.read(8))
            end = f.tell() - 8
            f.seek(foff)
            self.footer = json.loads(f.read(end - foff))
        sch = {}
        for c, meta in self.footer["schema"].items():
            if meta["name"] == "bytes":
                sch[c] = dt.bytes_(meta["width"])
            elif meta["name"] == "dict32":
                sch[c] = dt.DType("dict32", dictionary=tuple(meta["dict"]))
            else:
                sch[c] = dt.DType(meta["name"])
        self.schema = sch
        self.pages_read = 0

    def read_column(self, col: str) -> np.ndarray:
        d = self.schema[col]
        out = []
        with open(self.path, "rb") as f:
            for rg in self.footer["row_groups"]:
                for off in rg["columns"][col]:
                    f.seek(off)
                    (hlen,) = struct.unpack("<I", f.read(4))
                    header = json.loads(f.read(hlen))      # metadata interpret
                    (plen,) = struct.unpack("<I", f.read(4))
                    payload = f.read(plen)
                    self.pages_read += 1
                    rows = header["rows"]
                    if header["enc"] == "delta":           # decode interleaved
                        deltas = np.frombuffer(payload, dtype=np.int32).astype(np.int64)
                        vals = header["first"] + np.cumsum(deltas)
                        out.append(vals.astype(d.np_dtype()))
                    elif d.name == "bytes":
                        out.append(np.frombuffer(payload, dtype=np.uint8)
                                   .reshape(rows, d.width))
                    else:
                        out.append(np.frombuffer(payload, dtype=d.np_dtype()))
        return np.concatenate(out) if out else np.zeros(0, d.np_dtype())
