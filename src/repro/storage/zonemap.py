"""Zone-map data skipping: tri-state predicate evaluation over min/max stats.

Both storage formats keep per-chunk (column-chunk format) or per-row-group
(paged format) min/max ranges. The optimizer pushes predicates into
``TableScan.filter``; the scan asks this module whether a chunk *may*
contain matching rows before reading it — skipped chunks are never read
from storage and never transferred to the device.

Evaluation is conservative: ``eval_range`` returns True (every row matches),
False (no row can match — safe to skip), or None (unknown). Only a provable
False skips data, so skipping on/off always produces identical results.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

from ..core.expr import BinaryOp, ColumnRef, Expr, Literal

# get_range(column) -> (min, max) of the zone, or None when unavailable
RangeLookup = Callable[[str], Optional[Tuple[float, float]]]


def eval_range(e: Expr, get_range: RangeLookup) -> Optional[bool]:
    """Tri-state (True/False/None=unknown) evaluation of a predicate against
    a zone's min/max ranges. Unknown expression shapes return None."""
    if isinstance(e, BinaryOp):
        if e.op == "and":
            l, r = eval_range(e.lhs, get_range), eval_range(e.rhs, get_range)
            if l is False or r is False:
                return False
            return True if (l is True and r is True) else None
        if e.op == "or":
            l, r = eval_range(e.lhs, get_range), eval_range(e.rhs, get_range)
            if l is True or r is True:
                return True
            return False if (l is False and r is False) else None
        lhs, rhs, op = e.lhs, e.rhs, e.op
        if isinstance(lhs, Literal) and isinstance(rhs, ColumnRef):
            # normalize "lit OP col" to "col FLIP(OP) lit"
            flip = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le", "eq": "eq"}
            if op not in flip:
                return None
            lhs, rhs, op = rhs, lhs, flip[op]
        if isinstance(lhs, ColumnRef) and isinstance(rhs, Literal):
            rng = get_range(lhs.name)
            if rng is None:
                return None
            lo, hi = rng
            try:
                v = float(rhs.value)
            except (TypeError, ValueError):
                return None
            if op == "lt":
                return True if hi < v else (False if lo >= v else None)
            if op == "le":
                return True if hi <= v else (False if lo > v else None)
            if op == "gt":
                return True if lo > v else (False if hi <= v else None)
            if op == "ge":
                return True if lo >= v else (False if hi < v else None)
            if op == "eq":
                return False if (v < lo or v > hi) else None
    return None


def may_match(e: Optional[Expr], get_range: RangeLookup) -> bool:
    """False only when the zone provably contains no matching row."""
    if e is None:
        return True
    return eval_range(e, get_range) is not False
