"""Weighted HLO cost model: trip-count-aware FLOPs / bytes / collectives.

XLA's compiled.cost_analysis() counts while-loop bodies ONCE (verified in
tests/test_roofline.py), so for scan-over-layers models it understates
FLOPs and collective bytes by ~n_layers x. This parser rebuilds the cost
from the post-SPMD HLO text with loop weighting:

* call graph: ENTRY -> fusion/call/conditional (x1), while (x trip count,
  recovered from the loop condition's comparison constant),
* FLOPs: dot ops = 2 * prod(result dims) * prod(contracting dims),
* bytes: per surface op, result bytes + operand bytes (fusion internals
  excluded — a fusion moves only its operands/result through HBM, which is
  exactly the TPU memory-traffic model),
* collectives: result-shape bytes per op kind.

All quantities are whole-program; divide by chip count for per-chip terms.
"""

from __future__ import annotations

import re
from typing import Dict, List, NamedTuple, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\][^ ]*)\s+)?"
                        r"([a-z0-9\-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


class OpInfo(NamedTuple):
    name: str
    kind: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    line: str


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Module:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self.ops: Dict[str, OpInfo] = {}        # op name -> info (module-wide)
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        hdr = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
        for raw in text.splitlines():
            s = raw.strip()
            m = hdr.match(s)
            if m:
                name = m.group(2)
                if not name.startswith("%"):
                    name = "%" + name
                cur = name
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if s == "}":
                cur = None
                continue
            if cur is None:
                continue
            self.computations[cur].append(s)
            dm = _DEF_RE.match(s)
            if dm:
                name, rhs = dm.group(1), dm.group(2)
                om = _OPNAME_RE.match(rhs)
                kind = om.group(2) if om else "unknown"
                # result shapes: everything before the op name token
                head = rhs.split(kind + "(", 1)[0] if kind + "(" in rhs else rhs
                self.ops[name] = OpInfo(name, kind, _parse_shapes(head), s)

    # -- per-computation direct costs ---------------------------------------
    _CALL_RE = re.compile(r"\b[a-z][a-z0-9\-]*\(([^()]*)\)")

    def _operands(self, line: str) -> List[str]:
        # operand names: inside the op's call parens (first `kind(...)`)
        m = self._CALL_RE.search(line)
        if not m:
            return []
        return _OPERAND_RE.findall(m.group(1))

    def _dot_flops(self, line: str) -> int:
        # result shape
        dm = _DEF_RE.match(line)
        rhs = dm.group(2)
        head = rhs.split("dot(", 1)[0]
        res = _parse_shapes(head)
        res_elems = 1
        for _, dims in res:
            for d in dims:
                res_elems *= d
        # contracting dims of the lhs operand
        ops = self._operands(line)
        cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", line)
        if not ops or not cm or ops[0] not in self.ops:
            return 0
        lhs_shapes = self.ops[ops[0]].result_shapes
        if not lhs_shapes:
            return 0
        lhs_dims = lhs_shapes[0][1]
        contract = 1
        for idx in cm.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
        return 2 * res_elems * contract

    def direct_costs(self, comp: str):
        flops = 0
        bytes_ = 0
        coll = {k: 0 for k in _COLLECTIVES}
        children: List[Tuple[str, float]] = []
        for line in self.computations.get(comp, []):
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            info = self.ops.get(name)
            if info is None:
                continue
            kind = info.kind
            if kind in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "iota", "after-all"):
                continue
            res_bytes = _shape_bytes(info.result_shapes)
            operand_sizes = [_shape_bytes(self.ops[o].result_shapes)
                             for o in self._operands(line) if o in self.ops]
            if "dynamic-update-slice" in name or kind == "dynamic-update-slice":
                # in-place buffer update: traffic = the update slice (read +
                # write) + small operands, NOT the whole carry buffer
                big = max(operand_sizes, default=0)
                op_bytes = 2 * (sum(operand_sizes) - big)
            elif "dynamic-slice" in name or kind == "dynamic-slice":
                # slice read from a resident buffer: only the slice moves
                op_bytes = 2 * res_bytes
            else:
                op_bytes = res_bytes + sum(operand_sizes)
            bytes_ += op_bytes
            if kind == "dot":
                flops += self._dot_flops(line)
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in _COLLECTIVES and not kind.endswith("-done"):
                coll[base] += res_bytes
            if kind == "while":
                cm = re.search(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)", line)
                if cm:
                    trips = self.trip_count(cm.group(1))
                    children.append((cm.group(2), trips))
                    children.append((cm.group(1), trips))
            elif kind == "fusion":
                fm = re.search(r"calls=(%[\w.\-]+)", line)
                if fm:
                    # fusion internals: dots count (flops), bytes do not
                    children.append((fm.group(1), 1.0))
            elif kind in ("call", "custom-call"):
                fm = re.search(r"to_apply=(%[\w.\-]+)", line)
                if fm:
                    children.append((fm.group(1), 1.0))
            elif kind == "conditional":
                for b in re.findall(r"(?:branch_computations=|true_computation="
                                    r"|false_computation=){?(%[\w.\-]+)", line):
                    children.append((b, 1.0))
        return flops, bytes_, coll, children

    def trip_count(self, cond_comp: str) -> float:
        """Largest s32 scalar constant in the loop condition (scan bound)."""
        best = 1
        for line in self.computations.get(cond_comp, []):
            for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return float(best)

    # -- weighted totals -----------------------------------------------------
    def weighted_costs(self, comp: Optional[str] = None, weight: float = 1.0,
                       _memo=None, in_fusion: bool = False):
        comp = comp or self.entry
        flops, bytes_, coll, children = self.direct_costs(comp)
        if in_fusion:
            bytes_ = 0
            coll = {k: 0 for k in coll}
        total_f = flops * weight
        total_b = bytes_ * weight
        total_c = {k: v * weight for k, v in coll.items()}
        for child, mult in children:
            child_in_fusion = in_fusion or (
                self.ops and "fused" in child)
            f, b, c = self.weighted_costs(child, weight * mult,
                                          in_fusion=child_in_fusion)
            total_f += f
            total_b += b
            for k in total_c:
                total_c[k] += c[k]
        return total_f, total_b, total_c


def analyze(hlo_text: str):
    """-> dict(flops, bytes, collectives{kind: bytes}, collective_total)."""
    mod = Module(hlo_text)
    f, b, c = mod.weighted_costs()
    return {"flops": f, "bytes": b, "collectives": c,
            "collective_total": sum(c.values())}
