"""Generate EXPERIMENTS.md from results/dryrun/*.json + results/perf/*.json.

    PYTHONPATH=src python -m repro.launch.report > EXPERIMENTS.md
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

HERE = os.path.dirname(__file__)
DRYRUN = os.path.join(HERE, "..", "..", "..", "results", "dryrun")
PERF = os.path.join(HERE, "..", "..", "..", "results", "perf")

ARCH_ORDER = ["seamless_m4t_large_v2", "qwen2_1_5b", "phi4_mini_3_8b",
              "granite_3_8b", "granite_34b", "pixtral_12b", "dbrx_132b",
              "deepseek_moe_16b", "xlstm_125m", "jamba_v0_1_52b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

MOVE_HINT = {
    "compute_s": "more MXU-efficient tiling / fewer redundant flops "
                 "(remat recompute, attention masking)",
    "memory_s": "fusing the residual/activation chain (remat, kernel "
                "fusion) to cut HBM round trips",
    "collective_s": "reducing gathered/exchanged volume (compaction, "
                    "ZeRO stage, explicit a2a instead of padded relayout)",
}


def _load(d: str) -> List[dict]:
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x:.2e}"
    return f"{x:.4f}"


def _sig(x: float) -> str:
    return f"{x:.3g}"


def dryrun_section(cells: List[dict]) -> str:
    rows = ["### Compile/fit summary (every cell, both meshes)", "",
            "| arch | shape | mesh | chips | compile_s | params/chip | "
            "state/chip (train) | collective kinds present |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                rec = next((c for c in cells if c["arch"] == arch
                            and c["shape"] == shape and c["mesh"] == mesh),
                           None)
                if rec is None:
                    continue
                if "error" in rec:
                    rows.append(f"| {arch} | {shape} | {mesh} | - | FAILED: "
                                f"{rec['error'][:60]} | | | |")
                    continue
                kinds = ",".join(k.replace("all-", "a").replace(
                    "reduce-scatter", "rs").replace("collective-permute", "cp")
                    for k, v in rec["collective_bytes"].items() if v)
                rows.append(
                    f"| {arch} | {shape} | {mesh} | {rec['chips']} | "
                    f"{rec['compile_seconds']} | "
                    f"{rec['param_bytes_per_chip'] / 1e9:.2f} GB | "
                    f"{rec['state_bytes_per_chip'] / 1e9:.2f} GB | "
                    f"{kinds or '-'} |")
    return "\n".join(rows)


def roofline_section(cells: List[dict]) -> str:
    rows = ["### Roofline terms (single-pod 16x16, 256 chips; seconds/step)",
            "",
            "| arch | shape | compute | memory | collective | dominant | "
            "MODEL_FLOPS | useful/HLO | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = next((c for c in cells if c["arch"] == arch
                        and c["shape"] == shape and c["mesh"] == "16x16"
                        and "error" not in c), None)
            if rec is None:
                continue
            t = rec["roofline"]
            ratio = rec.get("useful_flops_ratio")
            rows.append(
                f"| {arch} | {shape} | {_fmt_s(t['compute_s'])} | "
                f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
                f"{rec['dominant'][:-2]} | {_sig(rec['model_flops'])} | "
                f"{ratio:.3f} | {MOVE_HINT[rec['dominant']]} |")
    return "\n".join(rows)


def perf_section(cells: List[dict]) -> str:
    rows = ["| cell | variant | compute | memory | collective | "
            "collective bytes | HLO flops |",
            "|---|---|---|---|---|---|---|"]
    order = ["recurrent", "chunkwise", "zero3", "zero1", "zero3_remat",
             "gspmd_cap1.25", "gspmd_cap1.0", "explicit_a2a"]
    cells = sorted(cells, key=lambda c: (c["arch"],
                                         order.index(c["variant"])
                                         if c["variant"] in order else 99))
    for rec in cells:
        t = rec["roofline"]
        rows.append(
            f"| {rec['arch']}/{rec['shape']} | {rec['variant']} | "
            f"{_fmt_s(t['compute_s'])} | {_fmt_s(t['memory_s'])} | "
            f"{_fmt_s(t['collective_s'])} | "
            f"{_sig(rec['collective_bytes_total'])} | "
            f"{_sig(rec['hlo_flops'])} |")
    return "\n".join(rows)


def tables() -> Dict[str, str]:
    dr = _load(DRYRUN)
    pf = _load(PERF)
    return {"dryrun": dryrun_section(dr), "roofline": roofline_section(dr),
            "perf": perf_section(pf)}


def splice_experiments_md():
    """Replace the <!-- *_TABLE --> placeholders in EXPERIMENTS.md with the
    generated tables (idempotent: regenerates between marker lines)."""
    path = os.path.join(HERE, "..", "..", "..", "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    t = tables()
    for marker, content in (("DRYRUN_TABLE", t["dryrun"]),
                            ("ROOFLINE_TABLE", t["roofline"]),
                            ("PERF_TABLE", t["perf"])):
        begin = f"<!-- {marker} -->"
        end = f"<!-- /{marker} -->"
        block = f"{begin}\n{content}\n{end}"
        if end in text:   # regenerate existing block
            pre = text.split(begin)[0]
            post = text.split(end, 1)[1]
            text = pre + block + post
        else:
            text = text.replace(begin, block)
    with open(path, "w") as f:
        f.write(text)
    print(f"spliced tables into {os.path.abspath(path)}")


if __name__ == "__main__":
    import sys
    if "--write" in sys.argv:
        splice_experiments_md()
    else:
        t = tables()
        for k, v in t.items():
            print(f"\n<!-- {k} -->\n{v}\n")
