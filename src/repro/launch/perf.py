import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: lowers the three selected cells under each
variant and records the roofline terms to results/perf/<cell>__<variant>.json.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  A. xlstm_125m  train_4k   — worst roofline fraction (memory-bound by the
     recurrent state round trip).  variants: recurrent | chunkwise
  B. granite_34b train_4k   — most collective-bound (FSDP weight gathers).
     variants: zero3 | zero1
  C. dbrx_132b   train_4k   — most representative of the paper's exchange
     (MoE EP dispatch). variants: gspmd_cap1.25 | gspmd_cap1.0 | explicit_a2a
"""

import argparse  # noqa: E402  (XLA_FLAGS must be set before jax imports)
import json  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "perf")


def run_variant(cell: str, variant: str):
    # configure globals BEFORE lowering
    from ..models import moe, xlstm
    from . import dryrun

    from ..models import transformer

    arch, shape = cell.split("/")
    zero_stage = 3
    transformer.REMAT_BLOCKS = False        # baseline: no remat
    if variant == "recurrent":
        xlstm.MLSTM_MODE = "recurrent"
    elif variant == "chunkwise":
        xlstm.MLSTM_MODE = "chunkwise"
    elif variant == "zero3":
        zero_stage = 3
    elif variant == "zero1":
        zero_stage = 1
    elif variant == "zero3_remat":
        zero_stage = 3
        transformer.REMAT_BLOCKS = True
    elif variant.startswith("gspmd_cap"):
        moe.MOE_DISPATCH = "gspmd"
        moe.CAPACITY_FACTOR = float(variant.replace("gspmd_cap", ""))
    elif variant == "explicit_a2a":
        moe.MOE_DISPATCH = "a2a"
        moe.CAPACITY_FACTOR = 1.0
    else:
        raise ValueError(variant)

    rec = dryrun.lower_cell(arch, shape, multi_pod=False,
                            zero_stage=zero_stage)
    rec["variant"] = variant
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["roofline"]
    print(f"[{arch} {shape} {variant}] dom={rec['dominant'][:-2]} "
          f"compute={t['compute_s']:.4g}s memory={t['memory_s']:.4g}s "
          f"collective={t['collective_s']:.4g}s "
          f"flops={rec['hlo_flops']:.3g} collB={rec['collective_bytes_total']:.3g}",
          flush=True)
    # restore production defaults
    xlstm.MLSTM_MODE = "chunkwise"
    moe.MOE_DISPATCH = "a2a"
    moe.CAPACITY_FACTOR = 1.25
    transformer.REMAT_BLOCKS = True
    return rec


CELLS = {
    "A": ("xlstm_125m/train_4k", ["recurrent", "chunkwise"]),
    "B": ("granite_34b/train_4k", ["zero3", "zero1", "zero3_remat"]),
    "C": ("dbrx_132b/train_4k",
          ["gspmd_cap1.25", "gspmd_cap1.0", "explicit_a2a"]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C"])
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    for key, (cell, variants) in CELLS.items():
        if args.cell and key != args.cell:
            continue
        for v in variants:
            if args.variant and v != args.variant:
                continue
            path = os.path.join(RESULTS_DIR,
                                f"{cell.replace('/', '__')}__{v}.json")
            if os.path.exists(path):
                print(f"[cached] {cell} {v}")
                continue
            run_variant(cell, v)


if __name__ == "__main__":
    main()
