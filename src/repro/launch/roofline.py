"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs      / (chips * 197e12)      TPU v5e bf16 peak
    memory     = HLO_bytes      / (chips * 819e9)       HBM bandwidth
    collective = collective_B   / (chips * 50e9)        ICI per-link

cost_analysis() provides FLOPs/bytes; collective bytes are parsed from the
post-SPMD HLO text (result-shape bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops). The post-SPMD module
is the per-device program, so parsed quantities are already per-chip and
``roofline_terms`` is called with chips=1; MODEL_FLOPS comparisons divide
the analytic global count by the chip count.

(The old ``launch/perf.py`` hillclimb driver — a training-model variant
sweep predating this repo's query-engine direction — was retired; its
salvageable core, recording roofline terms against measured wall time for
one compiled program, lives on as ``measure_program`` below, which
``benchmarks/bench_kernels.py`` uses for per-kernel roofline fractions.)
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result type of a collective op, e.g.:  %x = bf16[8,128]{1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the whole program."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if line.lstrip().startswith("%") or " = " in line:
            lhs = line.split("=", 1)[0]
            rhs = line.split("=", 1)[1]
            # result may be a tuple (async pairs); sum every shape before
            # the op name
            head = rhs.split(kind)[0]
            total = sum(_shape_bytes(dt, dims)
                        for dt, dims in _TUPLE_RE.findall(head))
            out[kind] += total
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: int, chips: int) -> Dict[str, float]:
    return {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": bytes_accessed / (chips * HBM_BW),
        "collective_s": coll_bytes / (chips * ICI_BW),
    }


def dominant(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def measure_program(fn, *args, warmup: int = 1, iters: int = 3,
                    chips: int = 1) -> Dict[str, float]:
    """Roofline-vs-measured report for one jittable program at one shape.

    Lowers and compiles ``fn(*args)``, takes FLOPs / bytes-accessed from
    the compiled cost analysis (a one-element list on some jax versions)
    and collective bytes from the post-SPMD HLO text, and compares the
    roofline time bound — the max of the ``roofline_terms`` — to the
    measured per-call wall time. ``achieved_fraction`` is bound/measured:
    ~1.0 means the program runs at the hardware ceiling for its dominant
    term; off-TPU (interpret-mode kernels) the fraction is tiny and only
    the relative ordering across kernels is meaningful.
    """
    import time

    import jax

    jfn = jax.jit(fn)
    compiled = jfn.lower(*args).compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = sum(collective_bytes(compiled.as_text()).values())
    terms = roofline_terms(flops, bytes_accessed, coll, chips=chips)
    bound_s = max(terms.values())
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jfn(*args))
    measured_s = (time.perf_counter() - t0) / iters
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collective_bytes": coll,
        "roofline_bound_s": bound_s,
        "measured_s": measured_s,
        "dominant": dominant(terms),
        "achieved_fraction": bound_s / measured_s if measured_s else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D prefill,
    2*N_active*B decode (one token per sequence)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch
