import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no GSPMD errors, supported collectives),
  * the program fits (memory_analysis), and
  * yields cost_analysis + collective bytes for the roofline (§Roofline).

Results are cached per cell in results/dryrun/<cell>.json so the sweep is
resumable; `python -m repro.launch.dryrun --arch qwen2_1_5b --shape train_4k`
runs one cell, no flags runs everything outstanding.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, SHAPES, applicable_shapes, get_config
from ..models.model import build_model
from ..models import sharding as shp
from ..train.train_step import make_train_step, train_state_init
from . import roofline as rf
from .mesh import axes_of, make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cell_path(arch, shape, mesh_name):
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               zero_stage: int = 3):
    """Lower + compile one cell; returns the roofline record."""
    import dataclasses as _dc

    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = _dc.replace(axes_of(mesh), zero_stage=zero_stage)
    chips = mesh.devices.size

    with mesh, shp.use_axes(axes, mesh):
        state_struct = jax.eval_shape(
            lambda: train_state_init(model, jax.random.key(0)))
        param_struct = state_struct.params
        p_shard = shp.params_shardings(param_struct, axes, mesh)
        in_specs = model.input_specs(shape)
        b_shard = shp.batch_shardings(in_specs, axes, mesh)

        if shape.kind == "train":
            step = make_train_step(model)
            s_shard = shp.params_shardings(state_struct, axes, mesh)
            lowered = jax.jit(step, in_shardings=(s_shard, b_shard)) \
                .lower(state_struct, in_specs)
        elif shape.kind == "prefill":
            def prefill(params, batch):
                return model.prefill(params, batch)
            lowered = jax.jit(prefill, in_shardings=(p_shard, b_shard)) \
                .lower(param_struct, in_specs)
        else:  # decode
            cache_struct = model.cache_specs(shape)
            c_shard = shp.cache_shardings(cache_struct, shape.seq_len, axes,
                                          mesh)
            pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
            from jax.sharding import NamedSharding, PartitionSpec
            pos_shard = NamedSharding(mesh, PartitionSpec())

            def decode(params, tokens, caches, pos):
                return model.decode_step(params, tokens, caches, pos)

            lowered = jax.jit(
                decode,
                in_shardings=(p_shard, b_shard["tokens"], c_shard, pos_shard)
            ).lower(param_struct, in_specs["tokens"], cache_struct, pos_struct)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    # older jaxlibs return a one-element list of per-module dicts
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001 — backend support varies
        mem_rec = {"error": str(e)}

    # trip-count-weighted reparse of the post-SPMD HLO: XLA's cost_analysis
    # counts while (scan) bodies once, so scan-over-layers models would be
    # understated by ~n_layers x (see launch/hloparse.py + test_roofline.py).
    # NOTE: the post-SPMD module is the PER-DEVICE program, so parsed
    # quantities are already per-chip (verified in test_roofline.py) —
    # roofline terms divide by the single-chip peak only.
    hlo = compiled.as_text()
    from . import hloparse
    parsed = hloparse.analyze(hlo)
    flops = max(flops, parsed["flops"])
    bytes_accessed = max(bytes_accessed, parsed["bytes"])
    coll = {k: int(v) for k, v in parsed["collectives"].items()}
    coll_total = int(parsed["collective_total"])
    terms = rf.roofline_terms(flops, bytes_accessed, coll_total, chips=1)
    mf = rf.model_flops(cfg, shape)

    # per-device parameter residency (proves the FSDP+TP layout fits)
    param_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(
                          jax.eval_shape(lambda: build_model(cfg)
                                         .init(jax.random.key(0)))))
    opt_bytes = 2 * sum(x.size * 4 for x in jax.tree.leaves(
        jax.eval_shape(lambda: build_model(cfg).init(jax.random.key(0)))))

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips),
        "kind": shape.kind,
        "compile_seconds": round(compile_s, 1),
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_bytes_total": coll_total,
        "roofline": terms,
        "dominant": rf.dominant(terms),
        "model_flops": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_ratio": (mf / chips / flops) if flops else None,
        "memory_analysis": mem_rec,
        "param_bytes_global": int(param_bytes),
        "param_bytes_per_chip": int(param_bytes / chips),
        "state_bytes_per_chip": int((param_bytes + (opt_bytes if
                                     shape.kind == "train" else 0)) / chips),
    }


def run_cell(arch, shape_name, mesh_name, force=False):
    path = _cell_path(arch, shape_name, mesh_name)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f), True
    os.makedirs(RESULTS_DIR, exist_ok=True)
    try:
        rec = lower_cell(arch, shape_name, mesh_name == "2x16x16")
    except Exception as e:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec, False


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            for mesh_name in ("16x16", "2x16x16"):
                yield arch, shape_name, mesh_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    failures = 0
    for arch, shape_name, mesh_name in all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape_name != args.shape:
            continue
        if args.mesh and mesh_name != args.mesh:
            continue
        t0 = time.time()
        rec, cached = run_cell(arch, shape_name, mesh_name, args.force)
        status = "cached" if cached else f"{time.time()-t0:.0f}s"
        if "error" in rec:
            failures += 1
            print(f"[FAIL {status}] {arch} {shape_name} {mesh_name}: "
                  f"{rec['error'][:200]}", flush=True)
        else:
            t = rec["roofline"]
            print(f"[ok {status}] {arch} {shape_name} {mesh_name} "
                  f"dom={rec['dominant'][:-2]} "
                  f"c={t['compute_s']:.3g} m={t['memory_s']:.3g} "
                  f"x={t['collective_s']:.3g}", flush=True)
    print(f"done, failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
