"""Production meshes (defined as functions so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS first)."""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, (
        f"need {n} devices, have {len(devices)} — the dry-run entry point "
        "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "before any jax import")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_engine_mesh(num_workers: int):
    """1-D worker mesh for the query engine (one worker per device)."""
    import jax

    from jax.sharding import Mesh
    devices = jax.devices()
    assert len(devices) >= num_workers
    return Mesh(np.asarray(devices[:num_workers]), ("workers",))


def axes_of(mesh):
    """Sharding-policy Axes from a production mesh."""
    from ..models.sharding import Axes

    names = mesh.axis_names
    if "pod" in names:
        dp = ("pod", "data")
    else:
        dp = ("data",)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    return Axes(dp=dp, tp="model", dp_size=dp_size,
                tp_size=int(mesh.shape["model"]))
