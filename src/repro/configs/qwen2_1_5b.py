"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; QKV bias, tied embeddings [arXiv:2407.10671; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_1_5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960,
    vocab=151_936, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2_1_5b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)
