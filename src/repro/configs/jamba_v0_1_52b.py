"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba+attention 1:7 interleave, MoE 16 experts top-2 on every
second layer [arXiv:2403.19887; hf]. Hybrid -> long_500k runs (attention
only on 4 of 32 layers; the sharded KV cache fits)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba_v0_1_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14_336,
    vocab=65_536, n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=3, block_period=8,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)

SMOKE = ArchConfig(
    name="jamba_v0_1_52b_smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv=2, d_ff=96,
    vocab=512, n_experts=4, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=3, block_period=8,
    mamba_d_state=4, mamba_d_conv=4, mamba_expand=2,
)
