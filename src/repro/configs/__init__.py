"""Architecture configs for the assigned pool (one module per arch)."""

from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeSpec,  # noqa: F401
                   applicable_shapes, get_config)
