"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite_3_8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12_800,
    vocab=49_155,
)

SMOKE = ArchConfig(
    name="granite_3_8b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=160,
    vocab=512,
)
