"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; llama-arch code model [arXiv:2405.04324; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite_34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv=1, d_ff=24_576,
    vocab=49_152, mlp_gelu=True,    # gpt-bigcode-style 2-matrix MLP
)

SMOKE = ArchConfig(
    name="granite_34b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=1, d_ff=256,
    vocab=512, mlp_gelu=True,
)
