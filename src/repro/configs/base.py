"""Architecture configs and input shapes for the assigned pool.

Each assigned architecture gets a module in repro/configs/<id>.py exporting
``CONFIG`` (full published size) and ``SMOKE`` (reduced same-family config
for CPU smoke tests). Shapes follow the assignment:

    train_4k     seq 4096,    global_batch 256   (train_step)
    prefill_32k  seq 32768,   global_batch 32    (prefill)
    decode_32k   KV 32768,    global_batch 128   (decode_step)
    long_500k    KV 524288,   global_batch 1     (decode_step; sub-quadratic
                                                  archs only)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

ARCH_IDS = (
    "seamless_m4t_large_v2",
    "qwen2_1_5b",
    "phi4_mini_3_8b",
    "granite_3_8b",
    "granite_34b",
    "pixtral_12b",
    "dbrx_132b",
    "deepseek_moe_16b",
    "xlstm_125m",
    "jamba_v0_1_52b",
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    # attention details
    d_head: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                # qwen2
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    mlp_gelu: bool = False                # 2-matrix GeLU MLP (granite-34b)
                                          # instead of 3-matrix SwiGLU

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1                    # MoE on layers where i % moe_every
                                          # == moe_offset (jamba: every 2nd)
    moe_offset: int = 0

    # hybrid (jamba): attention on layers where i % attn_every == attn_offset,
    # Mamba elsewhere. attn_every=1 -> pure attention stack.
    attn_every: int = 1
    attn_offset: int = 0

    # mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xlstm: sLSTM on layers where i % slstm_every == slstm_offset
    slstm_every: int = 0                  # 0 -> no sLSTM layers
    slstm_offset: int = 3

    # enc-dec
    n_enc_layers: int = 0                 # 0 -> decoder-only

    # layer grouping for scan-over-layers (must divide n_layers and be a
    # multiple of every block pattern period)
    block_period: int = 1

    # modality frontend stub: inputs are precomputed embeddings, not tokens
    embed_frontend_stub: bool = False

    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can serve a 500k context (SSM / hybrid with sparse attention)."""
        return self.family in ("ssm", "hybrid")

    def is_attn_layer(self, i: int) -> bool:
        return i % self.attn_every == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i % self.moe_every == self.moe_offset

    def is_slstm_layer(self, i: int) -> bool:
        return self.slstm_every > 0 and i % self.slstm_every == self.slstm_offset

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            if self.family == "ssm" and not self.is_slstm_layer(i):
                di = self.mamba_expand * d   # mLSTM-ish projections
                total += d * di * 4 + di * d
            elif self.family == "ssm":
                total += d * d * 4
            elif self.family == "hybrid" and not self.is_attn_layer(i):
                di = self.mamba_expand * d
                total += 2 * d * di + di * (2 * self.mamba_d_state + 2) + di * d
            else:
                total += d * (self.n_heads * dh) * 2          # q, o
                total += d * (self.n_kv * dh) * 2             # k, v
            # ffn / moe
            ffn_mats = 2 if self.mlp_gelu else 3
            if self.is_moe_layer(i):
                e = self.n_experts + self.n_shared_experts
                total += e * ffn_mats * d * self.d_ff + d * self.n_experts
            elif self.d_ff > 0 and not (self.family == "ssm"):
                total += ffn_mats * d * self.d_ff
        if self.n_enc_layers:
            total += self.n_enc_layers * (4 * d * d + 3 * d * self.d_ff)
            total += self.n_layers * 4 * d * d                # cross attention
        return total

    def active_param_count(self) -> int:
        """MoE: only routed-active experts count toward step FLOPs."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = 0
        for i in range(self.n_layers):
            if self.is_moe_layer(i):
                inactive += (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE if smoke else mod.CONFIG


def applicable_shapes(cfg: ArchConfig) -> Tuple[str, ...]:
    """long_500k is skipped for pure full-attention archs (DESIGN.md
    §Arch-applicability); every other cell runs."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return tuple(shapes)
