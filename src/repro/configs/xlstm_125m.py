"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks [arXiv:2405.04517]. mLSTM everywhere except every 4th block (sLSTM),
matching the paper's mostly-mLSTM ratios. Recurrent state -> long_500k runs.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0,
    vocab=50_304, slstm_every=4, slstm_offset=3, mamba_expand=2,
    block_period=4, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="xlstm_125m_smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv=2, d_ff=0,
    vocab=512, slstm_every=4, slstm_offset=3, mamba_expand=2,
    block_period=4, tie_embeddings=True,
)
