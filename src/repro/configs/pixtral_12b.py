"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409]. Backbone only per the assignment: the
ViT patch embedder is a STUB (precomputed patch embeddings)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral_12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14_336,
    vocab=131_072, d_head=160, rope_theta=1e6, embed_frontend_stub=True,
)

SMOKE = ArchConfig(
    name="pixtral_12b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160,
    vocab=512, d_head=20, rope_theta=1e6, embed_frontend_stub=True,
)
