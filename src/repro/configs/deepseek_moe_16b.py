"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400; 2 shared + 64 routed experts, top-6, fine-grained
[arXiv:2401.06066; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_moe_16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
    vocab=102_400, n_experts=64, top_k=6, n_shared_experts=2,
)

SMOKE = ArchConfig(
    name="deepseek_moe_16b_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=48,
    vocab=512, n_experts=8, top_k=3, n_shared_experts=2,
)
