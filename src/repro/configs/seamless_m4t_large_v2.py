"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.

24 encoder + 24 decoder layers, d_model 1024, 16H (kv=16), d_ff 8192,
vocab 256206 [arXiv:2308.11596; hf]. The speech frontend is a STUB per the
assignment: input_specs provides precomputed frame embeddings.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless_m4t_large_v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
    vocab=256_206, n_enc_layers=24, embed_frontend_stub=True,
)

SMOKE = ArchConfig(
    name="seamless_m4t_large_v2_smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
    vocab=512, n_enc_layers=2, embed_frontend_stub=True,
)
