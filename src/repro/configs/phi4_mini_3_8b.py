"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064; RoPE + SwiGLU + GQA [arXiv:2412.08905; hf]."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi4_mini_3_8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
    vocab=200_064, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="phi4_mini_3_8b_smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=192,
    vocab=512, tie_embeddings=True,
)
