"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth the kernels are validated
against in tests/test_kernels.py (interpret mode, shape/dtype sweeps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, causal: bool = True):
    """q,k,v: [B, H, S, D] -> [B, H, S, D] full-softmax attention."""
    s = q.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    scores = scores * (q.shape[-1] ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)


def segmented_agg(gids, values, num_groups: int, kind: str = "sum"):
    """gids [N] int32 (>= num_groups means dropped), values [N] f32."""
    valid = gids < num_groups
    seg = jnp.where(valid, gids, num_groups)
    if kind == "sum":
        vals = jnp.where(valid, values, 0.0)
        return jax.ops.segment_sum(vals, seg, num_groups + 1)[:num_groups]
    if kind == "count":
        return jax.ops.segment_sum(valid.astype(jnp.float32), seg,
                                   num_groups + 1)[:num_groups]
    raise ValueError(kind)


def radix_histogram(pids, num_partitions: int):
    """pids [N] int32 -> counts [num_partitions] int32 (the exchange's
    metadata phase)."""
    onehot = jax.nn.one_hot(pids, num_partitions, dtype=jnp.int32)
    return jnp.sum(onehot, axis=0)


def hash_probe(table_keys, table_vals, probe_keys, empty_key: int):
    """Open-addressing (linear probe) lookup.

    table_keys [T] int32 (power-of-two T, empty slots = empty_key),
    probe_keys [N] -> (found [N] bool, vals [N] int32)."""
    t = table_keys.shape[0]
    mask = t - 1

    def lookup(key):
        h = _hash(key) & mask

        def body(i, carry):
            found, val, done = carry
            idx = (h + i) & mask
            slot = table_keys[idx]
            hit = (slot == key) & (~done)
            miss = (slot == empty_key) & (~done)
            return (found | hit,
                    jnp.where(hit, table_vals[idx], val),
                    done | hit | miss)

        found, val, _ = jax.lax.fori_loop(
            0, t, body, (jnp.bool_(False), jnp.int32(0), jnp.bool_(False)))
        return found, val

    return jax.vmap(lookup)(probe_keys)


def _hash(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    return x.astype(jnp.int32)


def block_prefix_sum(mask):
    """mask [N] bool/int -> (exclusive positions [N] int32, total int32):
    the stream-compaction address computation."""
    m = mask.astype(jnp.int32)
    inclusive = jnp.cumsum(m)
    return inclusive - m, inclusive[-1] if m.size else jnp.int32(0)
