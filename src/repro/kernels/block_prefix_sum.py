"""Stream-compaction address computation (blocked prefix sum) in Pallas.

cuDF's apply_boolean_mask uses a decoupled-lookback scan on GPU; the TPU
adaptation computes within-block exclusive positions with a triangular
matmul (the MXU does the prefix sum) and carries the running block total
through the sequential grid (TPU grids execute in order, so a scalar carry
in the output ref is race-free) — a two-level scan with no atomics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 1024


def _kernel(mask_ref, pos_ref, total_ref, *, row_block: int):
    m = mask_ref[...].astype(jnp.float32)           # [R]
    rows = m.shape[0]
    # strictly-lower-triangular ones: exclusive prefix via MXU
    tri = (jax.lax.broadcasted_iota(jnp.int32, (rows, rows), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (rows, rows), 1)) \
        .astype(jnp.float32)
    excl = (tri @ m[:, None])[:, 0]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        total_ref[...] = jnp.zeros_like(total_ref)

    base = total_ref[0]
    pos_ref[...] = (excl + base.astype(jnp.float32)).astype(jnp.int32)
    total_ref[...] = (base + jnp.sum(m).astype(jnp.int32))[None]


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def block_prefix_sum(mask, row_block: int = ROW_BLOCK,
                     interpret: bool = False):
    """mask [N] -> (exclusive positions [N] int32, total int32)."""
    n = mask.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.int32(0)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    m = jnp.pad(mask.astype(jnp.int32), (0, pad))
    pos, total = pl.pallas_call(
        functools.partial(_kernel, row_block=row_block),
        grid=(m.shape[0] // row_block,),
        in_specs=[pl.BlockSpec((row_block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((row_block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((m.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(m)
    return pos[:n], total[0]
