"""Pallas kernels for the engine's hot relational primitives.

The cuDF-operator analogue layer (paper §3): each kernel accelerates one
physical primitive of the query engine and ships with a pure-jnp oracle in
``ref.py`` that tests/test_kernels.py sweeps it against in interpret mode.

* ``hash_probe``        -- open-addressing join-table build + probe
                           (HashJoin's inner loop);
* ``segmented_agg``     -- one-hot MXU scatter-add (HashAggregation's
                           segmented reduction);
* ``block_prefix_sum``  -- two-level scan producing stream-compaction
                           addresses (``DeviceTable.compact``);
* ``radix_histogram``   -- per-partition row counts (the exchange's
                           metadata phase);
* ``flash_attention``   -- blocked attention (model-side workloads).

``ops`` carries the jit'd public wrappers plus the engine's backend switch
(``use_pallas`` / ``use_backend``, see ``core`` for how the driver selects
a backend per query); ``ref`` carries the semantic ground truths.
"""

from . import ops, ref
from .ops import (
    BACKENDS,
    block_prefix_sum,
    build_table,
    current_backend,
    default_backend,
    flash_attention,
    hash_probe,
    radix_histogram,
    segmented_sum,
    set_default_backend,
    use_backend,
    use_pallas,
)

__all__ = [
    "ops", "ref", "BACKENDS",
    "block_prefix_sum", "build_table", "flash_attention", "hash_probe",
    "radix_histogram", "segmented_sum",
    "current_backend", "default_backend", "set_default_backend",
    "use_backend", "use_pallas",
]
