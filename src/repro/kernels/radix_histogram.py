"""Radix histogram (exchange metadata phase) in Pallas.

Counts rows per destination partition — the receive-buffer sizing handshake
of the ICI exchange (paper's "metadata first" rendezvous). Same MXU
scatter-add idiom as segmented_agg: one_hot(pids)ᵀ @ 1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 2048


def _kernel(pid_ref, out_ref, *, num_partitions: int):
    rows = pid_ref.shape[0]
    pids = pid_ref[...]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rows, num_partitions), 1)
              == pids[:, None]).astype(jnp.float32)
    ones = jnp.ones((rows, 1), jnp.float32)
    counts = onehot.T @ ones                      # [P, 1] on the MXU

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += counts[:, 0].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_partitions", "row_block",
                                             "interpret"))
def radix_histogram(pids, num_partitions: int, row_block: int = ROW_BLOCK,
                    interpret: bool = False):
    """pids [N] int32 in [0, P) (others ignored) -> counts [P] int32."""
    n = pids.shape[0]
    if n == 0:
        return jnp.zeros((num_partitions,), jnp.int32)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    if pad:
        pids = jnp.pad(pids, (0, pad), constant_values=num_partitions)
    out = pl.pallas_call(
        functools.partial(_kernel, num_partitions=num_partitions),
        grid=(pids.shape[0] // row_block,),
        in_specs=[pl.BlockSpec((row_block,), lambda r: (r,))],
        out_specs=pl.BlockSpec((num_partitions,), lambda r: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_partitions,), jnp.int32),
        interpret=interpret,
    )(pids)
    return out
