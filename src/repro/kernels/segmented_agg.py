"""Segmented aggregation via one-hot MXU scatter-add (Pallas).

cuDF's hash-aggregation scatter is warp-atomic on GPU; the TPU has no
atomics, so the adaptation (DESIGN.md §2) turns scatter-add into a matmul:
for each row block, one_hot(gids)ᵀ @ values accumulates onto the group
vector using the MXU — the systolic array does the reduction. The grid is
sequential on TPU, so output-block accumulation across row blocks is safe.

Group counts beyond the block width accumulate in slabs of GROUP_BLOCK.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 1024
GROUP_BLOCK = 1024


def _kernel(gid_ref, val_ref, out_ref, *, group_block: int):
    rows = gid_ref.shape[0]
    gids = gid_ref[...]
    vals = val_ref[...].astype(jnp.float32)
    local = gids - pl.program_id(0) * group_block  # [R], this group slab
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
              == local[:, None]).astype(jnp.float32)
    contrib = onehot.T @ vals[:, None]             # [G_blk, 1] via MXU

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib[:, 0]


@functools.partial(jax.jit, static_argnames=("num_groups", "row_block",
                                             "interpret"))
def segmented_sum(gids, values, num_groups: int, row_block: int = ROW_BLOCK,
                  interpret: bool = False):
    """gids [N] int32 (>= num_groups dropped), values [N] -> [num_groups]."""
    n = gids.shape[0]
    if n == 0:
        return jnp.zeros((num_groups,), jnp.float32)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    if pad:
        gids = jnp.pad(gids, (0, pad), constant_values=num_groups)
        values = jnp.pad(values, (0, pad))
    n_pad = gids.shape[0]
    g_pad = ((num_groups + GROUP_BLOCK - 1) // GROUP_BLOCK) * GROUP_BLOCK

    grid = (g_pad // GROUP_BLOCK, n_pad // row_block)
    out = pl.pallas_call(
        functools.partial(_kernel, group_block=GROUP_BLOCK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
        ],
        out_specs=pl.BlockSpec((GROUP_BLOCK,), lambda g, r: (g,)),
        out_shape=jax.ShapeDtypeStruct((g_pad,), jnp.float32),
        interpret=interpret,
    )(gids, values.astype(jnp.float32))
    return out[:num_groups]
