"""Segmented aggregation via one-hot MXU scatter-add (Pallas).

cuDF's hash-aggregation scatter is warp-atomic on GPU; the TPU has no
atomics, so the adaptation (DESIGN.md §2) turns scatter-add into a matmul:
for each row block, one_hot(gids)ᵀ @ values accumulates onto the group
vector using the MXU — the systolic array does the reduction. The grid is
sequential on TPU, so output-block accumulation across row blocks is safe.

Group counts beyond the block width accumulate in slabs of GROUP_BLOCK.

VMEM sizing: each (group slab, row block) grid step materializes a
[ROW_BLOCK, GROUP_BLOCK] one-hot (4 MiB at the 1024x1024 defaults) next to
the in/out blocks, well inside a ~16 MiB core. The slab loop makes the
kernels correct for any group count; the engine's dispatch cap
(``relational.PALLAS_AGG_GROUP_LIMIT``) is an *inclusive* bound — exactly
``1 << 16`` groups (64 slabs) still dispatches here, ``(1 << 16) + 1``
takes the jnp fallback — chosen where slab-loop trace time starts to beat
the kernel's win. All three accumulators (float sum, int sum, min/max)
share the bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 1024
GROUP_BLOCK = 1024

# Inclusive group-count dispatch bound, kept in sync by hand with
# ``core.relational.PALLAS_AGG_GROUP_LIMIT`` (the kernels package cannot
# import core — core imports kernels). A regression test pins the two.
STACKED_GROUP_LIMIT = 1 << 16


def stacked_group_capacity(max_groups: int, limit: int = STACKED_GROUP_LIMIT
                           ) -> int:
    """How many queries can stack into one segmented-aggregation dispatch.

    Inter-query batching (``core.batch``) fuses B compatible aggregations
    by remapping ``group_id = query_id * max_groups + local_group``, so
    the kernels see one segmented problem with ``B * max_groups`` groups.
    The slab loop is correct for any count, but past ``limit`` (inclusive,
    matching the solo dispatch bound) trace time beats the kernel's win
    and the engine takes the jnp fallback — so the scheduler caps batches
    at the largest power of two B with ``B * max_groups <= limit``
    (power of two because member lanes pad up to one; a query whose solo
    ``max_groups`` already exceeds ``limit`` gets capacity 1: solo
    execution, never a wrong result).
    """
    if max_groups <= 0:
        raise ValueError("max_groups must be positive")
    cap = limit // max_groups
    if cap <= 1:
        return 1
    return 1 << (cap.bit_length() - 1)


def _kernel(gid_ref, val_ref, out_ref, *, group_block: int):
    rows = gid_ref.shape[0]
    gids = gid_ref[...]
    vals = val_ref[...].astype(jnp.float32)
    local = gids - pl.program_id(0) * group_block  # [R], this group slab
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
              == local[:, None]).astype(jnp.float32)
    contrib = onehot.T @ vals[:, None]             # [G_blk, 1] via MXU

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib[:, 0]


@functools.partial(jax.jit, static_argnames=("num_groups", "row_block",
                                             "interpret"))
def segmented_sum(gids, values, num_groups: int, row_block: int = ROW_BLOCK,
                  interpret: bool = False):
    """gids [N] int32 (>= num_groups dropped), values [N] -> [num_groups]."""
    n = gids.shape[0]
    if n == 0:
        return jnp.zeros((num_groups,), jnp.float32)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    if pad:
        gids = jnp.pad(gids, (0, pad), constant_values=num_groups)
        values = jnp.pad(values, (0, pad))
    n_pad = gids.shape[0]
    g_pad = ((num_groups + GROUP_BLOCK - 1) // GROUP_BLOCK) * GROUP_BLOCK

    grid = (g_pad // GROUP_BLOCK, n_pad // row_block)
    out = pl.pallas_call(
        functools.partial(_kernel, group_block=GROUP_BLOCK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
        ],
        out_specs=pl.BlockSpec((GROUP_BLOCK,), lambda g, r: (g,)),
        out_shape=jax.ShapeDtypeStruct((g_pad,), jnp.float32),
        interpret=interpret,
    )(gids, values.astype(jnp.float32))
    return out[:num_groups]


def _int_kernel(gid_ref, val_ref, out_ref, *, group_block: int):
    """Integer scatter-add: one-hot matmul with an int32 accumulator, so
    sums stay exact past 2^24 (float32's integer range) and wrap at 2^31
    exactly like the int32 ``jax.ops.segment_sum`` oracle."""
    rows = gid_ref.shape[0]
    gids = gid_ref[...]
    vals = val_ref[...].astype(jnp.int32)
    local = gids - pl.program_id(0) * group_block
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
              == local[:, None]).astype(jnp.int32)
    contrib = jax.lax.dot(onehot.T, vals[:, None],
                          preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib[:, 0]


@functools.partial(jax.jit, static_argnames=("num_groups", "row_block",
                                             "interpret"))
def segmented_int_sum(gids, values, num_groups: int,
                      row_block: int = ROW_BLOCK, interpret: bool = False):
    """gids [N] int32 (>= num_groups dropped), values [N] int ->
    int32[num_groups] (exact; overflow wraps like the int32 oracle)."""
    n = gids.shape[0]
    if n == 0:
        return jnp.zeros((num_groups,), jnp.int32)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    if pad:
        gids = jnp.pad(gids, (0, pad), constant_values=num_groups)
        values = jnp.pad(values, (0, pad))
    n_pad = gids.shape[0]
    g_pad = ((num_groups + GROUP_BLOCK - 1) // GROUP_BLOCK) * GROUP_BLOCK

    grid = (g_pad // GROUP_BLOCK, n_pad // row_block)
    out = pl.pallas_call(
        functools.partial(_int_kernel, group_block=GROUP_BLOCK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
        ],
        out_specs=pl.BlockSpec((GROUP_BLOCK,), lambda g, r: (g,)),
        out_shape=jax.ShapeDtypeStruct((g_pad,), jnp.int32),
        interpret=interpret,
    )(gids, values.astype(jnp.int32))
    return out[:num_groups]


def _minmax_kernel(gid_ref, val_ref, out_ref, *, group_block: int,
                   is_min: bool, init):
    """Segmented min/max: mask each row's value onto its group lane (the
    identity everywhere else) and reduce the row block with a plain
    min/max — no MXU, but the same slab/accumulate structure as the sums.
    Empty groups keep the identity, matching ``jax.ops.segment_min/max``."""
    rows = gid_ref.shape[0]
    gids = gid_ref[...]
    vals = val_ref[...]
    local = gids - pl.program_id(0) * group_block
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
              == local[:, None])
    ident = jnp.asarray(init, vals.dtype)
    masked = jnp.where(onehot, vals[:, None], ident)    # [R, G_blk]
    reduce = jnp.min if is_min else jnp.max
    contrib = reduce(masked, axis=0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    merge = jnp.minimum if is_min else jnp.maximum
    out_ref[...] = merge(out_ref[...], contrib)


@functools.partial(jax.jit, static_argnames=("num_groups", "kind",
                                             "row_block", "interpret"))
def segmented_minmax(gids, values, num_groups: int, kind: str,
                     row_block: int = ROW_BLOCK, interpret: bool = False):
    """gids [N] int32 (>= num_groups dropped), values [N] ->
    [num_groups] of values.dtype; kind in ('min', 'max'). Empty groups
    hold the reduction identity (+/-inf for floats, iinfo extremes for
    ints), exactly like ``jax.ops.segment_min/max``."""
    assert kind in ("min", "max")
    is_min = kind == "min"
    if jnp.issubdtype(values.dtype, jnp.floating):
        init = float("inf") if is_min else float("-inf")
    else:
        info = jnp.iinfo(values.dtype)
        init = info.max if is_min else info.min
    n = gids.shape[0]
    if n == 0:
        return jnp.full((num_groups,), init, values.dtype)
    row_block = min(row_block, n)
    pad = (-n) % row_block
    if pad:
        gids = jnp.pad(gids, (0, pad), constant_values=num_groups)
        values = jnp.pad(values, (0, pad))
    n_pad = gids.shape[0]
    g_pad = ((num_groups + GROUP_BLOCK - 1) // GROUP_BLOCK) * GROUP_BLOCK

    grid = (g_pad // GROUP_BLOCK, n_pad // row_block)
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, group_block=GROUP_BLOCK,
                          is_min=is_min, init=init),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
            pl.BlockSpec((row_block,), lambda g, r: (r,)),
        ],
        out_specs=pl.BlockSpec((GROUP_BLOCK,), lambda g, r: (g,)),
        out_shape=jax.ShapeDtypeStruct((g_pad,), values.dtype),
        interpret=interpret,
    )(gids, values)
    return out[:num_groups]
