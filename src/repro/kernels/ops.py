"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the compiled kernels run natively; elsewhere (this CPU
container) they run in interpret mode, which executes the kernel body in
Python and is what the correctness tests sweep. ``use_pallas()`` is the
engine's dispatch switch.
"""

from __future__ import annotations

import jax

from . import ref  # noqa: F401  (oracles re-exported for convenience)
from .block_prefix_sum import block_prefix_sum as _bps
from .flash_attention import flash_attention as _flash
from .hash_probe import build_table, hash_probe as _probe  # noqa: F401
from .radix_histogram import radix_histogram as _hist
from .segmented_agg import segmented_sum as _segsum


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, causal=True, **kw):
    return _flash(q, k, v, causal=causal, interpret=_interp(), **kw)


def segmented_sum(gids, values, num_groups, **kw):
    return _segsum(gids, values, num_groups, interpret=_interp(), **kw)


def radix_histogram(pids, num_partitions, **kw):
    return _hist(pids, num_partitions, interpret=_interp(), **kw)


def hash_probe(table_keys, table_vals, probe_keys, **kw):
    return _probe(table_keys, table_vals, probe_keys, interpret=_interp(),
                  **kw)


def block_prefix_sum(mask, **kw):
    return _bps(mask, interpret=_interp(), **kw)
