"""Public kernel wrappers + the engine's kernel-backend dispatch switch.

This module is the boundary where the engine picks its physical execution
layer, mirroring the paper's swap of Velox CPU operators for cuDF GPU
kernels behind one operator interface. Two backends exist:

* ``"jnp"``    -- the sort/searchsorted/segment_sum code in
                  ``core.relational`` / ``core.table`` (doubles as the
                  oracle the kernels are validated against);
* ``"pallas"`` -- the Pallas kernels in this package (``hash_probe``,
                  ``segmented_sum``, ``radix_histogram``,
                  ``block_prefix_sum``). On a TPU backend the compiled
                  kernels run natively; elsewhere (CPU containers, CI) they
                  run in interpret mode, which executes the kernel body as
                  ordinary XLA ops and is what the correctness sweeps test.

Selection is thread-scoped: ``use_backend("pallas")`` / ``use_pallas()``
are context managers the driver enters per query, the default comes from
``Session(kernel_backend=...)`` or the ``REPRO_KERNEL_BACKEND`` env var.
Dispatch accounting (``collect_dispatches`` / ``record_kernels``) lets the
driver report per-query ``kernel_dispatch`` counts in ``executor_stats``.
"""

from __future__ import annotations

import contextlib
import os
import threading
from typing import Dict, Iterator, Set

import jax

from . import ref  # noqa: F401  (oracles re-exported for convenience)
from .block_prefix_sum import block_prefix_sum as _bps
from .flash_attention import flash_attention as _flash
from .hash_probe import (build_table as _build, hash_probe as _probe,
                         hash_probe_multi as _probe_multi)
from .radix_histogram import radix_histogram as _hist
from .segmented_agg import (segmented_int_sum as _segisum,
                            segmented_minmax as _segminmax,
                            segmented_sum as _segsum)

BACKENDS = ("jnp", "pallas")

_tls = threading.local()
_default_backend = os.environ.get("REPRO_KERNEL_BACKEND", "jnp")
if _default_backend not in BACKENDS:          # pragma: no cover - env typo
    raise ValueError(
        f"REPRO_KERNEL_BACKEND={_default_backend!r} not in {BACKENDS}")


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------

def default_backend() -> str:
    """Process-wide default backend (``REPRO_KERNEL_BACKEND`` or 'jnp')."""
    return _default_backend


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend ('jnp' or 'pallas')."""
    global _default_backend
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; one of {BACKENDS}")
    _default_backend = name


def current_backend() -> str:
    """The backend active on this thread (innermost ``use_backend`` scope,
    falling back to the process default). Engine hot paths read this at
    trace time; compile caches must key on it."""
    stack = getattr(_tls, "backend_stack", None)
    return stack[-1] if stack else _default_backend


@contextlib.contextmanager
def use_backend(name: str) -> Iterator[None]:
    """Scope the calling thread to kernel backend ``name``::

        with kernels.ops.use_backend("pallas"):
            session.execute(plan)        # hot paths dispatch to Pallas
    """
    if name not in BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; one of {BACKENDS}")
    stack = getattr(_tls, "backend_stack", None)
    if stack is None:
        stack = _tls.backend_stack = []
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def use_pallas():
    """The engine's dispatch switch: ``with use_pallas(): ...`` routes the
    hot relational primitives (join probe, segmented aggregation, stream
    compaction, exchange histogram) through the Pallas kernels."""
    return use_backend("pallas")


# ---------------------------------------------------------------------------
# dispatch accounting
# ---------------------------------------------------------------------------
# Two thread-local channels: ``record_kernels`` captures *which* kernels a
# traced program uses (wrappers run at trace time only, so the driver cannot
# count executions there), and ``collect_dispatches`` receives per-execution
# counts replayed by the callers that invoke the compiled programs
# (operators.table_op, the exchange protocols).

def _stack(name: str) -> list:
    s = getattr(_tls, name, None)
    if s is None:
        s = []
        setattr(_tls, name, s)
    return s


@contextlib.contextmanager
def collect_dispatches(counts: Dict[str, int]) -> Iterator[None]:
    """Accumulate kernel-dispatch counts into ``counts`` (kind -> calls)
    for the duration of the scope; the driver wraps each query with this
    and surfaces the dict as ``executor_stats()['kernel_dispatch']``."""
    stack = _stack("counter_stack")
    stack.append(counts)
    try:
        yield
    finally:
        stack.pop()


def count_dispatch(kind: str, n: int = 1) -> None:
    """Report ``n`` executions of kernel ``kind`` to every active
    ``collect_dispatches`` scope on this thread (no-op outside one)."""
    for counts in _stack("counter_stack"):
        counts[kind] = counts.get(kind, 0) + n


@contextlib.contextmanager
def record_kernels(used: Set[str]) -> Iterator[None]:
    """Trace-time capture: while active, every kernel wrapper invocation
    adds its kind to ``used``. ``operators.table_op`` keeps one set per
    compiled program and replays it through ``count_dispatch`` per call."""
    stack = _stack("record_stack")
    stack.append(used)
    try:
        yield
    finally:
        stack.pop()


# guards recorded-kernel sets: a scheduler worker may replay a set while
# another worker's first call of the same compiled program is still
# tracing into it
_record_lock = threading.Lock()


def kernel_snapshot(used: Set[str]) -> tuple:
    """Race-free snapshot of a ``record_kernels`` set (callers iterate the
    returned tuple while other threads may still be tracing)."""
    with _record_lock:
        return tuple(used)


def _mark(kind: str) -> None:
    with _record_lock:
        for used in _stack("record_stack"):
            used.add(kind)


def mark_kernel(kind: str) -> None:
    """Trace-time record of a kernel dispatch for kernels that live
    outside this package but report through the same accounting (the
    fused per-morsel pipeline kernel in ``core.fused`` records 'fused')."""
    _mark(kind)


def mark_fallback(kind: str) -> None:
    """Trace-time note that a hot path wanted the pallas kernel for
    ``kind`` but took its jnp fallback (oversized capacity, composite key,
    unsupported accumulator...). Recorded as ``fallback_<kind>`` alongside
    the kernel kinds, so ``executor_stats()['kernel_dispatch']`` counts one
    fallback per would-be dispatch — the number adaptive re-planning tries
    to drive down."""
    _mark("fallback_" + kind)


# ---------------------------------------------------------------------------
# kernel wrappers (interpret mode off-TPU)
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    """True when jax's default backend is a TPU (compiled kernels)."""
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention(q, k, v, causal=True, **kw):
    """Blocked flash attention: [B, H, S, D] -> [B, H, S, D]."""
    _mark("attention")
    return _flash(q, k, v, causal=causal, interpret=_interp(), **kw)


def segmented_sum(gids, values, num_groups, **kw):
    """MXU scatter-add: sum ``values`` per group id (gids >= num_groups
    are dropped) -> float32[num_groups]. Oracle: ``ref.segmented_agg``."""
    _mark("agg")
    return _segsum(gids, values, num_groups, interpret=_interp(), **kw)


def segmented_int_sum(gids, values, num_groups, **kw):
    """Integer MXU scatter-add with an int32 accumulator (exact past 2^24,
    wraps at 2^31 like the int32 oracle) -> int32[num_groups]. Serves both
    integer sums and counts. Oracle: ``jax.ops.segment_sum``."""
    _mark("agg")
    return _segisum(gids, values, num_groups, interpret=_interp(), **kw)


def segmented_minmax(gids, values, num_groups, kind, **kw):
    """Segmented min/max (kind in 'min'|'max') -> [num_groups] of the
    value dtype; empty groups hold the reduction identity. Oracle:
    ``jax.ops.segment_min/max``."""
    _mark("agg")
    return _segminmax(gids, values, num_groups, kind, interpret=_interp(),
                      **kw)


def radix_histogram(pids, num_partitions, **kw):
    """Rows per destination partition (the exchange's metadata phase) ->
    int32[num_partitions]. Oracle: ``ref.radix_histogram``."""
    _mark("partition")
    return _hist(pids, num_partitions, interpret=_interp(), **kw)


def build_table(keys, vals, table_size, **kw):
    """Build the open-addressing join table (vectorized cooperative
    insertion, pure jnp) -> (table_keys, table_vals)."""
    _mark("build")
    return _build(keys, vals, table_size, **kw)


def hash_probe(table_keys, table_vals, probe_keys, **kw):
    """Probe the open-addressing table -> (found bool[N], vals int32[N]).
    Oracle: ``ref.hash_probe``."""
    _mark("probe")
    return _probe(table_keys, table_vals, probe_keys, interpret=_interp(),
                  **kw)


def hash_probe_multi(table_keys, table_vals, probe_keys, max_matches, **kw):
    """Expansion probe: every slot matching a probe key, in run order ->
    (count int32[N], slots int32[N, max_matches]). Oracle:
    ``relational.join_probe`` over the same build rows."""
    _mark("probe")
    return _probe_multi(table_keys, table_vals, probe_keys, max_matches,
                        interpret=_interp(), **kw)


def block_prefix_sum(mask, **kw):
    """Stream-compaction addresses: mask [N] -> (exclusive positions
    int32[N], total int32). Oracle: ``ref.block_prefix_sum``."""
    _mark("compact")
    return _bps(mask, interpret=_interp(), **kw)
