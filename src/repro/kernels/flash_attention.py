"""Blocked causal flash attention (forward) in Pallas.

Online-softmax over K/V blocks with the running (m, l, acc) statistics in
VMEM scratch; Q is tiled (BLOCK_Q x D) and each grid step streams K/V tiles
(BLOCK_K x D) from HBM through VMEM. Tile sizes are multiples of the TPU
lane layout (x128) and the MXU dimension; D (head dim) is kept whole per
tile — 64..256 on the assigned archs, within VMEM budget:

    VMEM per step ~ BLOCK_Q*D (q) + 2*BLOCK_K*D (k,v) + BLOCK_Q*BLOCK_K (s)
    = 128*128*4B * 4 tiles ~ 256 KiB  << 16 MiB v5e VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_len: int,
            causal: bool, scale: float):
    q = q_ref[...].astype(jnp.float32) * scale            # [BQ, D]
    block_q = q.shape[0]
    q_base = pl.program_id(1) * block_q

    m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q, 1), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[1]), jnp.float32)

    num_k = seq_len // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                        # [BQ, BK] on MXU
        if causal:
            rows = q_base + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    if causal:
        # only K blocks at or before this Q block contribute
        last = pl.program_id(1) * block_q // block_k + \
            (block_q + block_k - 1) // block_k
        last = jnp.minimum(last, num_k)
    else:
        last = num_k
    m, l, acc = jax.lax.fori_loop(0, last, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q,k,v: [B, H, S, D] -> [B, H, S, D]. S must divide by the blocks."""
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / math.sqrt(d)
    bh = b * h

    def reshaped(x):
        return x.reshape(bh, s, d)

    grid = (bh, s // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, seq_len=s, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(reshaped(q), reshaped(k), reshaped(v))
    return out.reshape(b, h, s, d)
