"""Open-addressing hash-table probe (join inner loop) in Pallas.

The cuDF GPU join probes a dynamic hash table with warp-cooperative linear
probing. TPU adaptation (DESIGN.md §2): the table is a power-of-two
key/value array resident in VMEM — the engine caps eligible builds at
``operators.MAX_HASH_TABLE_SLOTS`` (2^18 slots x 8 B = 2 MiB, comfortably
inside a ~16 MiB core alongside the probe blocks) and falls back to the
sorted-key path beyond that. A block of probe keys advances all lanes
together with a masked fori_loop — lanes that found their key (or an
empty slot) stop contributing. Collision verification stays vectorized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PROBE_BLOCK = 1024
MAX_PROBES_DEFAULT = 64


def _hash(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    return x.astype(jnp.int32)


def probe_loop(table_keys, table_vals, keys, *, table_size: int,
               empty_key: int, max_probes: int):
    """Single-match masked linear probe over a block of keys.

    Shared by the standalone ``hash_probe`` kernel and the fused morsel
    kernel (``fused_pipeline``): all lanes advance together, lanes that
    found their key (or an empty slot) stop contributing.
    """
    mask = table_size - 1
    h = _hash(keys) & mask

    def body(i, carry):
        found, val, done = carry
        idx = (h + i) & mask
        slot_keys = jnp.take(table_keys, idx)       # VMEM gather
        slot_vals = jnp.take(table_vals, idx)
        hit = (slot_keys == keys) & (~done)
        miss = (slot_keys == empty_key) & (~done)
        return (found | hit,
                jnp.where(hit, slot_vals, val),
                done | hit | miss)

    zero = jnp.zeros_like(keys)
    found, val, _ = jax.lax.fori_loop(
        0, max_probes, body,
        (jnp.zeros(keys.shape, jnp.bool_), zero,
         jnp.zeros(keys.shape, jnp.bool_)))
    return found, val


def probe_loop_multi(table_keys, table_vals, keys, *, table_size: int,
                     empty_key: int, max_probes: int, max_matches: int):
    """Multi-match (expansion) probe: walk the whole occupied run.

    Duplicate build keys occupy distinct slots of one linear-probe run
    (cooperative insertion places them round by round), so a lane keeps a
    cursor instead of a done-on-hit flag: every matching slot appends the
    slot's value to the lane's match list until the run's first empty slot
    (or the match capacity) stops it. Matches land in build-row order --
    duplicates are placed along the run in ascending row index -- which is
    the same order the sorted-key oracle emits.

    Returns (count int32[PB], slots int32[PB, max_matches]); slots past a
    lane's count hold garbage and must be masked by the caller.
    """
    mask = table_size - 1
    h = _hash(keys) & mask
    m = max_matches
    lane = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], m), 1)

    def body(i, carry):
        count, slots, done = carry
        idx = (h + i) & mask
        slot_keys = jnp.take(table_keys, idx)
        slot_vals = jnp.take(table_vals, idx)
        hit = (slot_keys == keys) & (~done) & (count < m)
        sel = hit[:, None] & (lane == count[:, None])
        slots = jnp.where(sel, slot_vals[:, None], slots)
        count = count + hit.astype(jnp.int32)
        miss = (slot_keys == empty_key) & (~done)
        return count, slots, done | miss | (count >= m)

    count0 = jnp.zeros(keys.shape, jnp.int32)
    slots0 = jnp.zeros((keys.shape[0], m), jnp.int32)
    done0 = jnp.zeros(keys.shape, jnp.bool_)
    count, slots, _ = jax.lax.fori_loop(0, max_probes, body,
                                        (count0, slots0, done0))
    return count, slots


def _kernel(tk_ref, tv_ref, pk_ref, found_ref, val_ref, *,
            table_size: int, empty_key: int, max_probes: int):
    found, val = probe_loop(tk_ref[...], tv_ref[...], pk_ref[...],
                            table_size=table_size, empty_key=empty_key,
                            max_probes=max_probes)
    found_ref[...] = found
    val_ref[...] = val


def _expand_kernel(tk_ref, tv_ref, pk_ref, cnt_ref, slot_ref, *,
                   table_size: int, empty_key: int, max_probes: int,
                   max_matches: int):
    count, slots = probe_loop_multi(
        tk_ref[...], tv_ref[...], pk_ref[...], table_size=table_size,
        empty_key=empty_key, max_probes=max_probes, max_matches=max_matches)
    cnt_ref[...] = count
    slot_ref[...] = slots


@functools.partial(jax.jit, static_argnames=("table_size", "empty_key"))
def build_table(keys, vals, table_size: int, empty_key: int = -1, valid=None):
    """Linear-probing insert of (key, val) pairs -> (tkeys, tvals).

    Vectorized cooperative insertion (the GPU build idiom, no atomics):
    every unplaced key attempts slot ``(hash(key) + round) & mask`` each
    round; ties on a slot resolve by scatter-min on key index, winners are
    placed, losers advance. Occupied slots never vacate, so the resulting
    table satisfies the linear-probe invariant (a key at distance ``d``
    from its home slot has no empty slot in between) regardless of the
    placement order. Rows with ``valid`` False (or key == ``empty_key``,
    which is indistinguishable from an empty slot) are never placed;
    callers detect the latter by comparing occupied-slot and valid-row
    counts. Pure jnp: runs the same on host, device, and under ``vmap``.
    """
    n = keys.shape[0]
    mask = table_size - 1
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    tk0 = jnp.full((table_size,), empty_key, jnp.int32)
    tv0 = jnp.zeros((table_size,), jnp.int32)
    if n == 0:
        return tk0, tv0
    keys = keys.astype(jnp.int32)
    vals = vals.astype(jnp.int32)
    home = _hash(keys) & mask
    idxs = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, _, placed, i = state
        return jnp.any(~placed) & (i < table_size)

    def body(state):
        tk, tv, placed, i = state
        slot = (home + i) & mask
        want = (~placed) & (jnp.take(tk, slot) == empty_key)
        cand = jnp.where(want, idxs, n)
        winner = jnp.full((table_size,), n, jnp.int32).at[slot].min(
            cand, mode="drop")
        won = want & (jnp.take(winner, slot) == idxs)
        dst = jnp.where(won, slot, table_size)      # losers scatter OOB
        tk = tk.at[dst].set(keys, mode="drop")
        tv = tv.at[dst].set(vals, mode="drop")
        return tk, tv, placed | won, i + 1

    tk, tv, _, _ = jax.lax.while_loop(
        cond, body, (tk0, tv0, ~valid, jnp.int32(0)))
    return tk, tv


@functools.partial(jax.jit, static_argnames=("empty_key", "max_probes",
                                             "probe_block", "interpret"))
def hash_probe(table_keys, table_vals, probe_keys, empty_key: int = -1,
               max_probes: int = MAX_PROBES_DEFAULT,
               probe_block: int = PROBE_BLOCK, interpret: bool = False):
    """-> (found [N] bool, vals [N] int32)."""
    n = probe_keys.shape[0]
    t = table_keys.shape[0]
    assert t & (t - 1) == 0, "table size must be a power of two"
    if n == 0:
        return (jnp.zeros((0,), jnp.bool_), jnp.zeros((0,), jnp.int32))
    probe_block = min(probe_block, n)
    pad = (-n) % probe_block
    if pad:
        probe_keys = jnp.pad(probe_keys, (0, pad), constant_values=empty_key)
    grid = (probe_keys.shape[0] // probe_block,)
    found, vals = pl.pallas_call(
        functools.partial(_kernel, table_size=t, empty_key=empty_key,
                          max_probes=min(max_probes, t)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t,), lambda i: (0,)),       # table resident in VMEM
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((probe_block,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((probe_block,), lambda i: (i,)),
                   pl.BlockSpec((probe_block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((probe_keys.shape[0],), jnp.bool_),
                   jax.ShapeDtypeStruct((probe_keys.shape[0],), jnp.int32)],
        interpret=interpret,
    )(table_keys, table_vals, probe_keys)
    return found[:n], vals[:n]


@functools.partial(jax.jit, static_argnames=("max_matches", "empty_key",
                                             "max_probes", "probe_block",
                                             "interpret"))
def hash_probe_multi(table_keys, table_vals, probe_keys, max_matches: int,
                     empty_key: int = -1,
                     max_probes: int = MAX_PROBES_DEFAULT,
                     probe_block: int = PROBE_BLOCK, interpret: bool = False):
    """Expansion probe -> (count int32[N], slots int32[N, max_matches]).

    ``slots[i, :count[i]]`` are the table values (build row indices) of
    every slot whose key equals ``probe_keys[i]``, in run order; entries
    past the count are garbage. Probe keys equal to ``empty_key`` report a
    bogus match (an empty slot compares equal) and must be masked by the
    caller, exactly as with ``hash_probe``.
    """
    n = probe_keys.shape[0]
    t = table_keys.shape[0]
    assert t & (t - 1) == 0, "table size must be a power of two"
    m = max_matches
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0, m), jnp.int32))
    probe_block = min(probe_block, n)
    pad = (-n) % probe_block
    if pad:
        probe_keys = jnp.pad(probe_keys, (0, pad), constant_values=empty_key)
    n_pad = probe_keys.shape[0]
    grid = (n_pad // probe_block,)
    count, slots = pl.pallas_call(
        functools.partial(_expand_kernel, table_size=t, empty_key=empty_key,
                          max_probes=min(max_probes, t), max_matches=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t,), lambda i: (0,)),       # table resident in VMEM
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((probe_block,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((probe_block,), lambda i: (i,)),
                   pl.BlockSpec((probe_block, m), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad, m), jnp.int32)],
        interpret=interpret,
    )(table_keys, table_vals, probe_keys)
    return count[:n], slots[:n]
