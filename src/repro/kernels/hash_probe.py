"""Open-addressing hash-table probe (join inner loop) in Pallas.

The cuDF GPU join probes a dynamic hash table with warp-cooperative linear
probing. TPU adaptation (DESIGN.md §2): the table is a power-of-two
key/value array resident in VMEM (fits: 64K slots x 8 B = 512 KiB); a block
of probe keys advances all lanes together with a masked fori_loop — lanes
that found their key (or an empty slot) stop contributing. Collision
verification stays vectorized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PROBE_BLOCK = 1024
MAX_PROBES_DEFAULT = 64


def _hash(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    return x.astype(jnp.int32)


def _kernel(tk_ref, tv_ref, pk_ref, found_ref, val_ref, *,
            table_size: int, empty_key: int, max_probes: int):
    keys = pk_ref[...]                              # [PB]
    mask = table_size - 1
    h = _hash(keys) & mask
    table_keys = tk_ref[...]
    table_vals = tv_ref[...]

    def body(i, carry):
        found, val, done = carry
        idx = (h + i) & mask
        slot_keys = jnp.take(table_keys, idx)       # VMEM gather
        slot_vals = jnp.take(table_vals, idx)
        hit = (slot_keys == keys) & (~done)
        miss = (slot_keys == empty_key) & (~done)
        return (found | hit,
                jnp.where(hit, slot_vals, val),
                done | hit | miss)

    zero = jnp.zeros_like(keys)
    found, val, _ = jax.lax.fori_loop(
        0, max_probes, body,
        (jnp.zeros(keys.shape, jnp.bool_), zero,
         jnp.zeros(keys.shape, jnp.bool_)))
    found_ref[...] = found
    val_ref[...] = val


def build_table(keys, vals, table_size: int, empty_key: int = -1):
    """Host-side insert (linear probing), jnp: returns (tkeys, tvals)."""
    mask = table_size - 1

    def insert(carry, kv):
        tk, tv = carry
        key, val = kv

        def cond(state):
            i, placed = state
            return (~placed) & (i < table_size)

        def body(state):
            i, placed = state
            return i + 1, placed

        # scan probe positions; insert at first empty
        def find(i, best):
            idx = (_hash(key) + i) & mask
            empty = tk[idx] == empty_key
            return jnp.where((best < 0) & empty, idx, best)

        pos = jax.lax.fori_loop(0, table_size,
                                lambda i, b: find(i, b), jnp.int32(-1))
        tk = tk.at[pos].set(key)
        tv = tv.at[pos].set(val)
        return (tk, tv), ()

    tk0 = jnp.full((table_size,), empty_key, jnp.int32)
    tv0 = jnp.zeros((table_size,), jnp.int32)
    (tk, tv), _ = jax.lax.scan(insert, (tk0, tv0), (keys, vals))
    return tk, tv


@functools.partial(jax.jit, static_argnames=("empty_key", "max_probes",
                                             "probe_block", "interpret"))
def hash_probe(table_keys, table_vals, probe_keys, empty_key: int = -1,
               max_probes: int = MAX_PROBES_DEFAULT,
               probe_block: int = PROBE_BLOCK, interpret: bool = False):
    """-> (found [N] bool, vals [N] int32)."""
    n = probe_keys.shape[0]
    t = table_keys.shape[0]
    assert t & (t - 1) == 0, "table size must be a power of two"
    probe_block = min(probe_block, n)
    pad = (-n) % probe_block
    if pad:
        probe_keys = jnp.pad(probe_keys, (0, pad), constant_values=empty_key)
    grid = (probe_keys.shape[0] // probe_block,)
    found, vals = pl.pallas_call(
        functools.partial(_kernel, table_size=t, empty_key=empty_key,
                          max_probes=min(max_probes, t)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t,), lambda i: (0,)),       # table resident in VMEM
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((probe_block,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((probe_block,), lambda i: (i,)),
                   pl.BlockSpec((probe_block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((probe_keys.shape[0],), jnp.bool_),
                   jax.ShapeDtypeStruct((probe_keys.shape[0],), jnp.int32)],
        interpret=interpret,
    )(table_keys, table_vals, probe_keys)
    return found[:n], vals[:n]
