from .ckpt import CheckpointManager, restore_latest  # noqa: F401
