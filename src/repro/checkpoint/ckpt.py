"""Checkpointing: async, atomic, latest-k, elastic across mesh shapes.

Layout: <dir>/step_<n>/  with one .npy per pytree leaf plus MANIFEST.json
(pytree paths, shapes, dtypes, data-pipeline state). Writes go to a tmp
directory and are renamed into place atomically, so a crash mid-save never
corrupts the restore target (fault-tolerance requirement).

Leaves are written as *global* host arrays (device_get gathers shards), so a
checkpoint saved on one mesh restores onto any other mesh — elastic
rescaling = restore with new shardings. At real 1000+-chip scale you would
write per-shard files via a distributed array serializer; the manifest
format carries global shapes so that swap is local to this module.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        # snapshot to host synchronously (cheap vs. serialization), write
        # in a background thread (async checkpointing)
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: Dict[str, Any]):
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{time.time_ns()}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten(host_state)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (path, arr) in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            dtype = str(arr.dtype)
            if dtype == "bfloat16":     # npy has no bf16: store exact f32
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname, "shape": list(arr.shape),
                 "dtype": dtype})
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, template, shardings=None):
        """Restore into the structure of ``template``; ``shardings`` (same
        pytree shape) re-places leaves on a (possibly different) mesh."""
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "MANIFEST.json")) as f:
            manifest = json.load(f)
        t_leaves, treedef = _flatten(template)
        assert len(t_leaves) == len(manifest["leaves"]), "structure mismatch"
        by_path = {m["path"]: m for m in manifest["leaves"]}
        arrays = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(t_leaves))
        for (path, t_leaf), sh in zip(t_leaves, shard_leaves):
            m = by_path[path]
            arr = np.load(os.path.join(final, m["file"]))
            assert tuple(arr.shape) == tuple(t_leaf.shape), \
                f"{path}: {arr.shape} vs {t_leaf.shape}"
            if m["dtype"] == "bfloat16":
                arr = jnp.asarray(arr).astype(jnp.bfloat16)
            arrays.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, arrays), manifest["extra"]


def restore_latest(directory: str, template, shardings=None):
    mgr = CheckpointManager(directory)
    steps = mgr.all_steps()
    if not steps:
        return None, None, None
    state, extra = mgr.restore(steps[-1], template, shardings)
    return steps[-1], state, extra
