"""Runtime-feedback statistics: observed cardinalities fed back to the planner.

The optimizer plans once from static catalog row counts, but the executor
measures the truth: per-operator output cardinalities, join key
multiplicities, zone-map skip fractions. This module closes that loop
(ROADMAP "Adaptive execution"): ``FeedbackStore`` records per-plan-node
observations after every execution, keyed by the capacity-normalized
``plan.feedback_key`` (bucketed per worker count and per catalog table
versions, so stale observations can never resize an operator for data
they were not measured on), with q-error tracking per entry.

Consumers:

* ``optimizer.choose_join_distribution`` / ``derive_capacities`` override
  declared row bounds with observed ones — tighter ``build_rows`` /
  ``max_groups`` / ``max_matches`` keep more joins and aggregations on the
  pallas kernels instead of the jnp fallback;
* ``optimizer.estimate_memory_breakdown`` prices warm plans from observed
  footprints, raising admission throughput;
* ``scheduler.QueryScheduler`` invalidates plan-cache entries whose
  producing estimates diverge from observation (q-error past a threshold),
  so the next submission re-plans warm.

Soundness: capacities are only tightened where an overflow degrades to the
jnp fallback (``build_rows``) or where the observation is an exact count
for the recorded table versions (``max_groups`` from the aggregate's own
output, ``max_matches`` from exact-key build multiplicity); any catalog
``register`` bumps the version and the warm entry stops matching.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from . import plan as P


def qerror(estimated: float, observed: float) -> float:
    """Multiplicative estimation error ``max(est/obs, obs/est)``.

    Both inputs are floored at 1 row so empty results and zero estimates
    stay finite; the result is symmetric (over- and under-estimation by
    the same factor score identically) and >= 1, with 1.0 meaning exact.
    """
    est = max(float(estimated), 1.0)
    obs = max(float(observed), 1.0)
    return max(est / obs, obs / est)


def referenced_sources(node: P.PlanNode) -> Tuple[str, ...]:
    """Sorted catalog table names scanned anywhere under ``node``."""
    names: set = set()
    stack: List[P.PlanNode] = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, P.TableScan):
            names.add(n.table)
        stack.extend(n.children())
    return tuple(sorted(names))


@dataclasses.dataclass
class FeedbackEntry:
    """One plan node's observed runtime statistics.

    ``rows`` is the latest observed valid output cardinality;
    ``estimated`` the static planner bound in force when it was recorded,
    and ``qerror`` their multiplicative divergence. ``max_matches`` is the
    maximum build-key multiplicity seen on an exact-key join build (an
    exact per-probe-row match bound); ``skip_fraction`` the zone-map chunk
    skip rate of a scan. ``updates``/``hits`` count store writes and
    planner reads.
    """

    rows: int
    estimated: Optional[int] = None
    qerror: float = 1.0
    max_matches: Optional[int] = None
    skip_fraction: Optional[float] = None
    updates: int = 0
    hits: int = 0


class FeedbackStore:
    """Thread-safe map from normalized plan-node keys to observations.

    One store typically lives on a ``Session`` (``Session(feedback=True)``)
    and is shared by every query the session runs — directly or through
    the scheduler — so the second execution of a plan shape re-plans from
    what the first one measured.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: Dict[str, FeedbackEntry] = {}

    def key_for(self, node: P.PlanNode, catalog, num_workers: int) -> str:
        """Store key for ``node``: capacity-normalized fingerprint bucketed
        by worker count and by the catalog versions of every table the
        subtree scans (a ``register`` invalidates dependent entries by
        construction)."""
        names = referenced_sources(node)
        try:
            versions = tuple(catalog.versions(names)) if names else ()
        except (AttributeError, KeyError):
            versions = ()
        return f"w{num_workers}|{versions!r}|{P.feedback_key(node)}"

    def record(self, key: str, rows: int, estimated: Optional[int] = None,
               max_matches: Optional[int] = None,
               skip_fraction: Optional[float] = None) -> FeedbackEntry:
        """Record one observation; the latest ``rows`` wins, side stats
        (``max_matches``/``skip_fraction``) only overwrite when provided."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                entry = self._entries[key] = FeedbackEntry(rows=int(rows))
            entry.rows = int(rows)
            if estimated is not None:
                entry.estimated = int(estimated)
                entry.qerror = qerror(estimated, rows)
            if max_matches is not None:
                entry.max_matches = int(max_matches)
            if skip_fraction is not None:
                entry.skip_fraction = float(skip_fraction)
            entry.updates += 1
            return entry

    def get(self, key: str) -> Optional[FeedbackEntry]:
        """The full entry for ``key`` (no hit accounting), or None."""
        with self._lock:
            return self._entries.get(key)

    def rows(self, key: str) -> Optional[int]:
        """Observed output rows for ``key`` (counts a planner hit)."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return None
            entry.hits += 1
            return entry.rows

    def max_matches(self, key: str) -> Optional[int]:
        """Observed exact-key build multiplicity for ``key``, if any."""
        with self._lock:
            entry = self._entries.get(key)
            return None if entry is None else entry.max_matches

    def skip_fraction(self, key: str) -> Optional[float]:
        """Observed zone-map skip fraction for ``key``, if any."""
        with self._lock:
            entry = self._entries.get(key)
            return None if entry is None else entry.skip_fraction

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        """Drop every observation (tests; catalog swaps)."""
        with self._lock:
            self._entries.clear()

    def summary(self) -> Dict[str, object]:
        """Aggregate view for ``executor_stats()['feedback']``: entry and
        update/hit counts plus the mean and max q-error across entries."""
        with self._lock:
            n = len(self._entries)
            qerrors = [e.qerror for e in self._entries.values()
                       if e.estimated is not None]
            return {
                "entries": n,
                "updates": sum(e.updates for e in self._entries.values()),
                "hits": sum(e.hits for e in self._entries.values()),
                "max_qerror": max(qerrors) if qerrors else 1.0,
                "mean_qerror": (sum(qerrors) / len(qerrors)
                                if qerrors else 1.0),
            }
