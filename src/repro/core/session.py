"""Session & catalog: the engine's public entry point.

A Session binds a catalog of tables to an execution configuration (worker
count, exchange protocol, batch size) and runs logical plans through the
Driver. Mirrors a Presto cluster: catalog -> connector, session -> query
submission, ExecutionContext -> worker fleet config.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from .driver import Driver, ExecutionContext, empty_executor_stats
from .exchange import ExchangeProtocol, ICIExchange
from .plan import PlanNode
from .streaming import HostMorsel, MorselPrefetcher, ScanStats, morsel_to_device
from .table import DeviceTable


class TableSource:
    """Abstract storage backend for one catalog table.

    Backends implement ``_host_morsels`` (pure host-side reads) and
    ``num_rows``; the shared ``scan``/``stream`` wrappers handle device
    placement. Implementations: ``InMemoryTable`` (numpy), and the chunked
    file formats ``storage.colchunk.ColumnChunkTable`` /
    ``storage.paged.PagedTableSource`` (both with zone-map skipping).
    """

    name: str
    schema: dict
    # catalog statistics for the optimizer: column sets that uniquely
    # identify a row (primary/candidate keys), e.g. (("o_orderkey",),)
    unique_keys: tuple = ()

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        """Host-side scan units (storage reads only, no device transfer).
        Backends implement this once; ``scan``/``stream`` wrap it."""
        raise NotImplementedError

    def scan(self, num_workers: int, columns, batch_rows: int,
             filter_expr=None,
             stats: Optional[ScanStats] = None) -> Iterator[DeviceTable]:
        """Synchronous scan: read + device-put inline on the caller's thread
        (the materialize-then-run baseline the paper starts from).

        Yields worker-stacked ``DeviceTable`` batches::

            src = session.catalog.get("lineitem")
            for batch in src.scan(num_workers=1, columns=["l_quantity"],
                                  batch_rows=4096):
                print(batch.validity.shape)     # [W, cap]
        """
        for morsel in self._host_morsels(num_workers, columns, batch_rows,
                                         filter_expr, stats=stats):
            if stats is not None:
                stats.morsels += 1
                stats.bytes_transferred += morsel.nbytes()
            yield morsel_to_device(morsel)

    def stream(self, num_workers: int, columns, batch_rows: int,
               filter_expr=None, prefetch_depth: int = 2, sharding=None,
               stats: Optional[ScanStats] = None,
               host_budget=None) -> MorselPrefetcher:
        """Asynchronous scan: a background thread reads morsel N+1 from
        storage and transfers it to the device while morsel N computes
        (double-buffered at ``prefetch_depth``). Returns an iterator of
        device morsels; counters accumulate into ``stats``::

            from repro.core.streaming import ScanStats
            stats = ScanStats()
            src = session.catalog.get("lineitem")
            for batch in src.stream(num_workers=1, columns=None,
                                    batch_rows=4096, stats=stats):
                pass                            # compute overlaps next read
            print(stats.prefetch_overlap)       # fraction of I/O hidden

        Sources that predate the morsel API (override ``scan`` only, not
        ``_host_morsels``) are still prefetched: their device batches feed
        the same bounded queue."""
        if (type(self)._host_morsels is TableSource._host_morsels
                and type(self).scan is not TableSource.scan):
            gen = self.scan(num_workers, columns, batch_rows, filter_expr)
        else:
            gen = self._host_morsels(num_workers, columns, batch_rows,
                                     filter_expr, stats=stats)
        return MorselPrefetcher(gen, depth=prefetch_depth, sharding=sharding,
                                stats=stats, host_budget=host_budget)

    def num_rows(self) -> int:
        """Total rows in the table (catalog statistic the optimizer uses)."""
        raise NotImplementedError


class InMemoryTable(TableSource):
    """Numpy-backed table; rows are range-partitioned across workers."""

    def __init__(self, name: str, data: Dict[str, np.ndarray], schema: dict,
                 unique_keys: tuple = ()):
        self.name = name
        self.data = {k: np.asarray(v, dtype=schema[k].np_dtype())
                     for k, v in data.items()}
        self.schema = dict(schema)
        self.unique_keys = tuple(tuple(u) for u in unique_keys)
        self._n = len(next(iter(self.data.values()))) if self.data else 0

    def num_rows(self) -> int:
        return self._n

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        cols = list(columns) if columns else list(self.data.keys())
        w = num_workers
        per_worker = math.ceil(self._n / w) if self._n else 1
        n_batches = max(1, math.ceil(per_worker / batch_rows))
        schema = {c: self.schema[c] for c in cols}
        for b in range(n_batches):
            lo = b * batch_rows
            hi = min(lo + batch_rows, per_worker)
            cap = hi - lo
            stacked_cols, stacked_valid = {}, np.zeros((w, cap), dtype=bool)
            for name in cols:
                dt_ = self.schema[name]
                arr = self.data[name]
                shape = (w, cap, dt_.width) if dt_.name == "bytes" else (w, cap)
                buf = np.zeros(shape, dtype=dt_.np_dtype())
                for wk in range(w):
                    base = wk * per_worker
                    s, e = base + lo, min(base + hi, self._n)
                    if e > s:
                        buf[wk, : e - s] = arr[s:e]
                        stacked_valid[wk, : e - s] = True
                stacked_cols[name] = buf
                if stats is not None:
                    stats.bytes_read += buf.nbytes
            yield HostMorsel(stacked_cols, stacked_valid, schema)


class Catalog:
    """Named ``TableSource`` registry (a Presto connector catalog).

    Every (re-)registration bumps the table's *version*; the scheduler's
    plan/result caches snapshot versions at insert time and treat any bump
    as invalidation, so re-registering a table (new data under the same
    name) can never serve stale cached results.
    """

    def __init__(self):
        self._tables: Dict[str, TableSource] = {}
        self._versions: Dict[str, int] = {}

    def register(self, source: TableSource):
        """Add or replace a table; bumps its version."""
        self._tables[source.name] = source
        self._versions[source.name] = self._versions.get(source.name, 0) + 1

    def register_numpy(self, name: str, data: Dict[str, np.ndarray], schema,
                       unique_keys: tuple = ()):
        """Register a dict of numpy arrays as an ``InMemoryTable``."""
        self.register(InMemoryTable(name, data, schema, unique_keys))

    def get(self, name: str) -> TableSource:
        """Look up a table source; raises ``KeyError`` if unknown."""
        return self._tables[name]

    def tables(self):
        """Names of all registered tables."""
        return list(self._tables)

    def version(self, name: str) -> int:
        """Monotonic registration counter for ``name`` (0 = never seen)."""
        return self._versions.get(name, 0)

    def versions(self, names) -> tuple:
        """Sorted ``(name, version)`` snapshot for cache-validity checks."""
        return tuple(sorted((n, self.version(n)) for n in names))


@dataclasses.dataclass(frozen=True)
class ExecutionOptions:
    """Per-query execution options, accepted uniformly by every entry
    point: ``Session.run``/``submit``/``execute``/``sql``,
    ``QueryBuilder.collect``/``submit``. ``None`` fields inherit the
    session (or call-site) defaults, so ``ExecutionOptions()`` is always a
    no-op::

        opts = ExecutionOptions(num_workers=2, kernel_backend="pallas")
        session.sql("SELECT count(*) AS n FROM orders", options=opts).collect()
        session.run(query, options=ExecutionOptions(priority=2))

    The legacy per-method keywords (``run(query, priority=...)``,
    ``collect(optimize=...)``, ``submit(priority=...)``) remain as thin
    shims; an explicit field here wins over them.
    """

    # scheduler queue priority (submit/run path; higher dequeues first)
    priority: Optional[int] = None
    # worker count for this query only (optimizer exchange placement and
    # the execution context both honor it)
    num_workers: Optional[int] = None
    # kernel backend ('jnp' | 'pallas') for this query only
    kernel_backend: Optional[str] = None
    # run the logical optimizer before execution (default True)
    optimize: Optional[bool] = None
    # runtime-feedback override for this query only: ``True`` enables an
    # ephemeral ``core.feedback.FeedbackStore``, ``False`` disables the
    # session's store, or pass a ``FeedbackStore`` to share across queries
    feedback: Optional[object] = None
    # inter-query batching opt-out for this query only: ``False`` keeps it
    # out of stacked launches even when ``SchedulerConfig.batching`` is on
    # (``True``/``None`` defer to the scheduler config — batching never
    # activates from here alone)
    batching: Optional[bool] = None


@dataclasses.dataclass
class Session:
    """The engine's public entry point: a catalog bound to an execution
    configuration, with both batch and serving entry points.

    Batch path (one query, this thread)::

        from repro.core import Session
        from repro.core.expr import col
        from repro.tpch import dbgen

        session = Session(dbgen.load_catalog(sf=0.002), num_workers=2)
        out = (session.table("lineitem")
               .filter(col("l_quantity") < 10.0)
               .group_by("l_returnflag")
               .agg(n=("count", None))
               .collect())

    Serving path (many queries, scheduled concurrently under a
    device-memory budget, with plan + result caching)::

        from repro.tpch import queries
        h1 = session.submit(queries.build_query(1, session.catalog))
        h6 = session.submit(queries.build_query(6, session.catalog))
        q1, q6 = session.gather(h1, h6)       # morsel pipelines interleave
        out = session.run(queries.build_query(14, session.catalog))

    ``submit``/``gather``/``run`` route through a lazily created
    ``QueryScheduler`` (see ``core.scheduler``); configure it by assigning
    ``session.scheduler_config = SchedulerConfig(...)`` before first use.
    """

    catalog: Catalog
    num_workers: int = 1
    exchange: Optional[ExchangeProtocol] = None
    batch_rows: int = 8192
    host_only_ops: frozenset = frozenset()
    mesh: Optional[object] = None          # Mesh with a 'workers' axis
    # morsel-driven scan pipeline: async storage->device prefetch with a
    # bounded queue of `prefetch_depth` in-flight morsels (False = the
    # synchronous materialize-then-run baseline)
    streaming: bool = True
    prefetch_depth: int = 2
    # physical kernel backend for the hot relational primitives (join
    # probe, segmented aggregation, stream compaction, exchange
    # histogram): 'jnp' (sort-based, the oracle) or 'pallas' (the
    # repro.kernels Pallas kernels; interpret mode off-TPU). None defers
    # to the REPRO_KERNEL_BACKEND env var, defaulting to 'jnp'.
    kernel_backend: Optional[str] = None
    # tiered-memory spill (core.spill): a device-memory budget in bytes
    # turns on out-of-core execution — every query gets a SpillManager and
    # the memory-hungry operators degrade through host buffers and paged
    # disk files instead of exceeding the budget. None = in-memory only.
    device_budget: Optional[int] = None
    # host-tier cap shared by spilled partitions and prefetched morsels
    host_budget: int = 1 << 31
    # directory for paged spill files (None = per-query temp dirs)
    spill_dir: Optional[str] = None
    # hard ceiling for the disk tier (the only tier that rejects work)
    disk_ceiling: int = 1 << 38
    # scheduler knobs (core.scheduler.SchedulerConfig); None = defaults.
    # Assign before the first submit()/run() — the scheduler is built lazily.
    scheduler_config: Optional[object] = None
    # adaptive execution (core.feedback): ``True`` gives the session a
    # ``FeedbackStore`` recording observed per-node cardinalities after
    # every query; the optimizer then re-plans warm runs from those
    # observations (tighter kernel capacities, feedback-driven build-side
    # selection) and the scheduler invalidates cached plans whose
    # estimates drifted. Pass an existing ``FeedbackStore`` to share one
    # across sessions; ``None`` disables adaptivity entirely.
    feedback: Optional[object] = None

    def feedback_store(self):
        """The session's ``core.feedback.FeedbackStore``, or ``None`` when
        adaptivity is off. Normalizes ``feedback=True`` into a concrete
        store on first use (thread-safe; all later calls share it)."""
        fb = self.feedback
        if fb is True:
            with Session._scheduler_lock:
                if self.feedback is True:
                    from .feedback import FeedbackStore
                    self.feedback = FeedbackStore()
                fb = self.feedback
        return fb if fb is not None and fb is not False else None

    def context(self) -> ExecutionContext:
        """Snapshot this session's execution config for one Driver run
        (each context gets its own per-query ``SpillManager``)."""
        spill = None
        if self.device_budget is not None:
            from .spill import SpillManager
            spill = SpillManager(self.device_budget, self.host_budget,
                                 spill_dir=self.spill_dir,
                                 disk_ceiling=self.disk_ceiling)
        return ExecutionContext(
            catalog=self.catalog,
            num_workers=self.num_workers,
            exchange=self.exchange or ICIExchange(mesh=self.mesh),
            batch_rows=self.batch_rows,
            host_only_ops=self.host_only_ops,
            mesh=self.mesh,
            streaming=self.streaming,
            prefetch_depth=self.prefetch_depth,
            kernel_backend=self.kernel_backend,
            spill=spill,
            feedback=self.feedback_store(),
        )

    def _with_options(self, options: Optional[ExecutionOptions]) -> "Session":
        """Session view with per-query overrides applied (direct path)."""
        if options is None:
            return self
        repl = {}
        if options.num_workers is not None:
            repl["num_workers"] = options.num_workers
        if options.kernel_backend is not None:
            repl["kernel_backend"] = options.kernel_backend
        if options.feedback is not None:
            repl["feedback"] = options.feedback
        return dataclasses.replace(self, **repl) if repl else self

    def execute(self, plan: PlanNode,
                options: Optional[ExecutionOptions] = None
                ) -> Dict[str, np.ndarray]:
        """Execute one plan on this thread; returns name -> numpy column.

        This is the direct batch path: no admission control, no caches.
        Serving workloads should prefer ``run``/``submit``, which route
        through the scheduler. ``options`` applies per-query
        ``num_workers``/``kernel_backend`` overrides (``priority`` is
        meaningless here; ``optimize`` is the caller's job — ``execute``
        runs the plan exactly as given).
        """
        driver = Driver(self._with_options(options).context())
        self.last_driver = driver
        return driver.collect(plan)

    # -- serving entry points (core.scheduler) ------------------------------
    # guards lazy scheduler creation: N client threads whose first call is
    # submit() must all get the same scheduler (one budget, one cache)
    _scheduler_lock = threading.Lock()

    def scheduler(self):
        """The session's ``QueryScheduler`` (created on first use).

        Configure with ``session.scheduler_config = SchedulerConfig(...)``
        before the first call; later assignments require ``reset_scheduler``.
        """
        sched = getattr(self, "_scheduler", None)
        if sched is None:
            with Session._scheduler_lock:
                sched = getattr(self, "_scheduler", None)
                if sched is None:
                    from .scheduler import QueryScheduler
                    sched = QueryScheduler(self, self.scheduler_config)
                    self._scheduler = sched
        return sched

    def reset_scheduler(self) -> None:
        """Drop the current scheduler (and its caches/queue) if any."""
        sched = getattr(self, "_scheduler", None)
        if sched is not None:
            sched.close(wait=False)
            self._scheduler = None

    def submit(self, query, priority: int = 0,
               options: Optional[ExecutionOptions] = None):
        """Submit a query for scheduled execution; returns a ``QueryHandle``.

        ``query`` is a ``PlanNode`` or a ``QueryBuilder`` (its plan is
        taken as-built; the scheduler optimizes through the plan cache —
        for SQL-frontend builders the originating SQL text prefixes the
        cache keys). ``options`` carries per-query overrides
        (``ExecutionOptions``); a builder from ``session.sql(...,
        options=...)`` brings its own unless overridden here. Raises
        ``QueryRejected`` when admission control refuses it::

            h = session.submit(session.table("lineitem").limit(5), priority=1)
            rows = h.result()
        """
        plan = query.plan if hasattr(query, "plan") else query
        if options is None:
            options = getattr(query, "_options", None)
        sql = getattr(query, "sql_text", None)
        opts = options or ExecutionOptions()
        if opts.priority is not None:
            priority = opts.priority
        return self.scheduler().submit(
            plan, priority=priority, sql=sql,
            num_workers=opts.num_workers,
            kernel_backend=opts.kernel_backend,
            optimize=opts.optimize,
            feedback=opts.feedback,
            batching=opts.batching)

    def gather(self, *handles) -> list:
        """Wait for ``submit`` handles; results in argument order."""
        return self.scheduler().gather(*handles)

    def run(self, query, priority: int = 0,
            options: Optional[ExecutionOptions] = None
            ) -> Dict[str, np.ndarray]:
        """Synchronous scheduled execution: ``submit`` + ``result``.

        Unlike ``execute``, this path gets admission control and the
        plan/result caches — repeated identical queries are served from
        cache until a referenced table is re-registered. ``options``
        applies per-query ``ExecutionOptions`` overrides.
        """
        return self.submit(query, priority=priority,
                           options=options).result()

    def executor_stats(self) -> Dict[str, object]:
        """Stats from the most recent ``execute`` (scan + operator timings).

        Before any query has run this returns the same *shape* a Driver
        reports — every key present, empty values — so callers can index
        ``stats['kernel_dispatch']``/``stats['feedback']`` unconditionally
        on both the direct and the scheduler path. The ``feedback`` entry
        always reflects the session's live store (it accumulates across
        queries, unlike the per-query driver stats).
        """
        driver = getattr(self, "last_driver", None)
        stats = (driver.executor_stats() if driver is not None
                 else empty_executor_stats())
        fb = self.feedback_store()
        if fb is not None:
            stats["feedback"] = fb.summary()
        return stats

    # -- fluent frontend + optimizer entry points ---------------------------
    def table(self, name: str, columns=None):
        """Start a fluent query on a catalog table; ``.collect()`` runs it
        through the logical optimizer and this session's driver."""
        from .builder import QueryBuilder
        return QueryBuilder.scan(self.catalog, name, columns, session=self)

    def sql(self, text: str, options: Optional[ExecutionOptions] = None,
            dialect: Optional[str] = None):
        """Parse SQL text into a session-bound ``QueryBuilder``.

        The returned builder is indistinguishable from a hand-built one —
        ``.collect()``, ``.submit()``, ``.explain(analyze=True)`` all work,
        and the optimizer/scheduler treat it identically (the SQL text
        additionally prefixes the scheduler's plan/result cache keys)::

            out = session.sql(
                "SELECT l_returnflag, count(*) AS n FROM lineitem "
                "GROUP BY l_returnflag ORDER BY l_returnflag").collect()

        Unsupported constructs raise ``SqlUnsupportedError`` naming the
        offending node; syntax errors raise ``SqlParseError``; unknown
        tables/columns raise ``SchemaError``. ``dialect`` transpiles
        foreign dialects via the optional ``sqlglot`` dependency (the
        ``[sql]`` extra). ``options`` attaches per-query
        ``ExecutionOptions`` that ``collect``/``submit`` pick up.
        """
        from .sql import lower_sql
        qb = lower_sql(text, self.catalog, session=self, dialect=dialect)
        qb._options = options
        return qb

    def optimizer_config(self):
        """This session's ``OptimizerConfig`` (worker count threaded in so
        exchange placement plans for the session's cluster size)."""
        from .optimizer import DEFAULT_CONFIG
        return dataclasses.replace(DEFAULT_CONFIG,
                                   num_workers=self.num_workers,
                                   feedback=self.feedback_store())

    def optimize(self, plan: PlanNode) -> PlanNode:
        """Run the rule-based logical optimizer over a plan tree. With
        ``num_workers > 1`` this includes physical exchange placement: the
        returned tree is a distributed fragment plan with explicit
        ``Repartition``/``Broadcast`` nodes."""
        from .optimizer import optimize
        return optimize(plan, self.catalog, config=self.optimizer_config())

    def explain(self, plan: PlanNode, analyze: bool = False) -> str:
        """Pretty-print a plan before and after optimization.

        .. deprecated::
            Prefer ``QueryBuilder.explain(analyze=...)`` — builder and SQL
            queries share that one explain surface and delegate here. This
            plan-first form is kept for callers holding a bare ``PlanNode``.

        With ``analyze=True`` the (optimized) plan is also executed and the
        executor's per-table scan stats -- bytes read, bytes transferred,
        chunks skipped by zone maps, prefetch-overlap fraction -- plus
        operator timings, per-fragment exchange stats (rows/bytes moved,
        host-staged bytes per Repartition/Broadcast), the per-operator
        memory-footprint breakdown, and -- when a ``device_budget`` is set
        -- the spill-cost estimate and observed per-tier spill counters
        are appended (EXPLAIN ANALYZE)."""
        from .optimizer import (estimate_memory_breakdown,
                                explain_before_after)
        text = explain_before_after(plan, self.catalog,
                                    config=self.optimizer_config())
        if not analyze:
            return text
        optimized = self.optimize(plan)
        breakdown = estimate_memory_breakdown(
            optimized, self.catalog, num_workers=self.num_workers,
            batch_rows=self.batch_rows, prefetch_depth=self.prefetch_depth)
        self.execute(optimized)
        lines = ["== executor stats =="]
        stats = self.executor_stats()
        for tname, s in sorted(stats.get("tables", {}).items()):
            lines.append(
                f"scan {tname}: morsels={s['morsels']} "
                f"chunks={s['chunks_total']} "
                f"chunks_skipped={s['chunks_skipped']} "
                f"bytes_read={s['bytes_read']} "
                f"bytes_transferred={s['bytes_transferred']} "
                f"prefetch_overlap={s['prefetch_overlap']:.2f}")
        for op, sec in sorted(stats.get("op_seconds", {}).items()):
            lines.append(f"op {op}: {sec:.4f}s")
        kd = stats.get("kernel_dispatch") or {}
        if kd:
            lines.append(
                f"kernels [{stats.get('kernel_backend')}]: "
                + " ".join(f"{k}={v}" for k, v in sorted(kd.items())))
        for frag, ex in stats.get("exchanges", {}).items():
            lines.append(
                f"exchange {frag} [{stats.get('exchange_protocol')}]: "
                f"rounds={ex['rounds']} rows_moved={ex['rows_moved']} "
                f"bytes_moved={ex['bytes_moved']} "
                f"host_staged_bytes={ex['host_staged_bytes']} "
                f"{ex['seconds']:.4f}s")
        lines.append("== memory ==")
        lines.extend(breakdown.describe(self.device_budget,
                                        self.host_budget).splitlines())
        spill = stats.get("spill") or {}
        if spill:
            lines.append(
                f"spill: reserved_peak={spill['reserved_peak']} "
                f"reserve_denials={spill['reserve_denials']} "
                f"staged_exchanges={stats.get('spill_staged_exchanges', 0)}")
            for tier in ("host", "disk"):
                t = spill[tier]
                lines.append(
                    f"spill {tier} tier: spilled_bytes={t['spilled_bytes']} "
                    f"restored_bytes={t['restored_bytes']} "
                    f"spills={t['spills']} restores={t['restores']}")
        return text + "\n" + "\n".join(lines)
