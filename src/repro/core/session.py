"""Session & catalog: the engine's public entry point.

A Session binds a catalog of tables to an execution configuration (worker
count, exchange protocol, batch size) and runs logical plans through the
Driver. Mirrors a Presto cluster: catalog -> connector, session -> query
submission, ExecutionContext -> worker fleet config.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional

import numpy as np

from .driver import Driver, ExecutionContext
from .exchange import ExchangeProtocol, ICIExchange
from .plan import PlanNode
from .streaming import HostMorsel, MorselPrefetcher, ScanStats, morsel_to_device
from .table import DeviceTable


class TableSource:
    name: str
    schema: dict
    # catalog statistics for the optimizer: column sets that uniquely
    # identify a row (primary/candidate keys), e.g. (("o_orderkey",),)
    unique_keys: tuple = ()

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        """Host-side scan units (storage reads only, no device transfer).
        Backends implement this once; ``scan``/``stream`` wrap it."""
        raise NotImplementedError

    def scan(self, num_workers: int, columns, batch_rows: int,
             filter_expr=None,
             stats: Optional[ScanStats] = None) -> Iterator[DeviceTable]:
        """Synchronous scan: read + device-put inline on the caller's thread
        (the materialize-then-run baseline the paper starts from)."""
        for morsel in self._host_morsels(num_workers, columns, batch_rows,
                                         filter_expr, stats=stats):
            if stats is not None:
                stats.morsels += 1
                stats.bytes_transferred += morsel.nbytes()
            yield morsel_to_device(morsel)

    def stream(self, num_workers: int, columns, batch_rows: int,
               filter_expr=None, prefetch_depth: int = 2, sharding=None,
               stats: Optional[ScanStats] = None) -> MorselPrefetcher:
        """Asynchronous scan: a background thread reads morsel N+1 from
        storage and transfers it to the device while morsel N computes
        (double-buffered at ``prefetch_depth``). Returns an iterator of
        device morsels; counters accumulate into ``stats``.

        Sources that predate the morsel API (override ``scan`` only, not
        ``_host_morsels``) are still prefetched: their device batches feed
        the same bounded queue."""
        if (type(self)._host_morsels is TableSource._host_morsels
                and type(self).scan is not TableSource.scan):
            gen = self.scan(num_workers, columns, batch_rows, filter_expr)
        else:
            gen = self._host_morsels(num_workers, columns, batch_rows,
                                     filter_expr, stats=stats)
        return MorselPrefetcher(gen, depth=prefetch_depth, sharding=sharding,
                                stats=stats)

    def num_rows(self) -> int:
        raise NotImplementedError


class InMemoryTable(TableSource):
    """Numpy-backed table; rows are range-partitioned across workers."""

    def __init__(self, name: str, data: Dict[str, np.ndarray], schema: dict,
                 unique_keys: tuple = ()):
        self.name = name
        self.data = {k: np.asarray(v, dtype=schema[k].np_dtype())
                     for k, v in data.items()}
        self.schema = dict(schema)
        self.unique_keys = tuple(tuple(u) for u in unique_keys)
        self._n = len(next(iter(self.data.values()))) if self.data else 0

    def num_rows(self) -> int:
        return self._n

    def _host_morsels(self, num_workers: int, columns, batch_rows: int,
                      filter_expr=None,
                      stats: Optional[ScanStats] = None
                      ) -> Iterator[HostMorsel]:
        cols = list(columns) if columns else list(self.data.keys())
        w = num_workers
        per_worker = math.ceil(self._n / w) if self._n else 1
        n_batches = max(1, math.ceil(per_worker / batch_rows))
        schema = {c: self.schema[c] for c in cols}
        for b in range(n_batches):
            lo = b * batch_rows
            hi = min(lo + batch_rows, per_worker)
            cap = hi - lo
            stacked_cols, stacked_valid = {}, np.zeros((w, cap), dtype=bool)
            for name in cols:
                dt_ = self.schema[name]
                arr = self.data[name]
                shape = (w, cap, dt_.width) if dt_.name == "bytes" else (w, cap)
                buf = np.zeros(shape, dtype=dt_.np_dtype())
                for wk in range(w):
                    base = wk * per_worker
                    s, e = base + lo, min(base + hi, self._n)
                    if e > s:
                        buf[wk, : e - s] = arr[s:e]
                        stacked_valid[wk, : e - s] = True
                stacked_cols[name] = buf
                if stats is not None:
                    stats.bytes_read += buf.nbytes
            yield HostMorsel(stacked_cols, stacked_valid, schema)


class Catalog:
    def __init__(self):
        self._tables: Dict[str, TableSource] = {}

    def register(self, source: TableSource):
        self._tables[source.name] = source

    def register_numpy(self, name: str, data: Dict[str, np.ndarray], schema,
                       unique_keys: tuple = ()):
        self.register(InMemoryTable(name, data, schema, unique_keys))

    def get(self, name: str) -> TableSource:
        return self._tables[name]

    def tables(self):
        return list(self._tables)


@dataclasses.dataclass
class Session:
    catalog: Catalog
    num_workers: int = 1
    exchange: Optional[ExchangeProtocol] = None
    batch_rows: int = 8192
    host_only_ops: frozenset = frozenset()
    mesh: Optional[object] = None          # Mesh with a 'workers' axis
    # morsel-driven scan pipeline: async storage->device prefetch with a
    # bounded queue of `prefetch_depth` in-flight morsels (False = the
    # synchronous materialize-then-run baseline)
    streaming: bool = True
    prefetch_depth: int = 2

    def context(self) -> ExecutionContext:
        return ExecutionContext(
            catalog=self.catalog,
            num_workers=self.num_workers,
            exchange=self.exchange or ICIExchange(mesh=self.mesh),
            batch_rows=self.batch_rows,
            host_only_ops=self.host_only_ops,
            mesh=self.mesh,
            streaming=self.streaming,
            prefetch_depth=self.prefetch_depth,
        )

    def execute(self, plan: PlanNode) -> Dict[str, np.ndarray]:
        driver = Driver(self.context())
        self.last_driver = driver
        return driver.collect(plan)

    def executor_stats(self) -> Dict[str, object]:
        """Stats from the most recent ``execute`` (scan + operator timings)."""
        driver = getattr(self, "last_driver", None)
        return driver.executor_stats() if driver is not None else {}

    # -- fluent frontend + planner entry points -----------------------------
    def table(self, name: str, columns=None):
        """Start a fluent query on a catalog table; ``.collect()`` runs it
        through the logical optimizer and this session's driver."""
        from .builder import QueryBuilder
        return QueryBuilder.scan(self.catalog, name, columns, session=self)

    def optimize(self, plan: PlanNode) -> PlanNode:
        """Run the rule-based logical optimizer over a plan tree."""
        from .optimizer import optimize
        return optimize(plan, self.catalog)

    def explain(self, plan: PlanNode, analyze: bool = False) -> str:
        """Pretty-print a plan before and after optimization.

        With ``analyze=True`` the (optimized) plan is also executed and the
        executor's per-table scan stats -- bytes read, bytes transferred,
        chunks skipped by zone maps, prefetch-overlap fraction -- plus
        operator timings are appended (EXPLAIN ANALYZE)."""
        from .optimizer import explain_before_after
        text = explain_before_after(plan, self.catalog)
        if not analyze:
            return text
        self.execute(self.optimize(plan))
        lines = ["== executor stats =="]
        stats = self.executor_stats()
        for tname, s in sorted(stats.get("tables", {}).items()):
            lines.append(
                f"scan {tname}: morsels={s['morsels']} "
                f"chunks={s['chunks_total']} "
                f"chunks_skipped={s['chunks_skipped']} "
                f"bytes_read={s['bytes_read']} "
                f"bytes_transferred={s['bytes_transferred']} "
                f"prefetch_overlap={s['prefetch_overlap']:.2f}")
        for op, sec in sorted(stats.get("op_seconds", {}).items()):
            lines.append(f"op {op}: {sec:.4f}s")
        return text + "\n" + "\n".join(lines)
