"""Session & catalog: the engine's public entry point.

A Session binds a catalog of tables to an execution configuration (worker
count, exchange protocol, batch size) and runs logical plans through the
Driver. Mirrors a Presto cluster: catalog -> connector, session -> query
submission, ExecutionContext -> worker fleet config.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .driver import Driver, ExecutionContext
from .exchange import ExchangeProtocol, ICIExchange
from .plan import PlanNode
from .table import DeviceTable


class TableSource:
    name: str
    schema: dict
    # catalog statistics for the optimizer: column sets that uniquely
    # identify a row (primary/candidate keys), e.g. (("o_orderkey",),)
    unique_keys: tuple = ()

    def scan(self, num_workers: int, columns, batch_rows: int,
             filter_expr=None) -> Iterator[DeviceTable]:
        raise NotImplementedError

    def num_rows(self) -> int:
        raise NotImplementedError


class InMemoryTable(TableSource):
    """Numpy-backed table; rows are range-partitioned across workers."""

    def __init__(self, name: str, data: Dict[str, np.ndarray], schema: dict,
                 unique_keys: tuple = ()):
        self.name = name
        self.data = {k: np.asarray(v, dtype=schema[k].np_dtype())
                     for k, v in data.items()}
        self.schema = dict(schema)
        self.unique_keys = tuple(tuple(u) for u in unique_keys)
        self._n = len(next(iter(self.data.values()))) if self.data else 0

    def num_rows(self) -> int:
        return self._n

    def scan(self, num_workers: int, columns, batch_rows: int,
             filter_expr=None) -> Iterator[DeviceTable]:
        cols = list(columns) if columns else list(self.data.keys())
        w = num_workers
        per_worker = math.ceil(self._n / w) if self._n else 1
        n_batches = max(1, math.ceil(per_worker / batch_rows))
        for b in range(n_batches):
            lo = b * batch_rows
            hi = min(lo + batch_rows, per_worker)
            cap = hi - lo
            stacked_cols, stacked_valid = {}, np.zeros((w, cap), dtype=bool)
            for name in cols:
                dt_ = self.schema[name]
                arr = self.data[name]
                shape = (w, cap) + dt_.storage_shape(1)[1:] if dt_.name == "bytes" \
                    else (w, cap)
                if dt_.name == "bytes":
                    shape = (w, cap, dt_.width)
                buf = np.zeros(shape, dtype=dt_.np_dtype())
                for wk in range(w):
                    base = wk * per_worker
                    s, e = base + lo, min(base + hi, self._n)
                    if e > s:
                        buf[wk, : e - s] = arr[s:e]
                        stacked_valid[wk, : e - s] = True
                stacked_cols[name] = jnp.asarray(buf)
            yield DeviceTable(stacked_cols,
                              jnp.asarray(stacked_valid),
                              {c: self.schema[c] for c in cols})


class Catalog:
    def __init__(self):
        self._tables: Dict[str, TableSource] = {}

    def register(self, source: TableSource):
        self._tables[source.name] = source

    def register_numpy(self, name: str, data: Dict[str, np.ndarray], schema,
                       unique_keys: tuple = ()):
        self.register(InMemoryTable(name, data, schema, unique_keys))

    def get(self, name: str) -> TableSource:
        return self._tables[name]

    def tables(self):
        return list(self._tables)


@dataclasses.dataclass
class Session:
    catalog: Catalog
    num_workers: int = 1
    exchange: Optional[ExchangeProtocol] = None
    batch_rows: int = 8192
    host_only_ops: frozenset = frozenset()
    mesh: Optional[object] = None          # Mesh with a 'workers' axis

    def context(self) -> ExecutionContext:
        return ExecutionContext(
            catalog=self.catalog,
            num_workers=self.num_workers,
            exchange=self.exchange or ICIExchange(mesh=self.mesh),
            batch_rows=self.batch_rows,
            host_only_ops=self.host_only_ops,
            mesh=self.mesh,
        )

    def execute(self, plan: PlanNode) -> Dict[str, np.ndarray]:
        driver = Driver(self.context())
        self.last_driver = driver
        return driver.collect(plan)

    # -- fluent frontend + planner entry points -----------------------------
    def table(self, name: str, columns=None):
        """Start a fluent query on a catalog table; ``.collect()`` runs it
        through the logical optimizer and this session's driver."""
        from .builder import QueryBuilder
        return QueryBuilder.scan(self.catalog, name, columns, session=self)

    def optimize(self, plan: PlanNode) -> PlanNode:
        """Run the rule-based logical optimizer over a plan tree."""
        from .optimizer import optimize
        return optimize(plan, self.catalog)

    def explain(self, plan: PlanNode) -> str:
        """Pretty-print a plan before and after optimization."""
        from .optimizer import explain_before_after
        return explain_before_after(plan, self.catalog)
