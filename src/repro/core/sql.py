"""SQL frontend: lower parsed SQL onto the fluent ``QueryBuilder``.

The paper runs unmodified Presto SQL against the GPU engine; this module is
that surface for the repro: ``Session.sql("SELECT ...")`` parses the text
with the bundled recursive-descent parser (``core.sqlast``) and lowers it
onto the existing ``core.builder.QueryBuilder`` — reusing its build-time
schema validation and the rule-based optimizer unchanged — so the returned
builder supports ``.collect()``, ``.submit()``, ``.explain()`` exactly like
a hand-built query::

    out = session.sql(
        "SELECT l_returnflag, sum(l_quantity) AS q "
        "FROM lineitem WHERE l_shipdate <= DATE '1998-09-02' "
        "GROUP BY l_returnflag ORDER BY l_returnflag").collect()

Supported: SELECT [DISTINCT] / FROM (comma joins + INNER JOIN ... ON) /
WHERE / GROUP BY / HAVING / ORDER BY / LIMIT, WITH-CTEs, derived tables,
arithmetic + comparison + boolean expressions, BETWEEN / IN / LIKE /
EXTRACT(YEAR) / SUBSTRING / searched CASE, the aggregates
sum/avg/min/max/count (+ the sole-aggregate COUNT(DISTINCT)), semi/anti
joins from [NOT] IN (SELECT ...) and [NOT] EXISTS, and scalar subqueries
(uncorrelated → ``ScalarBroadcast``; equi-correlated → group-by
decorrelation into a join). Everything else raises ``SqlUnsupportedError``
naming the construct — never silently wrong results.

String semantics follow the engine's dtypes: dict-encoded columns compare
as codes (the dictionaries are sorted, so order comparisons are
lexicographic) and LIKE over them constant-folds against the dictionary;
fixed-width bytes columns support the %-pattern subset of LIKE via
``BytesMatch``; ``SUBSTRING(col, 1, n)`` over digit prefixes lowers to
``PrefixCode``.

When the optional ``sqlglot`` dependency (the ``[sql]`` extra) is
installed, ``lower_sql(..., dialect="postgres")`` first transpiles foreign
dialects to this subset; without it, a ``dialect=`` request fails loudly.
"""

from __future__ import annotations

import bisect
import re
from typing import Callable, Dict, List, Optional, Tuple

from . import dtypes as dt
from . import optimizer as opt
from . import sqlast as A
from .builder import QueryBuilder, SchemaError
from .expr import (BinaryOp, BytesMatch, ColumnRef, Expr, IsIn, Literal,
                   PrefixCode, UnaryOp, Year, col)
from .sqlast import SqlParseError, SqlUnsupportedError  # noqa: F401 (re-export)

_AGG_FUNCS = {"sum", "avg", "min", "max", "count"}
_CMP_FLIP = {"eq": "eq", "ne": "ne", "lt": "gt", "le": "ge",
             "gt": "lt", "ge": "le"}
_LARGE_ROWS = 1 << 20


class _Source:
    """One FROM item: its builder, logical→physical column map, stats."""

    def __init__(self, alias: str, builder: QueryBuilder, rows: int,
                 unique: List[frozenset]):
        self.alias = alias
        self.builder = builder
        # logical (SQL-visible) name -> physical column name in the joined
        # builder; identical until a cross-source collision forces a rename
        self.colmap: Dict[str, str] = {c: c for c in builder.schema}
        self.rows = max(int(rows), 1)
        self.unique = unique            # frozensets of *logical* names


class _Frame:
    """The joined FROM/WHERE state of one SELECT: builder + resolution."""

    def __init__(self, sources: List[_Source]):
        self.sources = sources
        self.builder: Optional[QueryBuilder] = None
        # correlation equi-pairs discovered while lowering a subquery:
        # (outer physical column, inner physical column)
        self.corr: List[Tuple[str, str]] = []

    def locate(self, qual: Optional[str], name: str) -> Optional[_Source]:
        if qual is not None:
            src = next((s for s in self.sources if s.alias == qual), None)
            return src if src is not None and name in src.colmap else None
        hits = [s for s in self.sources if name in s.colmap]
        if len(hits) > 1:
            raise SchemaError(
                f"column '{name}' is ambiguous between "
                f"{sorted(s.alias for s in hits)}; qualify it")
        return hits[0] if hits else None

    def phys(self, qual: Optional[str], name: str) -> Optional[str]:
        src = self.locate(qual, name)
        return src.colmap[name] if src is not None else None


class _ExprCtx:
    """Everything expression lowering needs at one point in the pipeline."""

    def __init__(self, resolve: Callable[[Optional[str], str], Optional[str]],
                 schema: Dict[str, dt.DType],
                 subst: Optional[Dict[int, Expr]] = None,
                 structural: Optional[List[Tuple[A.SqlExpr, Expr]]] = None):
        self.resolve = resolve
        self.schema = schema
        self.subst = subst or {}          # id(ast node) -> lowered Expr
        self.structural = structural or []  # (ast, lowered) matched by ==


def _walk_all(e: A.SqlExpr):
    """Like ``sqlast.walk`` but also descends into subquery bodies."""
    for x in A.walk(e):
        yield x
        if isinstance(x, (A.SInSelect, A.SExists, A.SScalar)):
            yield from _select_exprs(x.select)


def _select_exprs(sel: A.Select):
    for it in sel.items:
        if not isinstance(it.expr, A.SStar):
            yield from _walk_all(it.expr)
    for jc in sel.join_conditions:
        yield from _walk_all(jc)
    if sel.where is not None:
        yield from _walk_all(sel.where)
    for g in sel.group_by:
        yield from _walk_all(g)
    if sel.having is not None:
        yield from _walk_all(sel.having)
    for oe, _ in sel.order_by:
        yield from _walk_all(oe)
    for _, c in sel.ctes:
        yield from _select_exprs(c)


def _refs_of(exprs) -> set:
    """(qualifier, name) pairs referenced by ``exprs`` (descending into
    subquery bodies — correlation refs must survive the outer joins)."""
    refs = set()
    for e in exprs:
        for x in _walk_all(e):
            if isinstance(x, A.SCol):
                refs.add((x.qualifier, x.name))
    return refs


def _like_regex(pattern: str):
    return re.compile(
        "".join(".*" if ch == "%" else re.escape(ch) for ch in pattern))


def _outer_ctx(frame: _Frame, cur: QueryBuilder) -> _ExprCtx:
    """Resolution context a subquery uses to see its *outer* query: only
    columns that actually survived into the outer builder are visible."""
    def resolve(qual, name):
        phys = frame.phys(qual, name)
        return phys if phys is not None and phys in cur.schema else None
    return _ExprCtx(resolve, cur.schema)


class _Lowering:
    """One ``lower_sql`` invocation (fresh-name counter + catalog/session)."""

    def __init__(self, catalog, session=None):
        self.catalog = catalog
        self.session = session
        self._n = 0

    def fresh(self, prefix: str) -> str:
        self._n += 1
        return f"__{prefix}{self._n}"

    # ------------------------------------------------------------------
    # statement lowering
    # ------------------------------------------------------------------
    def lower_select(self, sel: A.Select, env: Dict[str, QueryBuilder],
                     outer: Optional[_ExprCtx] = None) -> QueryBuilder:
        env = dict(env)
        for name, cte in sel.ctes:
            env[name] = self.lower_select(cte, env)

        frame = self.lower_from_where(sel, env, outer)
        cur = frame.builder

        # alias / positional substitution for GROUP BY and ORDER BY
        aliases = {it.alias: it.expr for it in sel.items if it.alias}

        def _resolve_item(e: A.SqlExpr, ctx_name: str) -> A.SqlExpr:
            if isinstance(e, A.SLit) and e.kind == "int":
                idx = int(e.value)
                if not 1 <= idx <= len(sel.items):
                    raise SqlParseError(
                        f"{ctx_name} position {idx} out of range")
                return sel.items[idx - 1].expr
            if (isinstance(e, A.SCol) and e.qualifier is None
                    and frame.locate(None, e.name) is None
                    and e.name in aliases):
                return aliases[e.name]
            return e

        group_exprs = [_resolve_item(g, "GROUP BY") for g in sel.group_by]
        agg_nodes = self._collect_aggregates(sel)

        if group_exprs or agg_nodes:
            cur, ctx = self._lower_aggregation(
                sel, cur, frame, env, group_exprs, agg_nodes)
        else:
            if sel.having is not None:
                raise SqlUnsupportedError(
                    "HAVING without GROUP BY or aggregates")
            ctx = _ExprCtx(frame.phys, cur.schema)

        # final projection to the select-list names, in order
        out_items: List[Tuple[str, Expr]] = []
        used = set()
        for i, it in enumerate(sel.items):
            if isinstance(it.expr, A.SStar):
                for src in frame.sources:
                    if it.expr.qualifier and src.alias != it.expr.qualifier:
                        continue
                    for logical, phys in src.colmap.items():
                        if logical in used:
                            raise SqlUnsupportedError(
                                f"SELECT * with duplicate column "
                                f"'{logical}' across tables")
                        used.add(logical)
                        out_items.append((logical, col(phys)))
                continue
            name = it.alias or (it.expr.name if isinstance(it.expr, A.SCol)
                                else f"col{i}")
            if name in used:
                raise SqlParseError(f"duplicate output column '{name}'")
            used.add(name)
            out_items.append((name, self.lower_expr(it.expr, ctx)))
        cur = cur.project(*out_items)

        if sel.distinct:
            cur = cur.distinct()

        if sel.order_by:
            keys, desc = [], []
            for oe, d in sel.order_by:
                keys.append(self._order_key(oe, sel, out_items, cur.schema))
                desc.append(d)
            cur = cur.order_by(*keys, descending=desc, limit=sel.limit)
        elif sel.limit is not None:
            cur = cur.limit(sel.limit)
        return cur

    def _order_key(self, oe: A.SqlExpr, sel: A.Select,
                   out_items: List[Tuple[str, Expr]],
                   schema: Dict[str, dt.DType]) -> str:
        if isinstance(oe, A.SLit) and oe.kind == "int":
            idx = int(oe.value)
            if not 1 <= idx <= len(out_items):
                raise SqlParseError(f"ORDER BY position {idx} out of range")
            return out_items[idx - 1][0]
        if isinstance(oe, A.SCol) and oe.qualifier is None \
                and oe.name in schema:
            return oe.name
        for it, (name, _) in zip(sel.items, out_items):
            if it.expr == oe:
                return name
        raise SqlUnsupportedError(
            "ORDER BY expression must be an output column, alias, or "
            f"select-list position; got {oe!r}")

    # ------------------------------------------------------------------
    # FROM + WHERE: sources, filters, join tree, subquery predicates
    # ------------------------------------------------------------------
    def lower_from_where(self, sel: A.Select, env: Dict[str, QueryBuilder],
                         outer: Optional[_ExprCtx]) -> _Frame:
        if not sel.from_items:
            raise SqlUnsupportedError("SELECT without FROM is not supported")
        sources: List[_Source] = []
        seen = set()
        for item in sel.from_items:
            if isinstance(item, A.SubqueryRef):
                base = self.lower_select(item.select, env)
                alias = item.alias
                rows, unique = self._derived_stats(base)
            else:
                alias = item.alias
                if item.name in env:
                    base = env[item.name]
                    rows, unique = self._derived_stats(base)
                else:
                    base = QueryBuilder.scan(self.catalog, item.name,
                                             session=self.session)
                    src = self.catalog.get(item.name)
                    rows = src.num_rows()
                    unique = [frozenset(u) for u in
                              getattr(src, "unique_keys", ())]
            if alias in seen:
                raise SqlParseError(f"duplicate table alias '{alias}'")
            seen.add(alias)
            sources.append(_Source(alias, base, rows, unique))
        frame = _Frame(sources)

        # classify WHERE/ON conjuncts
        conjs = ([c for jc in sel.join_conditions for c in A.conjuncts(jc)]
                 + A.conjuncts(sel.where))
        local: Dict[str, List[A.SqlExpr]] = {}
        edges: List[Tuple[Tuple[str, str], Tuple[str, str]]] = []
        post: List[A.SqlExpr] = []
        subq: List[A.SqlExpr] = []
        corr_asts: List[Tuple[A.SCol, A.SCol]] = []   # (outer ref, inner ref)
        for conj in conjs:
            if A.contains_aggregate(conj):
                raise SqlUnsupportedError("aggregate in WHERE clause")
            if A.contains_subquery(conj):
                subq.append(conj)
                continue
            refs = [x for x in A.walk(conj) if isinstance(x, A.SCol)]
            local_aliases, outer_refs = set(), []
            for r in refs:
                src = frame.locate(r.qualifier, r.name)
                if src is not None:
                    local_aliases.add(src.alias)
                elif outer is not None and outer.resolve(
                        r.qualifier, r.name) is not None:
                    outer_refs.append(r)
                else:
                    raise SchemaError(
                        f"unknown column "
                        f"'{(r.qualifier + '.') if r.qualifier else ''}"
                        f"{r.name}' in WHERE clause")
            if outer_refs:
                if not (isinstance(conj, A.SBin) and conj.op == "eq"
                        and isinstance(conj.lhs, A.SCol)
                        and isinstance(conj.rhs, A.SCol)
                        and len(outer_refs) == 1):
                    raise SqlUnsupportedError(
                        "correlated subquery predicate must be a simple "
                        f"column equality; got {conj!r}")
                inner_ref = (conj.rhs if outer_refs[0] is conj.lhs
                             else conj.lhs)
                corr_asts.append((outer_refs[0], inner_ref))
            elif len(local_aliases) <= 1:
                alias = next(iter(local_aliases), sources[0].alias)
                local.setdefault(alias, []).append(conj)
            elif (isinstance(conj, A.SBin) and conj.op == "eq"
                    and isinstance(conj.lhs, A.SCol)
                    and isinstance(conj.rhs, A.SCol)):
                ls = frame.locate(conj.lhs.qualifier, conj.lhs.name)
                rs = frame.locate(conj.rhs.qualifier, conj.rhs.name)
                edges.append(((ls.alias, conj.lhs.name),
                              (rs.alias, conj.rhs.name)))
            else:
                post.append(conj)

        # columns that must survive the join tree: everything referenced
        # downstream of it. Local-filter and join-edge conjuncts are
        # consumed by the tree itself, so a dimension table filtered and
        # joined purely for its effect (e.g. region in Q5) carries no
        # payload and lowers to a semi join.
        downstream = [it.expr for it in sel.items
                      if not isinstance(it.expr, A.SStar)]
        downstream.extend(sel.group_by)
        if sel.having is not None:
            downstream.append(sel.having)
        downstream.extend(oe for oe, _ in sel.order_by)
        downstream.extend(post)
        downstream.extend(subq)
        needed_refs = _refs_of(downstream)
        for _, inner_ref in corr_asts:
            needed_refs.add((inner_ref.qualifier, inner_ref.name))
        star = any(isinstance(it.expr, A.SStar) for it in sel.items)

        # per-source filters (before renames: identity colmap)
        for src in sources:
            for conj in local.get(src.alias, ()):
                ctx = _ExprCtx(
                    lambda q, n, _s=src: n if n in _s.colmap else None,
                    src.builder.schema)
                src.builder = src.builder.filter(self.lower_expr(conj, ctx))
                src.rows = max(1, src.rows // 2)

        # rename columns that collide across sources (self-joins)
        counts: Dict[str, int] = {}
        for src in sources:
            for c in src.colmap:
                counts[c] = counts.get(c, 0) + 1
        for src in sources:
            if any(counts[c] > 1 for c in src.colmap):
                src.colmap = {c: (f"{c}__{src.alias}" if counts[c] > 1 else c)
                              for c in src.colmap}
                src.builder = src.builder.project(
                    *[(src.colmap[c], col(c)) for c in src.builder.schema])

        frame.builder = self._join_tree(frame, edges, needed_refs, star)

        # residual multi-source predicates
        ctx = _ExprCtx(frame.phys, frame.builder.schema)
        for conj in post:
            frame.builder = frame.builder.filter(self.lower_expr(conj, ctx))

        # IN/EXISTS/scalar-subquery predicates
        for conj in subq:
            frame.builder = self._apply_subquery_conjunct(
                frame, conj, env)

        # correlation pairs, as physical columns on both sides
        for outer_ref, inner_ref in corr_asts:
            frame.corr.append((
                outer.resolve(outer_ref.qualifier, outer_ref.name),
                frame.phys(inner_ref.qualifier, inner_ref.name)))
        return frame

    def _derived_stats(self, base: QueryBuilder):
        try:
            rows = opt.row_bound(base.plan, self.catalog)
        except TypeError:
            rows = _LARGE_ROWS
        unique = [frozenset(u)
                  for u in opt.unique_sets(base.plan, self.catalog)]
        return rows, unique

    def _join_tree(self, frame: _Frame, edges, needed_refs,
                   star: bool) -> QueryBuilder:
        sources = frame.sources
        by_alias = {s.alias: s for s in sources}

        def needed(src: _Source) -> List[str]:
            return [c for c in src.colmap
                    if star or (src.alias, c) in needed_refs
                    or (None, c) in needed_refs]

        def covers(alias: str, keys) -> bool:
            return any(u <= keys for u in by_alias[alias].unique)

        # greedy left-deep tree: the root streams as the probe side; each
        # step materializes one connected source as a build side. Every
        # build's join keys must cover a declared/derived unique set: the
        # engine's static ``max_matches`` capacity silently truncates
        # matches past the bound, so a many-rows build side would be
        # silently wrong, not slow. Try roots largest-first until an
        # orientation proves unique on every build.
        def simulate(root: _Source):
            joined = {root.alias}
            steps: List[Tuple[str, List[Tuple[str, str, str]], bool]] = []
            all_cover = True
            while len(joined) < len(sources):
                cand: Dict[str, List[Tuple[str, str, str]]] = {}
                for (aa, an), (ba, bn) in edges:
                    if aa in joined and ba not in joined:
                        cand.setdefault(ba, []).append((aa, an, bn))
                    elif ba in joined and aa not in joined:
                        cand.setdefault(aa, []).append((ba, bn, an))
                if not cand:
                    missing = sorted(s.alias for s in sources
                                     if s.alias not in joined)
                    raise SqlUnsupportedError(
                        f"no equi-join condition connects {missing} to "
                        f"{sorted(joined)} (cross joins are not supported)")

                def cov(alias: str) -> bool:
                    return covers(alias, {bn for _, _, bn in cand[alias]})

                build_alias = min(
                    cand, key=lambda a: (not cov(a), by_alias[a].rows, a))
                steps.append((build_alias, cand[build_alias],
                              cov(build_alias)))
                all_cover = all_cover and cov(build_alias)
                joined.add(build_alias)
            return steps, all_cover

        roots = sorted(sources, key=lambda s: (-s.rows, s.alias))
        root, steps = roots[0], None
        for r in roots:
            s, all_cover = simulate(r)
            if steps is None or all_cover:
                root, steps = r, s
            if all_cover:
                break

        joined = {root.alias}
        cur = root.builder
        for build_alias, cand_edges, cov in steps:
            if not cov:
                keys = sorted({bn for _, _, bn in cand_edges})
                raise SqlUnsupportedError(
                    f"join builds '{build_alias}' on {keys}, which cover "
                    f"no unique key of it under any join order; the "
                    f"engine's static max_matches capacity cannot bound "
                    f"a many-to-many join")
            build = by_alias[build_alias]
            probe_keys = [by_alias[pa].colmap[pn]
                          for pa, pn, _ in cand_edges]
            build_keys = [build.colmap[bn]
                          for _, _, bn in cand_edges]
            # build columns that later joins will need as probe keys
            # (edges whose other endpoint is still unjoined) must ride
            # along as payload even when nothing downstream reads them
            future = set()
            for (aa, an), (ba, bn) in edges:
                if aa == build_alias and ba != build_alias \
                        and ba not in joined:
                    future.add(an)
                elif ba == build_alias and aa != build_alias \
                        and aa not in joined:
                    future.add(bn)
            want = set(needed(build)) | future
            payload = [build.colmap[c] for c in build.colmap
                       if c in want and build.colmap[c] not in cur.schema]
            if not payload and cov:
                cur = cur.semi_join(build.builder, probe_keys, build_keys)
            else:
                cur = cur.join(build.builder, probe_keys, build_keys,
                               payload=payload)
            joined.add(build_alias)
        return cur

    # ------------------------------------------------------------------
    # subquery predicates: IN / EXISTS / scalar comparisons
    # ------------------------------------------------------------------
    def _apply_subquery_conjunct(self, frame: _Frame, conj: A.SqlExpr,
                                 env) -> QueryBuilder:
        cur = frame.builder
        node, negated = conj, False
        while isinstance(node, A.SNot):
            node, negated = node.operand, not negated

        if isinstance(node, A.SExists):
            neg = node.negated ^ negated
            if node.select.group_by or node.select.having is not None:
                raise SqlUnsupportedError(
                    "EXISTS over a grouped subquery is not supported")
            inner = self.lower_from_where(
                node.select, env, _outer_ctx(frame, cur))
            if not inner.corr:
                raise SqlUnsupportedError(
                    "uncorrelated EXISTS is not supported")
            left = [o for o, _ in inner.corr]
            right = [i for _, i in inner.corr]
            join = cur.anti_join if neg else cur.semi_join
            return join(inner.builder, left, right)

        if isinstance(node, A.SInSelect):
            neg = node.negated ^ negated
            if not isinstance(node.operand, A.SCol):
                raise SqlUnsupportedError(
                    "IN (SELECT ...) needs a plain column on the left")
            phys = frame.phys(node.operand.qualifier, node.operand.name)
            if phys is None:
                raise SchemaError(
                    f"unknown column '{node.operand.name}' in IN predicate")
            inner = self.lower_select(node.select, env)
            if len(inner.schema) != 1:
                raise SqlUnsupportedError(
                    "IN (SELECT ...) subquery must produce one column, "
                    f"got {list(inner.schema)}")
            (inner_col,) = inner.schema
            join = cur.anti_join if neg else cur.semi_join
            return join(inner, [phys], [inner_col])

        # comparison containing scalar subqueries
        subst: Dict[int, Expr] = {}
        for x in A.walk(conj):
            if isinstance(x, (A.SInSelect, A.SExists)):
                raise SqlUnsupportedError(
                    f"IN/EXISTS nested inside an expression: {conj!r}")
            if isinstance(x, A.SScalar):
                cur = self._attach_scalar(cur, frame, x, env, subst)
        ctx = _ExprCtx(frame.phys, cur.schema, subst=subst)
        return cur.filter(self.lower_expr(conj, ctx))

    def _attach_scalar(self, cur: QueryBuilder, frame: Optional[_Frame],
                       node: A.SScalar, env,
                       subst: Dict[int, Expr]) -> QueryBuilder:
        """Lower one scalar subquery; register its replacement in subst."""
        sub = node.select
        if len(sub.items) != 1 or sub.group_by or sub.having:
            raise SqlUnsupportedError(
                "scalar subquery must be a single ungrouped aggregate")
        item = sub.items[0]
        aggs = [x for x in A.walk(item.expr)
                if isinstance(x, A.SFunc) and x.name in _AGG_FUNCS]
        if not aggs:
            raise SqlUnsupportedError(
                "scalar subquery must compute an aggregate")

        outer_ctx = _outer_ctx(frame, cur) if frame is not None else None
        inner = self.lower_from_where(sub, env, outer_ctx)

        ib = inner.builder
        agg_specs: Dict[str, Tuple[str, Optional[str]]] = {}
        agg_subst: Dict[int, Expr] = {}
        ictx = _ExprCtx(inner.phys, ib.schema)
        pre: List[Tuple[str, Expr]] = []
        for a in aggs:
            out = self.fresh("agg")
            spec, pre_col = self._agg_spec(a, ictx)
            if pre_col is not None:
                pre.append(pre_col)
            agg_specs[out] = spec
            agg_subst[id(a)] = col(out)
        if pre:
            ib = ib.project(*ib.schema, *pre)
        keys = [i for _, i in inner.corr]
        ib = ib.group_by(*keys).agg(**agg_specs) if keys \
            else ib.agg(**agg_specs)
        sname = self.fresh("s")
        post_ctx = _ExprCtx(lambda q, n: n if n in ib.schema else None,
                            ib.schema, subst=agg_subst)
        ib = ib.project(*keys, (sname, self.lower_expr(item.expr, post_ctx)))

        if inner.corr:
            cur = cur.join(ib, [o for o, _ in inner.corr], keys,
                           payload=[sname])
        else:
            cur = cur.attach_scalar(ib, [sname])
        subst[id(node)] = col(sname)
        return cur

    def _agg_spec(self, a: A.SFunc, ctx: _ExprCtx):
        """(kind, in_col) for one aggregate call, plus an optional
        precomputed input column (name, expr) when the argument is not a
        plain column reference."""
        if a.distinct:
            raise SqlUnsupportedError(
                f"{a.name.upper()}(DISTINCT ...) in this position")
        if a.name == "count":
            return ("count", None), None       # no NULLs: count(x) == count(*)
        if len(a.args) != 1:
            raise SqlUnsupportedError(
                f"{a.name}() takes exactly one argument")
        e = self.lower_expr(a.args[0], ctx)
        if isinstance(e, ColumnRef):
            return (a.name, e.name), None
        name = self.fresh("a")
        return (a.name, name), (name, e)

    # ------------------------------------------------------------------
    # aggregation (GROUP BY / HAVING / aggregate select items)
    # ------------------------------------------------------------------
    def _collect_aggregates(self, sel: A.Select) -> List[A.SFunc]:
        nodes: List[A.SFunc] = []
        exprs = [it.expr for it in sel.items
                 if not isinstance(it.expr, A.SStar)]
        if sel.having is not None:
            exprs.append(sel.having)
        exprs.extend(oe for oe, _ in sel.order_by)
        for e in exprs:
            for x in A.walk(e):      # not _walk_all: subqueries own theirs
                if isinstance(x, A.SFunc) and x.name in _AGG_FUNCS:
                    nodes.append(x)
        return nodes

    def _lower_aggregation(self, sel: A.Select, cur: QueryBuilder,
                           frame: _Frame, env, group_exprs,
                           agg_nodes) -> Tuple[QueryBuilder, _ExprCtx]:
        base_ctx = _ExprCtx(frame.phys, cur.schema)
        aliases = {id(it.expr): it.alias for it in sel.items if it.alias}

        keys: List[str] = []
        pre: List[Tuple[str, Expr]] = []
        structural: List[Tuple[A.SqlExpr, Expr]] = []
        for gi, ge in enumerate(group_exprs):
            e = self.lower_expr(ge, base_ctx)
            if isinstance(e, ColumnRef):
                keys.append(e.name)
            else:
                name = aliases.get(id(ge)) or f"__g{gi}"
                pre.append((name, e))
                keys.append(name)
            structural.append((ge, col(keys[-1])))

        distinct_counts = [a for a in agg_nodes
                           if a.distinct and a.name == "count"]
        for a in agg_nodes:
            if a.distinct and a.name != "count":
                raise SqlUnsupportedError(
                    f"{a.name.upper()}(DISTINCT ...) is not supported")
        if distinct_counts and len(agg_nodes) != len(distinct_counts):
            raise SqlUnsupportedError(
                "COUNT(DISTINCT ...) mixed with other aggregates")

        agg_specs: Dict[str, Tuple[str, Optional[str]]] = {}
        subst: Dict[int, Expr] = {}
        seen: List[Tuple[A.SFunc, str]] = []
        if distinct_counts:
            d0 = distinct_counts[0]
            if any(a != d0 for a in distinct_counts):
                raise SqlUnsupportedError(
                    "multiple distinct COUNT(DISTINCT ...) aggregates")
            if len(d0.args) != 1:
                raise SqlUnsupportedError("COUNT(DISTINCT ...) arity")
            de = self.lower_expr(d0.args[0], base_ctx)
            if not isinstance(de, ColumnRef):
                dname = self.fresh("d")
                pre.append((dname, de))
                de = col(dname)
            if pre:
                cur = cur.project(*cur.schema, *pre)
            cur = cur.distinct(*keys, de.name)
            out = self.fresh("agg")
            cur = cur.group_by(*keys).agg(**{out: ("count", None)})
            for a in distinct_counts:
                subst[id(a)] = col(out)
        else:
            for a in agg_nodes:
                prior = next((o for n, o in seen if n == a), None)
                if prior is not None:
                    subst[id(a)] = col(prior)
                    continue
                out = self.fresh("agg")
                spec, pre_col = self._agg_spec(a, base_ctx)
                if pre_col is not None:
                    pre.append(pre_col)
                agg_specs[out] = spec
                subst[id(a)] = col(out)
                seen.append((a, out))
            if pre:
                cur = cur.project(*cur.schema, *pre)
            cur = cur.group_by(*keys).agg(**agg_specs)

        def post_resolve(qual, name):
            phys = frame.phys(qual, name)
            if phys is not None and phys in cur.schema:
                return phys
            return None

        ctx = _ExprCtx(post_resolve, cur.schema, subst=subst,
                       structural=structural)

        if sel.having is not None:
            for conj in A.conjuncts(sel.having):
                if A.contains_subquery(conj):
                    for x in A.walk(conj):
                        if isinstance(x, (A.SInSelect, A.SExists)):
                            raise SqlUnsupportedError(
                                "IN/EXISTS in HAVING is not supported")
                        if isinstance(x, A.SScalar):
                            cur = self._attach_scalar(
                                cur, None, x, env, subst)
                    ctx = _ExprCtx(post_resolve, cur.schema, subst=subst,
                                   structural=structural)
                cur = cur.filter(self.lower_expr(conj, ctx))
                ctx = _ExprCtx(post_resolve, cur.schema, subst=subst,
                               structural=structural)
        return cur, ctx

    # ------------------------------------------------------------------
    # expression lowering
    # ------------------------------------------------------------------
    def lower_expr(self, e: A.SqlExpr, ctx: _ExprCtx) -> Expr:
        if id(e) in ctx.subst:
            return ctx.subst[id(e)]
        for ast, lowered in ctx.structural:
            if ast == e:
                return lowered
        if isinstance(e, A.SCol):
            phys = ctx.resolve(e.qualifier, e.name)
            if phys is None:
                raise SchemaError(
                    f"unknown column "
                    f"'{(e.qualifier + '.') if e.qualifier else ''}{e.name}'"
                    f"; available: {sorted(ctx.schema)}")
            return col(phys)
        if isinstance(e, A.SLit):
            return self._literal(e)
        if isinstance(e, A.SInterval):
            raise SqlUnsupportedError(
                "INTERVAL outside date +/- INTERVAL arithmetic")
        if isinstance(e, A.SBin):
            if e.op in ("and", "or"):
                return BinaryOp(e.op, self.lower_expr(e.lhs, ctx),
                                self.lower_expr(e.rhs, ctx))
            if e.op in _CMP_FLIP:
                return self._lower_cmp(e.op, e.lhs, e.rhs, ctx)
            return self._lower_arith(e, ctx)
        if isinstance(e, A.SNot):
            return UnaryOp("not", self.lower_expr(e.operand, ctx))
        if isinstance(e, A.SNeg):
            return UnaryOp("neg", self.lower_expr(e.operand, ctx))
        if isinstance(e, A.SExtract):
            if e.field != "year":
                raise SqlUnsupportedError(
                    f"EXTRACT({e.field.upper()}) is not supported "
                    f"(only YEAR)")
            return Year(self.lower_expr(e.operand, ctx))
        if isinstance(e, A.SSubstr):
            if e.start != 1:
                raise SqlUnsupportedError(
                    "SUBSTRING must start at position 1")
            operand = self.lower_expr(e.operand, ctx)
            if operand.out_dtype(ctx.schema).name != "bytes":
                raise SqlUnsupportedError(
                    "SUBSTRING needs a fixed-width bytes column")
            return PrefixCode(operand, e.length)
        if isinstance(e, A.SCase):
            return self._lower_case(e, ctx)
        if isinstance(e, A.SIn):
            return self._lower_in(e, ctx)
        if isinstance(e, A.SLike):
            return self._lower_like(e, ctx)
        if isinstance(e, A.SBetween):
            lo = self._lower_cmp("ge", e.operand, e.lo, ctx)
            hi = self._lower_cmp("le", e.operand, e.hi, ctx)
            return BinaryOp("and", lo, hi)
        if isinstance(e, A.SFunc):
            if e.name in _AGG_FUNCS:
                raise SqlUnsupportedError(
                    f"aggregate {e.name}() is not allowed here")
            raise SqlUnsupportedError(f"function {e.name}() is not supported")
        if isinstance(e, (A.SScalar, A.SInSelect, A.SExists)):
            raise SqlUnsupportedError(
                "subquery in this expression position is not supported")
        raise SqlUnsupportedError(f"cannot lower {type(e).__name__}")

    def _literal(self, e: A.SLit) -> Expr:
        if e.kind == "int":
            return Literal(int(e.value))
        if e.kind == "float":
            return Literal(float(e.value))
        if e.kind == "bool":
            return Literal(bool(e.value))
        if e.kind == "date":
            return Literal(dt.date_to_i32(e.value), dt.DATE32)
        raise SqlUnsupportedError(
            f"string literal {e.value!r} needs a string-typed column "
            f"context (comparison, IN, LIKE)")

    def _lower_arith(self, e: A.SBin, ctx: _ExprCtx) -> Expr:
        # date +/- INTERVAL folds at plan time (calendar arithmetic)
        for a, b in ((e.lhs, e.rhs), (e.rhs, e.lhs)):
            if isinstance(b, A.SInterval):
                if e.op not in ("add", "sub"):
                    raise SqlUnsupportedError(
                        f"INTERVAL with operator '{e.op}'")
                base = self.lower_expr(a, ctx)
                n = -b.n if e.op == "sub" else b.n
                if isinstance(base, Literal) and base.dtype is dt.DATE32:
                    return Literal(_shift_date(base.value, n, b.unit),
                                   dt.DATE32)
                if b.unit == "day":
                    return BinaryOp("add", base, Literal(int(n)))
                raise SqlUnsupportedError(
                    f"non-constant date +/- INTERVAL '{b.n}' {b.unit}")
        return BinaryOp(e.op, self.lower_expr(e.lhs, ctx),
                        self.lower_expr(e.rhs, ctx))

    def _lower_case(self, e: A.SCase, ctx: _ExprCtx) -> Expr:
        acc = (self.lower_expr(e.default, ctx)
               if e.default is not None else Literal(0))
        # first-match semantics: acc = cond*val + (!cond)*acc, right-to-left
        for cond_ast, val_ast in reversed(e.whens):
            c = self.lower_expr(cond_ast, ctx)
            v = self.lower_expr(val_ast, ctx)
            acc = BinaryOp("add",
                           BinaryOp("mul", c, v),
                           BinaryOp("mul", UnaryOp("not", c), acc))
        return acc

    def _lower_in(self, e: A.SIn, ctx: _ExprCtx) -> Expr:
        operand = self.lower_expr(e.operand, ctx)
        values = []
        for lit in e.values:
            values.append(self._encode_for(operand, lit, ctx,
                                           skip_missing=True))
        values = [v for v in values if v is not None]
        out: Expr = IsIn(operand, tuple(values))
        return UnaryOp("not", out) if e.negated else out

    def _lower_like(self, e: A.SLike, ctx: _ExprCtx) -> Expr:
        operand = self.lower_expr(e.operand, ctx)
        t = operand.out_dtype(ctx.schema)
        pattern = e.pattern
        if "_" in pattern:
            raise SqlUnsupportedError(
                f"LIKE wildcard '_' is not supported: {pattern!r}")
        if t.name == "dict32":
            rx = _like_regex(pattern)
            codes = tuple(i for i, v in enumerate(t.dictionary)
                          if rx.fullmatch(v))
            out: Expr = IsIn(operand, codes)
        elif t.name == "bytes":
            parts = pattern.split("%")
            if len(parts) >= 3 and parts[0] == "" and parts[-1] == "":
                out = BytesMatch(operand, tuple(p for p in parts if p),
                                 "contains")
            elif len(parts) == 2 and parts[1] == "" and parts[0]:
                out = BytesMatch(operand, (parts[0],), "startswith")
            elif len(parts) == 2 and parts[0] == "" and parts[1]:
                out = BytesMatch(operand, (parts[1],), "endswith")
            else:
                raise SqlUnsupportedError(
                    f"LIKE pattern {pattern!r} is not supported on "
                    f"bytes columns")
        else:
            raise SqlUnsupportedError(
                f"LIKE over a {t} column is not supported")
        return UnaryOp("not", out) if e.negated else out

    def _lower_cmp(self, op: str, lhs: A.SqlExpr, rhs: A.SqlExpr,
                   ctx: _ExprCtx) -> Expr:
        if isinstance(rhs, A.SLit) and not isinstance(lhs, A.SLit):
            return self._cmp_literal(op, self.lower_expr(lhs, ctx), rhs, ctx)
        if isinstance(lhs, A.SLit) and not isinstance(rhs, A.SLit):
            return self._cmp_literal(_CMP_FLIP[op],
                                     self.lower_expr(rhs, ctx), lhs, ctx)
        return BinaryOp(op, self.lower_expr(lhs, ctx),
                        self.lower_expr(rhs, ctx))

    def _cmp_literal(self, op: str, expr: Expr, lit: A.SLit,
                     ctx: _ExprCtx) -> Expr:
        encoded = self._encode_for(expr, lit, ctx, op=op)
        if isinstance(encoded, Expr):
            return encoded                       # fully folded predicate
        return BinaryOp(op, expr, Literal(encoded[0], encoded[1]))

    def _encode_for(self, expr: Expr, lit: A.SLit, ctx: _ExprCtx,
                    op: Optional[str] = None, skip_missing: bool = False):
        """Encode a literal for comparison against ``expr``.

        Returns ``(value, dtype)`` normally, a raw value for IN lists,
        ``None`` for IN-list members outside a dictionary domain, or a
        fully folded ``Expr`` when the comparison itself constant-folds
        (dictionary misses)."""
        if isinstance(expr, PrefixCode):
            if lit.kind == "str" and str(lit.value).isdigit():
                return (int(lit.value) if skip_missing
                        else (int(lit.value), dt.INT32))
            raise SqlUnsupportedError(
                f"SUBSTRING comparison needs a digit-string literal, "
                f"got {lit.value!r}")
        t = expr.out_dtype(ctx.schema)
        if t.name == "date32" and lit.kind in ("date", "str"):
            v = dt.date_to_i32(str(lit.value))
            return v if skip_missing else (v, dt.DATE32)
        if t.name == "dict32":
            if lit.kind != "str":
                raise SqlUnsupportedError(
                    f"comparing dictionary column with {lit.kind} literal")
            value = str(lit.value)
            if value in t.dictionary:
                code = t.dictionary.index(value)
                return code if skip_missing else (code, dt.INT32)
            if skip_missing:
                return None
            # dictionaries are sorted: fold against the insertion point
            pos = bisect.bisect_left(t.dictionary, value)
            if op == "eq":
                return IsIn(expr, ())
            if op == "ne":
                return UnaryOp("not", IsIn(expr, ()))
            if op in ("lt", "le"):
                return BinaryOp("lt", expr, Literal(pos))
            return BinaryOp("ge", expr, Literal(pos))
        if t.name == "bytes":
            raise SqlUnsupportedError(
                "comparison between a bytes column and a literal "
                "(use LIKE)")
        if lit.kind == "int":
            v = int(lit.value)
        elif lit.kind == "float":
            v = float(lit.value)
        elif lit.kind == "bool":
            v = bool(lit.value)
        else:
            raise SqlUnsupportedError(
                f"cannot compare {t} column with string literal "
                f"{lit.value!r}")
        return v if skip_missing else (v, None)


def _shift_date(days: int, n: int, unit: str) -> int:
    import datetime
    d = datetime.date(1970, 1, 1) + datetime.timedelta(days=int(days))
    if unit == "day":
        return days + n
    months = d.year * 12 + (d.month - 1) + (n * 12 if unit == "year" else n)
    y, m = divmod(months, 12)
    # clamp the day into the target month (SQL interval semantics)
    for day in (d.day, 30, 29, 28):
        try:
            return (datetime.date(y, m + 1, day)
                    - datetime.date(1970, 1, 1)).days
        except ValueError:
            continue
    raise AssertionError("unreachable")


def lower_sql(sql: str, catalog, session=None,
              dialect: Optional[str] = None) -> QueryBuilder:
    """Parse SQL text and lower it to a ``QueryBuilder``.

    ``dialect`` transpiles foreign SQL dialects to the engine's subset via
    the optional ``sqlglot`` dependency (the ``[sql]`` extra); without the
    package installed a dialect request fails loudly rather than guessing::

        q = lower_sql("SELECT count(*) AS n FROM orders", catalog)
        plan = q.optimized()

    Raises ``SqlParseError`` for invalid syntax, ``SqlUnsupportedError``
    for recognized-but-unexecutable constructs (naming the construct), and
    ``SchemaError`` for unknown tables/columns.
    """
    if dialect is not None:
        try:
            import sqlglot
        except ImportError as exc:
            raise SqlUnsupportedError(
                f"dialect={dialect!r} normalization needs the optional "
                f"'sqlglot' dependency (pip install 'repro[sql]')"
            ) from exc
        sql = sqlglot.transpile(sql, read=dialect, write="duckdb")[0]
    ast = A.parse(sql)
    builder = _Lowering(catalog, session).lower_select(ast, {})
    builder.sql_text = sql
    return builder
