"""Physical operators: device-resident analogues of the Velox operators the
paper replaces with cuDF versions (TableScan, FilterProject, HashJoin,
HashAggregation, OrderBy, Limit, ...).

Operators follow Velox's streaming contract:

    op.open()                       # acquire state
    out = op.add_input(batch)       # 0..n output batches, never blocks
    out = op.finish()               # flush blocking state at end of input

Per-batch device work is jitted; the operator object holds host-side state
between batches (the "driver thread" of Velox). Blocking operators (OrderBy,
final aggregation, join build) accumulate DeviceTables in *device* memory --
the paper's working-set-stays-on-device discipline.

Tables come in two layouts: local ``[cap, ...]`` and worker-stacked
``[W, cap, ...]`` (distributed execution; axis 0 = worker, sharded over the
mesh). The ``table_op`` decorator dispatches: stacked tables run the same
program per worker via vmap, so one operator implementation serves both the
single-GPU and the distributed paths (one Velox worker per GPU in the paper;
one vmap lane per mesh worker here).
"""

from __future__ import annotations

import functools
import itertools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from . import dtypes as dt
from . import fused
from . import relational as rel
from .expr import Expr
from .table import DeviceTable, concat_tables


# every table_op's compile cache, so long-lived processes (full-suite
# test sweeps, benchmark harnesses) can release accumulated executables
_OP_CACHES: list = []


def clear_compile_caches() -> None:
    """Drop every ``table_op`` compiled-program cache.

    The caches are unbounded by design (steady-state serving re-uses a
    small working set), but a process that runs many differently-shaped
    workloads back to back — e.g. a full TPC-H sweep at several scale
    factors — accumulates thousands of live XLA executables. Pair with
    ``jax.clear_caches()`` to actually release them."""
    for cache in _OP_CACHES:
        cache.cache_clear()


def table_op(n_tables: int = 1):
    """Wrap fn(*tables, *statics) with jit + optional worker-axis vmap.

    The compile cache additionally keys on the active kernel backend
    (``kernels.ops.current_backend``) and on the input tables' leaf
    shapes/dtypes: the traced program embeds the backend's dispatch
    decisions (Pallas kernels vs jnp, which can differ per dtype), so
    'jnp' and 'pallas' sessions never share a compilation and every cache
    entry corresponds to exactly one trace. Each entry remembers which
    kernels its trace used and replays them through
    ``kernels.ops.count_dispatch`` on every call, which is how the driver
    reports per-query ``kernel_dispatch`` counts.
    """

    def deco(fn):
        @functools.lru_cache(maxsize=None)
        def compiled(statics, stacked, spec, backend):
            del spec  # one cache entry (and used-set) per specialization
            body = lambda *tabs: fn(*tabs, *statics)
            used: set = set()
            return jax.jit(jax.vmap(body) if stacked else body), used

        _OP_CACHES.append(compiled)

        @functools.wraps(fn)
        def wrapper(*args):
            tables, statics = args[:n_tables], args[n_tables:]
            stacked = _is_stacked(tables[0])
            jitted, used = compiled(tuple(statics), stacked,
                                    _table_spec(tables),
                                    kernel_ops.current_backend())
            with kernel_ops.record_kernels(used):
                out = jitted(*tables)
            for kind in kernel_ops.kernel_snapshot(used):
                kernel_ops.count_dispatch(kind)
            return out

        wrapper.raw = fn
        return wrapper

    return deco


def _table_spec(tables) -> tuple:
    """Hashable (structure, leaf shape/dtype) description of the inputs —
    the same things jax.jit specializes a trace on, so each ``compiled``
    entry's recorded kernel set describes exactly the program that runs."""
    leaves, treedef = jax.tree.flatten(tables)
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _is_stacked(obj) -> bool:
    if isinstance(obj, DeviceTable):
        return obj.validity.ndim == 2
    # pytree containing tables (join build state)
    leaves = jax.tree.leaves(obj)
    return any(isinstance(t, DeviceTable) and t.validity.ndim == 2
               for t in jax.tree.leaves(obj, is_leaf=lambda x: isinstance(x, DeviceTable)))


# ---------------------------------------------------------------------------

class Operator:
    """Velox streaming-operator contract (see module docstring): ``open``,
    then ``add_input`` per batch, then ``finish`` to flush blocking state."""

    name = "operator"
    is_device = True     # has a "cuDF version" (device implementation)

    def open(self) -> None:
        """Acquire per-query state; called once before any input."""
        pass

    def add_input(self, batch: DeviceTable) -> List[DeviceTable]:
        """Consume one batch, return 0..n output batches (never blocks)."""
        raise NotImplementedError

    def finish(self) -> List[DeviceTable]:
        """Flush accumulated state at end of input (blocking operators)."""
        return []


# ---------------------------------------------------------------------------
# Pipeline: compose operators into one stage
# ---------------------------------------------------------------------------

class Pipeline(Operator):
    """Compose a list of operators into a single streaming stage.

    Used by the driver's ``StreamingScan`` to run the scan-fused chain
    (pushed-down filter, projections, ...) per morsel as each chunk arrives
    from the prefetch queue. ``finish`` flushes each operator in order,
    threading its flushed output through the operators downstream of it.
    """

    name = "Pipeline"

    def __init__(self, ops_: Sequence[Operator] = ()):
        self.ops: List[Operator] = list(ops_)

    def open(self):
        for op in self.ops:
            op.open()

    def add_input(self, batch):
        outs = [batch]
        for op in self.ops:
            outs = [o for b in outs for o in op.add_input(b)]
        return outs

    def finish(self):
        carry: List[DeviceTable] = []
        for op in self.ops:
            fed: List[DeviceTable] = []
            for b in carry:
                fed.extend(op.add_input(b))
            fed.extend(op.finish())
            carry = fed
        return carry


# ---------------------------------------------------------------------------
# FilterProject
# ---------------------------------------------------------------------------

@table_op()
def _filter_project(table: DeviceTable, filter_expr, projections, compact: bool):
    if filter_expr is not None:
        table = table.filter(filter_expr.evaluate(table))
    if projections is not None:
        cols, schema = {}, {}
        for out_name, e in projections:
            v = e.evaluate(table)
            if v.ndim == 0:   # literal: broadcast to rows
                v = jnp.broadcast_to(v, (table.capacity,))
            cols[out_name] = v
            schema[out_name] = e.out_dtype(table.schema)
        table = DeviceTable(cols, table.validity, schema)
    if compact:
        table = table.compact()
    return table


class FilterProject(Operator):
    """Fused filter + projection: one traced program = cuDF's AST path."""

    name = "FilterProject"

    def __init__(self, filter_expr: Optional[Expr] = None,
                 projections: Optional[Sequence[Tuple[str, Expr]]] = None,
                 compact: bool = False):
        self.filter_expr = filter_expr
        self.projections = tuple(projections) if projections is not None else None
        self.compact = compact

    def add_input(self, batch):
        return [_filter_project(batch, self.filter_expr, self.projections,
                                self.compact)]


# ---------------------------------------------------------------------------
# HashAggregation (partial / final / single) -- paper §3.2
# ---------------------------------------------------------------------------

AggSpec = Tuple[str, str, Optional[str]]   # (out_name, kind, in_column)
_MERGE_KIND = {"sum": "sum", "count": "sum", "min": "min", "max": "max",
               "first": "first"}


def lower_aggs(specs: Sequence[AggSpec]) -> Tuple[AggSpec, ...]:
    """avg -> sum+count for partial phases."""
    lowered: List[AggSpec] = []
    for out, kind, col_ in specs:
        if kind == "avg":
            lowered.append((f"{out}__sum", "sum", col_))
            lowered.append((f"{out}__cnt", "count", col_))
        else:
            lowered.append((out, kind, col_))
    return tuple(lowered)


def merge_specs(specs: Sequence[AggSpec]) -> Tuple[AggSpec, ...]:
    """Specs that merge partial outputs (count -> sum of counts, ...)."""
    return tuple((out, _MERGE_KIND[kind], out) for out, kind, _ in specs)


@table_op()
def _aggregate(table: DeviceTable, group_keys, specs, max_groups: int):
    key_cols = [table.columns[k] for k in group_keys]
    cols, schema = {}, {}
    if key_cols:
        g = rel.group_rows(key_cols, table.validity, max_groups)
        for k in group_keys:
            cols[k] = jnp.take(table.columns[k], g.key_rows, axis=0)
            schema[k] = table.schema[k]
        validity = g.group_valid
    else:
        validity = jnp.ones((1,), dtype=bool)
    for out, kind, col_ in specs:
        vals = (jnp.zeros(table.capacity, dtype=jnp.int32) if col_ is None
                else table.columns[col_])
        if kind == "first":
            # carry column: representative value per group (for functionally
            # dependent columns, e.g. group by custkey carrying c_name)
            if key_cols:
                cols[out] = jnp.take(vals, g.key_rows, axis=0)
            else:
                cols[out] = jnp.take(vals, jnp.argmax(table.validity), axis=0)[None]
            schema[out] = table.schema[col_]
            continue
        if key_cols:
            cols[out] = rel.segment_agg(vals, g.gids, g.order, table.validity,
                                        max_groups, kind)
        else:
            v = table.validity
            if kind == "count":
                cols[out] = jnp.sum(v.astype(jnp.int32))[None]
            elif kind == "sum":
                cols[out] = jnp.sum(jnp.where(v, vals, jnp.zeros((), vals.dtype)))[None]
            elif kind == "min":
                cols[out] = jnp.min(jnp.where(v, vals, rel._extreme(vals.dtype, 1)))[None]
            elif kind == "max":
                cols[out] = jnp.max(jnp.where(v, vals, rel._extreme(vals.dtype, -1)))[None]
            else:
                raise ValueError(kind)
        schema[out] = dt.INT32 if kind == "count" else table.schema[col_]
    return DeviceTable(cols, validity, schema)


@table_op()
def _finalize_avg(table: DeviceTable, user_specs):
    cols = dict(table.columns)
    schema = dict(table.schema)
    for out, kind, _ in user_specs:
        if kind == "avg":
            s = cols.pop(f"{out}__sum")
            c = cols.pop(f"{out}__cnt")
            cols[out] = s.astype(jnp.float32) / jnp.maximum(c, 1).astype(jnp.float32)
            schema.pop(f"{out}__sum"), schema.pop(f"{out}__cnt")
            schema[out] = dt.FLOAT32
    return DeviceTable(cols, table.validity, schema)


class HashAggregation(Operator):
    """Concatenation-based streaming aggregation (paper §3.2).

    cuDF has no streaming groupby, so the paper aggregates each batch,
    concatenates with the running partial result and re-aggregates until a
    size threshold triggers emission. Reproduced exactly: per-batch partial
    agg (sort-based on TPU), concat with the accumulator, re-aggregate.

    mode: 'partial'  emits partial columns (avg -> sum+cnt) for an exchange
          'final'    merges partial columns after an exchange
          'single'   complete aggregation in one operator
    """

    name = "HashAggregation"

    _spill_seq = itertools.count()

    def __init__(self, group_keys: Sequence[str], aggs: Sequence[AggSpec],
                 mode: str = "single", max_groups: int = 4096,
                 emit_rows: Optional[int] = None, spill=None,
                 spill_flush_groups: Optional[int] = None):
        assert mode in ("partial", "final", "single")
        self.group_keys = tuple(group_keys)
        self.user_specs = tuple(aggs)
        self.mode = mode
        lowered = lower_aggs(self.user_specs)
        self.specs = merge_specs(lowered) if mode == "final" else lowered
        self.max_groups = max_groups
        self.emit_rows = emit_rows
        # spill-aware mode (core.spill): with a SpillManager and a flush
        # threshold, the accumulator is flushed to the host tier whenever
        # its occupied groups reach the threshold (max_groups pressure);
        # finish() merges the flushed runs back in a final pass
        self.spill = spill
        self.spill_flush_groups = spill_flush_groups
        self._skey = f"agg{next(self._spill_seq)}"
        self._flushed: List[object] = []
        self._acc: Optional[DeviceTable] = None
        self._saw_input = False

    def open(self):
        self._acc = None
        self._flushed = []
        self._saw_input = False

    def add_input(self, batch):
        self._saw_input = True
        part = _aggregate(batch, self.group_keys, self.specs, self.max_groups)
        if self._acc is None:
            self._acc = part
        else:
            merged = concat_tables([self._acc, part])
            self._acc = _aggregate(merged, self.group_keys, merge_specs(self.specs),
                                   self.max_groups)
        if (self.spill is not None and self.spill_flush_groups is not None
                and int(self._acc.num_valid()) >= self.spill_flush_groups):
            key = (self._skey, len(self._flushed))
            self.spill.spill_table(key, self._acc)
            self._flushed.append(key)
            self._acc = None
            return []
        if (self.emit_rows is not None and self.mode == "partial"
                and int(self._acc.num_valid()) >= self.emit_rows):
            out, self._acc = self._acc, None
            return [out]
        return []

    def finish(self):
        if self._flushed:
            # final pass: restore the flushed runs one at a time and merge
            # each into the accumulator (device working set stays at two
            # max_groups tables regardless of how many runs spilled)
            acc = self._acc
            for key in self._flushed:
                run = self.spill.restore(key)
                if acc is None:
                    acc = run
                else:
                    acc = _aggregate(concat_tables([acc, run]),
                                     self.group_keys,
                                     merge_specs(self.specs), self.max_groups)
            self._acc, self._flushed = acc, []
        if self._acc is None:
            return []
        out, self._acc = self._acc, None
        if self.mode in ("final", "single"):
            out = _finalize_avg(out, self.user_specs)
        return [out]


class Distinct(Operator):
    """Row dedup on key columns (count(distinct ...) rewrites)."""

    name = "Distinct"

    def __init__(self, keys: Sequence[str], max_groups: int = 4096):
        self.keys = tuple(keys)
        self.max_groups = max_groups
        self.agg = HashAggregation(keys, [], "single", max_groups)

    def open(self):
        self.agg.open()

    def add_input(self, batch):
        return self.agg.add_input(batch.select(list(self.keys)))

    def finish(self):
        return self.agg.finish()


# ---------------------------------------------------------------------------
# HashJoin
# ---------------------------------------------------------------------------

# pallas probe eligibility: the open-addressing table must stay
# VMEM-resident (2^18 slots x 8 B = 2 MiB of a ~16 MiB core, leaving room
# for the probe blocks); larger builds fall back to the sorted-key path
MAX_HASH_TABLE_SLOTS = 1 << 18
EMPTY_KEY = -1


@table_op()
def _build_join_table(build: DeviceTable, build_keys):
    key, _ = rel.join_key([build.columns[k] for k in build_keys])
    return rel.join_build(key, build.validity)


def _join_probe_key(table: DeviceTable, key_names, pack):
    """Single-lane join key for the open-addressing table: the raw
    int-like column, or the injective composite pack. Packed keys are
    nonnegative by construction, so they can never alias the empty
    sentinel; out-of-range probe values map *to* the sentinel and are
    masked to no-match by the callers."""
    cols = [table.columns[k] for k in key_names]
    if pack is not None:
        return rel.packed_key(cols, pack, empty_key=EMPTY_KEY)
    key, _ = rel.join_key(cols)
    return key


@table_op()
def _build_hash_table(build: DeviceTable, build_keys, table_size: int, pack):
    key = _join_probe_key(build, build_keys, pack)
    rows = jnp.arange(key.shape[0], dtype=jnp.int32)
    return kernel_ops.build_table(key, rows, table_size,
                                  empty_key=EMPTY_KEY, valid=build.validity)


_PACKABLE_DTYPES = ("int32", "date32", "dict32")


def _derive_pack(build: DeviceTable, build_keys):
    """Host-side injective-pack windows for a composite int-like key.

    Returns ``((lo, span), ...)`` per key column — derived from the valid
    build rows' min/max (worker-stacked builds share one global window, a
    sound superset per worker) — or None when any column is not int-like
    or the windows' product overflows the int32 key lane. The resulting
    ``relational.packed_key`` is injective over in-window tuples, so no
    post-probe verification is needed; every valid build row is in-window
    by construction, and probe tuples outside any window pack to the empty
    sentinel (no build key can match them).
    """
    cols = []
    for k in build_keys:
        if build.schema[k].name not in _PACKABLE_DTYPES:
            return None
        cols.append(np.asarray(build.columns[k]).reshape(-1))
    valid = np.asarray(build.validity).reshape(-1)
    pack, prod = [], 1
    for c in cols:
        vals = c[valid]
        lo = int(vals.min()) if vals.size else 0
        span = int(vals.max()) - lo + 1 if vals.size else 1
        prod *= span
        if prod > np.iinfo(np.int32).max:
            return None
        pack.append((lo, span))
    return tuple(pack)


def _probe_bound(table_keys: np.ndarray) -> int:
    """Sound ``max_probes`` for a built table: the longest circular run of
    occupied slots + 1 (a linear probe terminates at the first empty slot),
    rounded up to a power of two so the static argument stays stable
    across similarly loaded tables."""
    occ = (np.asarray(table_keys) != EMPTY_KEY).reshape(
        -1, table_keys.shape[-1])
    t = occ.shape[-1]
    longest = 0
    for row in occ:
        if row.all():
            longest = max(longest, t)
            continue
        if not row.any():
            continue
        # rotate a free slot to the end so runs never wrap the boundary
        row = np.roll(row, t - 1 - int(np.where(~row)[0][-1]))
        edges = np.diff(np.concatenate(([0], row.astype(np.int8), [0])))
        starts, ends = np.where(edges == 1)[0], np.where(edges == -1)[0]
        longest = max(longest, int((ends - starts).max()))
    return min(int(2 ** np.ceil(np.log2(max(longest + 1, 2)))), t)


def _attach_build_payload(probe: DeviceTable, build: DeviceTable, found,
                          bidx, build_payload, join_type: str) -> DeviceTable:
    """Single-match output assembly (output row i is probe row i), shared
    by the standalone ``hash_probe`` path and the fused morsel kernel:
    semi/anti filter on membership, inner/left_outer gather the build
    payload by matched row (left_outer zero-fills unmatched rows and
    carries ``__matched``, matching the jnp path)."""
    if join_type == "left_semi":
        return probe.filter(found)
    if join_type == "left_anti":
        return probe.filter(probe.validity & ~found)

    safe = jnp.where(found, bidx, 0)
    cols = dict(probe.columns)
    schema = dict(probe.schema)
    for n in build_payload:
        v = jnp.take(build.columns[n], safe, axis=0)
        if join_type == "left_outer":
            mask = found.reshape(found.shape + (1,) * (v.ndim - 1))
            v = jnp.where(mask, v, jnp.zeros((), v.dtype))
        cols[n] = v
        schema[n] = build.schema[n]
    if join_type == "left_outer":
        cols["__matched"] = found
        schema["__matched"] = dt.BOOL
        return DeviceTable(cols, probe.validity, schema)
    return DeviceTable(cols, found, schema)


@table_op(n_tables=2)
def _probe_join_pallas(probe: DeviceTable, hash_state, probe_keys,
                       build_payload, join_type: str, max_probes: int, pack):
    """Open-addressing probe (Pallas ``hash_probe``): one table lookup per
    probe row. Reached for exact int-like keys (single, or composite via
    the injective ``pack``) against a build side the planner proved unique
    (``max_matches == 1``) or for semi/anti joins, where membership alone
    decides; output row i is probe row i."""
    build, tk, tv = hash_state
    key = _join_probe_key(probe, probe_keys, pack)
    found, bidx = kernel_ops.hash_probe(tk, tv, key, empty_key=EMPTY_KEY,
                                        max_probes=max_probes)
    # a probe key equal to the empty sentinel reads an empty slot as a hit;
    # no such key occupies the table (seal_build falls back if a valid
    # build key is EMPTY_KEY, and packed keys are nonnegative), so masking
    # it is exact
    found = found & probe.validity & (key != EMPTY_KEY)
    return _attach_build_payload(probe, build, found, bidx, build_payload,
                                 join_type)


@table_op(n_tables=2)
def _probe_join_pallas_multi(probe: DeviceTable, hash_state, probe_keys,
                             build_payload, join_type: str, max_probes: int,
                             max_matches: int, pack):
    """Expansion probe (Pallas ``hash_probe_multi``): probe row i owns
    output rows [i*m, (i+1)*m), the same static-capacity layout as the jnp
    ``relational.join_probe`` path, so downstream compaction and the
    oracle agree bit-for-bit. Matches surface in build-row order (the
    cooperative build places duplicate keys along the run in ascending row
    index), mirroring the sorted-key oracle's emission order."""
    build, tk, tv = hash_state
    key = _join_probe_key(probe, probe_keys, pack)
    count, slots = kernel_ops.hash_probe_multi(
        tk, tv, key, max_matches, empty_key=EMPTY_KEY, max_probes=max_probes)
    # sentinel mask, as in the single-match probe: an empty slot compares
    # equal to a sentinel probe key and would report one bogus match
    live = probe.validity & (key != EMPTY_KEY)
    count = jnp.where(live, count, 0)
    p = key.shape[0]
    j = jnp.arange(p * max_matches, dtype=jnp.int32)
    probe_idx = j // max_matches
    valid = (j % max_matches) < jnp.take(count, probe_idx)
    build_idx = slots.reshape(-1)        # garbage past count; masked by valid
    return _expand_join_output(probe, build, probe_idx, build_idx, valid,
                               build_payload, join_type)


def _expand_join_output(probe: DeviceTable, build_table: DeviceTable,
                        probe_idx, build_idx, valid, build_payload,
                        join_type: str) -> DeviceTable:
    """Expansion-layout output assembly shared by the jnp ``_probe_join``
    tail and the Pallas expansion probe: scatter-max membership for
    semi/anti, gather both sides for inner, append unmatched probe rows
    for left_outer."""
    if join_type in ("left_semi", "left_anti"):
        hit = jnp.zeros(probe.capacity, dtype=jnp.int32)
        hit = hit.at[probe_idx].max(valid.astype(jnp.int32))
        mask = probe.validity & (hit > 0)
        if join_type == "left_anti":
            mask = probe.validity & ~mask
        return probe.filter(mask)

    cols, schema = {}, {}
    for n in probe.column_names:
        cols[n] = jnp.take(probe.columns[n], probe_idx, axis=0)
        schema[n] = probe.schema[n]
    for n in build_payload:
        cols[n] = jnp.take(build_table.columns[n], build_idx, axis=0)
        schema[n] = build_table.schema[n]
    out_valid = valid

    if join_type == "left_outer":
        # append unmatched probe rows with zeroed build payload + match flag
        hit = jnp.zeros(probe.capacity, dtype=jnp.int32)
        hit = hit.at[probe_idx].max(valid.astype(jnp.int32))
        unmatched = probe.validity & (hit == 0)
        for n in probe.column_names:
            cols[n] = jnp.concatenate([cols[n], probe.columns[n]], axis=0)
        for n in build_payload:
            shape = (probe.capacity,) + cols[n].shape[1:]
            cols[n] = jnp.concatenate([cols[n], jnp.zeros(shape, cols[n].dtype)],
                                      axis=0)
        out_valid = jnp.concatenate([out_valid, unmatched], axis=0)
        cols["__matched"] = jnp.concatenate(
            [valid, jnp.zeros(probe.capacity, bool)])
        schema["__matched"] = dt.BOOL
    return DeviceTable(cols, out_valid, schema)


@table_op(n_tables=2)
def _probe_join(probe: DeviceTable, build_state, probe_keys, build_keys,
                build_payload, join_type: str, max_matches: int, exact: bool):
    build_table, bt = build_state
    key, _ = rel.join_key([probe.columns[k] for k in probe_keys])

    if join_type in ("left_semi", "left_anti") and exact:
        mask = rel.semi_mask(bt, key, probe.validity)
        if join_type == "left_anti":
            mask = probe.validity & ~mask
        return probe.filter(mask)

    res = rel.join_probe(bt, key, probe.validity, max_matches)
    valid = res.valid
    if not exact:   # hashed keys: verify true equality (bucket-then-verify)
        for pk, bk in zip(probe_keys, build_keys):
            pv = jnp.take(probe.columns[pk], res.probe_idx, axis=0)
            bv = jnp.take(build_table.columns[bk], res.build_idx, axis=0)
            eq = jnp.all(pv == bv, axis=-1) if pv.ndim > 1 else (pv == bv)
            valid = valid & eq
    return _expand_join_output(probe, build_table, res.probe_idx,
                               res.build_idx, valid, build_payload, join_type)


class HashJoin(Operator):
    """Streaming probe against a fully materialized build side.

    TPU adaptation of cuDF's hash join, with a per-session kernel backend
    (``kernels.ops.current_backend()``, sampled at ``seal_build``):

    * 'jnp'    -- the build side becomes a sorted key array probed with
                  searchsorted (doubles as the oracle);
    * 'pallas' -- exact int-like keys build an open-addressing table
                  (``kernels.build_table``, power-of-two slots sized 2x
                  the planner's ``build_rows`` bound). Composite int-like
                  keys pack injectively into one int32 lane when their
                  value windows fit (``_derive_pack``). Semi/anti and
                  ``max_matches == 1`` joins probe with ``hash_probe``;
                  expansion joins probe with ``hash_probe_multi`` (static
                  ``P x max_matches`` output, same layout as the jnp
                  path). Non-integer keys, unpackably wide composites,
                  build keys equal to the empty sentinel (-1) and
                  oversized builds fall back to the jnp path; probe keys
                  equal to the sentinel are masked to no-match (no such
                  key can occupy the table).

    Hashed multi-column keys on the jnp path are verified after the probe,
    as in a bucketed hash join (packed composites need no verification —
    the pack is injective). ``max_matches`` is the planner's
    expansion-capacity hint; the oracle tests assert it is never exceeded.
    """

    name = "HashJoin"

    def __init__(self, build_keys: Sequence[str], probe_keys: Sequence[str],
                 build_payload: Sequence[str] = (), join_type: str = "inner",
                 max_matches: int = 1, compact: bool = True,
                 build_rows: Optional[int] = None):
        assert join_type in ("inner", "left_semi", "left_anti", "left_outer")
        self.build_keys = tuple(build_keys)
        self.probe_keys = tuple(probe_keys)
        self.build_payload = tuple(build_payload)
        self.join_type = join_type
        self.max_matches = max_matches
        self.compact = compact
        self.build_rows = build_rows     # planner's build-side row bound
        self._build_batches: List[DeviceTable] = []
        self._state = None
        self._hash_state = None          # (build, table_keys, table_vals)
        self._max_probes = 0
        self._exact = True
        self._pack = None                # composite-key windows, or None
        self._multi = False              # expansion probe (hash_probe_multi)

    # build side is fed by the driver before probing starts
    def add_build(self, batch: DeviceTable):
        """Accumulate one build-side batch (device-resident)."""
        self._build_batches.append(batch)

    def _try_pallas_build(self, build: DeviceTable, pack) -> bool:
        """Build the open-addressing table; False -> jnp fallback."""
        cap = int(build.validity.shape[-1])
        bound = min(self.build_rows or cap, cap)
        table_size = max(int(2 ** np.ceil(np.log2(max(2 * bound, 2)))), 2)
        if table_size > MAX_HASH_TABLE_SLOTS:
            return False
        tk, tv = _build_hash_table(build, self.build_keys, table_size, pack)
        tk_host = np.asarray(tk)
        # every valid build row must occupy a slot: a shortfall means a key
        # collided with the empty sentinel (e.g. a -1 key) -- probing that
        # table would silently drop its matches
        if int((tk_host != EMPTY_KEY).sum()) != int(
                np.asarray(build.validity).sum()):
            return False
        self._hash_state = (build, tk, tv)
        self._max_probes = _probe_bound(tk_host)
        return True

    def seal_build(self):
        """Concatenate the build side and build the probe state (sorted
        keys, or the open-addressing table under the pallas backend);
        probing may start after."""
        assert self._build_batches, "join build side is empty"
        build = concat_tables(self._build_batches)
        self._build_batches = []
        kt = [build.schema[k] for k in self.build_keys]
        self._exact = (len(kt) == 1 and kt[0].name in _PACKABLE_DTYPES)
        if kernel_ops.current_backend() == "pallas":
            pack = None
            key_ok = self._exact
            if not key_ok and len(kt) >= 2:
                # composite int-like keys: try the injective single-lane
                # pack (a host-side range derivation — the same host sync
                # the occupancy check below performs anyway)
                pack = _derive_pack(build, self.build_keys)
                key_ok = pack is not None
            if key_ok and self._try_pallas_build(build, pack):
                self._pack = pack
                self._multi = not (self.join_type in ("left_semi",
                                                      "left_anti")
                                   or self.max_matches == 1)
                return
            # probe wanted a hash kernel but couldn't take it (non-integer
            # or unpackably wide composite key, sentinel-colliding key, or
            # a build_rows bound past the table's slot budget). Counted
            # once per sealed build so the adaptive suite can assert warm
            # re-plans with tighter bounds shrink it.
            kernel_ops.count_dispatch("fallback_probe")
        bt = _build_join_table(build, self.build_keys)
        self._state = (build, bt)

    def add_input(self, batch):
        if self._hash_state is not None:
            if self._multi:
                out = _probe_join_pallas_multi(
                    batch, self._hash_state, self.probe_keys,
                    self.build_payload, self.join_type, self._max_probes,
                    self.max_matches, self._pack)
                if (self.compact
                        and self.join_type in ("inner", "left_outer")):
                    out = compact_table(out)
                return [out]
            return [_probe_join_pallas(batch, self._hash_state,
                                       self.probe_keys, self.build_payload,
                                       self.join_type, self._max_probes,
                                       self._pack)]
        assert self._state is not None, "probe before build sealed"
        out = _probe_join(batch, self._state, self.probe_keys, self.build_keys,
                          self.build_payload, self.join_type, self.max_matches,
                          self._exact)
        if (self.compact and self.join_type in ("inner", "left_outer")
                and self.max_matches > 1):
            out = compact_table(out)
        return [out]


# ---------------------------------------------------------------------------
# GraceHashJoin (spill-aware out-of-core join over core.spill)
# ---------------------------------------------------------------------------

def _pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(int(n), 1)))), 0)


@table_op()
def _grace_pids(table: DeviceTable, keys, num_parts: int):
    """Radix-partition ids for grace-join fan-out — the exchange's
    partitioner (``rel.partition_ids``) with its metadata histogram
    (``radix_histogram`` under the pallas backend, one-hot sum as the jnp
    oracle)."""
    pids = rel.partition_ids([table.columns[k] for k in keys],
                             table.validity, num_parts)
    if kernel_ops.current_backend() == "pallas":
        masked = jnp.where(table.validity, pids,
                           jnp.asarray(num_parts, jnp.int32))
        counts = kernel_ops.radix_histogram(masked, num_parts)
    else:
        onehot = jax.nn.one_hot(pids, num_parts, dtype=jnp.int32)
        counts = jnp.sum(onehot * table.validity[..., None].astype(jnp.int32),
                         axis=-2)
    return pids, counts


def _split_host_partitions(table: DeviceTable, pids, num_parts: int):
    """Pull a (possibly worker-stacked) table to host and slice it into
    ``num_parts`` compacted partitions. Returns ``(columns, validity,
    valid_rows)`` per partition; capacities round up to powers of two so
    similarly sized partitions share compiled probe programs."""
    cols = {n: np.asarray(a) for n, a in table.columns.items()}
    valid = np.asarray(table.validity)
    pid = np.asarray(pids)
    stacked = valid.ndim == 2
    if not stacked:
        valid, pid = valid[None], pid[None]
        cols = {n: a[None] for n, a in cols.items()}
    w = valid.shape[0]
    parts = []
    for p in range(num_parts):
        sel = [np.nonzero(valid[i] & (pid[i] == p))[0] for i in range(w)]
        cap = _pow2(max(max((len(s) for s in sel), default=0), 1))
        validity = np.zeros((w, cap), dtype=bool)
        out = {}
        for n, a in cols.items():
            buf = np.zeros((w, cap) + a.shape[2:], dtype=a.dtype)
            for i, s in enumerate(sel):
                buf[i, : len(s)] = a[i][s]
            out[n] = buf
        for i, s in enumerate(sel):
            validity[i, : len(s)] = True
        if not stacked:
            out = {n: b[0] for n, b in out.items()}
            validity = validity[0]
        parts.append((out, validity, int(sum(len(s) for s in sel))))
    return parts


def _one_row_invalid(table: DeviceTable) -> DeviceTable:
    """A capacity-1, zero-valid-rows table with ``table``'s schema and
    layout (worker-stacked or local)."""
    cols = {n: a[..., :1, :] if table.schema[n].name == "bytes"
            else a[..., :1] for n, a in table.columns.items()}
    return DeviceTable({n: jnp.asarray(a) for n, a in cols.items()},
                       jnp.zeros_like(table.validity[..., :1]),
                       dict(table.schema))


class GraceHashJoin(Operator):
    """Grace-style partitioned hash join over the spill hierarchy.

    Used by the driver when a join's build side does not fit its device
    reservation (``core.spill.SpillManager``). Both sides are
    radix-partitioned on the join-key hash — the same partitioner the
    exchange uses for its metadata phase — so matching rows always land in
    the same partition and each pair joins independently:

    * ``seal_build`` partitions the materialized build side; partitions
      stay device-resident until the reservation is half used, the rest
      spill (host buffers, then paged disk pages as the host tier fills).
    * ``add_input`` partitions each probe batch and stages every slice in
      the spill store (fully blocking — like the classic grace join's
      pass 1).
    * ``finish`` processes partition pairs one at a time: restore one
      build partition, build its hash table (inheriting ``HashJoin``'s
      backend dispatch, so the pallas open-addressing path still applies
      per partition), replay its staged probe slices, emit the outputs.

    Inner/semi/anti/outer joins all stay correct per partition because a
    probe row's matches can only live in its own hash partition.
    """

    name = "GraceHashJoin"
    _seq = itertools.count()

    def __init__(self, build_keys: Sequence[str], probe_keys: Sequence[str],
                 build_payload: Sequence[str] = (), join_type: str = "inner",
                 max_matches: int = 1, compact: bool = True,
                 build_rows: Optional[int] = None, *, spill,
                 reservation: int, num_partitions: Optional[int] = None):
        self.build_keys = tuple(build_keys)
        self.probe_keys = tuple(probe_keys)
        self.build_payload = tuple(build_payload)
        self.join_type = join_type
        self.max_matches = max_matches
        self.compact = compact
        self.build_rows = build_rows
        self.spill = spill
        self.reservation = max(int(reservation), 1)
        self.num_partitions = num_partitions
        self._skey = f"grace{next(self._seq)}"
        self._build_batches: List[DeviceTable] = []
        self._resident: dict = {}        # partition -> DeviceTable (device tier)
        self._spilled_build: set = set()
        self._build_rows_by_part: dict = {}
        self._probe_chunks: dict = {}    # partition -> staged chunk count
        self._build_schema: Optional[dict] = None
        # one-row all-invalid prototypes: when every staged slice is empty
        # (or nothing matches), finish() still emits one correctly-shaped
        # output batch so downstream operators see the join's schema
        self._build_proto: Optional[DeviceTable] = None
        self._probe_proto: Optional[DeviceTable] = None

    def add_build(self, batch: DeviceTable):
        """Accumulate one build-side batch (device-resident until seal)."""
        self._build_batches.append(batch)

    def seal_build(self):
        """Radix-partition the build side; spill partitions past the
        reservation. Probing may start after."""
        assert self._build_batches, "join build side is empty"
        build = concat_tables(self._build_batches)
        self._build_batches = []
        self._build_schema = dict(build.schema)
        self._build_proto = _one_row_invalid(build)
        if self.num_partitions is None:
            # fan out until one partition (+ its probe slice and hash
            # state) fits about half the reservation
            want = -(-2 * build.nbytes() // self.reservation)
            self.num_partitions = max(min(_pow2(want), 64), 2)
        pids, _ = _grace_pids(build, self.build_keys, self.num_partitions)
        parts = _split_host_partitions(build, pids, self.num_partitions)
        resident_budget = self.reservation // 2
        used = 0
        for p, (cols, validity, rows) in enumerate(parts):
            self._build_rows_by_part[p] = rows
            nbytes = validity.nbytes + sum(a.nbytes for a in cols.values())
            if used + nbytes <= resident_budget:
                used += nbytes
                self._resident[p] = DeviceTable(
                    {n: jnp.asarray(a) for n, a in cols.items()},
                    jnp.asarray(validity), dict(self._build_schema))
            else:
                self.spill.put_host((self._skey, "build", p), cols, validity,
                                    self._build_schema)
                self._spilled_build.add(p)

    def add_input(self, batch):
        assert self._build_schema is not None, "probe before build sealed"
        if self._probe_proto is None:
            self._probe_proto = _one_row_invalid(batch)
        pids, _ = _grace_pids(batch, self.probe_keys, self.num_partitions)
        for p, (cols, validity, rows) in enumerate(
                _split_host_partitions(batch, pids, self.num_partitions)):
            if rows == 0:
                continue
            i = self._probe_chunks.get(p, 0)
            self.spill.put_host((self._skey, "probe", p, i), cols, validity,
                                dict(batch.schema))
            self._probe_chunks[p] = i + 1
        return []

    def finish(self):
        outs: List[DeviceTable] = []
        for p in range(self.num_partitions):
            chunks = self._probe_chunks.pop(p, 0)
            if chunks == 0:
                # no probe rows hashed here: nothing can match; discard
                self._resident.pop(p, None)
                if p in self._spilled_build:
                    self.spill.drop((self._skey, "build", p))
                continue
            if p in self._resident:
                build = self._resident.pop(p)
            else:
                build = self.spill.restore((self._skey, "build", p))
            inner = HashJoin(self.build_keys, self.probe_keys,
                             self.build_payload, self.join_type,
                             self.max_matches, compact=self.compact,
                             build_rows=max(self._build_rows_by_part[p], 1))
            inner.open()
            inner.add_build(build)
            inner.seal_build()
            for i in range(chunks):
                chunk = self.spill.restore((self._skey, "probe", p, i))
                outs.extend(inner.add_input(chunk))
            outs.extend(inner.finish())
        if not outs and self._probe_proto is not None:
            # every probe slice was empty (e.g. a selective build filter
            # upstream): emit one all-invalid batch with the join's output
            # schema so the stream stays alive for downstream operators
            inner = HashJoin(self.build_keys, self.probe_keys,
                             self.build_payload, self.join_type,
                             self.max_matches, compact=self.compact,
                             build_rows=1)
            inner.open()
            inner.add_build(self._build_proto)
            inner.seal_build()
            outs.extend(inner.add_input(self._probe_proto))
            outs.extend(inner.finish())
        return outs


@table_op()
def _compact(table: DeviceTable):
    return table.compact()


def compact_table(table: DeviceTable) -> DeviceTable:
    """Stream-compact a (possibly worker-stacked) table (paper 3.3.2)."""
    return _compact(table)


# ---------------------------------------------------------------------------
# FusedMorsel: one Pallas dispatch per morsel (filter -> project -> probe)
# ---------------------------------------------------------------------------

@table_op()
def _fused_morsel(table: DeviceTable, stages):
    out, _, _ = fused.fused_morsel_program(table, stages)
    return out


@table_op(n_tables=2)
def _fused_morsel_probe(table: DeviceTable, hash_state, stages, probe_keys,
                        build_payload, join_type: str, max_probes: int, pack):
    build, tk, tv = hash_state
    out, found, bidx = fused.fused_morsel_program(
        table, stages,
        probe=dict(tk=tk, tv=tv, probe_keys=probe_keys, pack=pack,
                   empty_key=EMPTY_KEY, max_probes=max_probes))
    return _attach_build_payload(out, build, found, bidx, build_payload,
                                 join_type)


class FusedMorsel(Operator):
    """A collapsed run of FilterProject stages — optionally ending in a
    single-match open-addressing probe — executed as one Pallas kernel per
    morsel (``core.fused``). Created by ``fuse_morsel_pipeline``; never
    built by the planner directly."""

    name = "FusedMorsel"

    def __init__(self, stages, join: Optional[HashJoin] = None):
        self.stages = tuple(stages)
        self.join = join

    def add_input(self, batch):
        if self.join is None:
            return [_fused_morsel(batch, self.stages)]
        j = self.join
        return [_fused_morsel_probe(batch, j._hash_state, self.stages,
                                    j.probe_keys, j.build_payload,
                                    j.join_type, j._max_probes, j._pack)]


def fuse_morsel_pipeline(pipe: Pipeline) -> None:
    """Collapse the scan pipeline's runs of non-compacting FilterProjects
    (optionally ending in an eligible single-match pallas HashJoin probe)
    into ``FusedMorsel`` operators — one Pallas dispatch per morsel
    instead of one per primitive, with no intermediate morsel
    materialization. Called by the driver's ``StreamingScan`` at iteration
    start, inside the query's backend scope; no-op under the jnp backend.

    A lone FilterProject stays unfused (same dispatch count either way);
    expansion probes, jnp-state joins and compacting stages keep their
    standalone operators.
    """
    if kernel_ops.current_backend() != "pallas":
        return
    new_ops: List[Operator] = []
    run: List[FilterProject] = []

    def stages():
        return [(fp.filter_expr, fp.projections) for fp in run]

    def flush():
        if len(run) >= 2:
            new_ops.append(FusedMorsel(stages()))
        else:
            new_ops.extend(run)
        run.clear()

    for op in pipe.ops:
        if isinstance(op, FilterProject) and not op.compact:
            run.append(op)
        elif (isinstance(op, HashJoin) and run
                and op._hash_state is not None and not op._multi):
            new_ops.append(FusedMorsel(stages(), join=op))
            run.clear()
        else:
            flush()
            new_ops.append(op)
    flush()
    pipe.ops = new_ops


@table_op()
def _head(table: DeviceTable, n: int):
    c = table.compact()
    return c.filter(jnp.arange(c.capacity) < n)


# ---------------------------------------------------------------------------
# OrderBy / Limit
# ---------------------------------------------------------------------------

@table_op()
def _order_by(table: DeviceTable, keys, descending, limit):
    order = rel.lexsort([table.columns[k] for k in keys], table.validity,
                        list(descending))
    n = table.capacity if limit is None else min(limit, table.capacity)
    idx = order[:n]
    nvalid = table.num_valid()
    keep = jnp.arange(n) < nvalid
    return table.gather(idx, keep)


class OrderBy(Operator):
    """Blocking global sort (optional top-``limit``); accumulates batches
    in device memory and sorts once at ``finish``."""

    name = "OrderBy"

    def __init__(self, keys: Sequence[str], descending: Sequence[bool] = None,
                 limit: Optional[int] = None):
        self.keys = tuple(keys)
        self.descending = tuple(descending or [False] * len(self.keys))
        self.limit = limit
        self._batches: List[DeviceTable] = []

    def open(self):
        self._batches = []

    def add_input(self, batch):
        self._batches.append(batch)     # device-resident accumulation
        return []

    def finish(self):
        table = concat_tables(self._batches)
        self._batches = []
        return [_order_by(table, self.keys, self.descending, self.limit)]


class Limit(Operator):
    """First ``n`` valid rows (blocking: concatenates, then truncates)."""

    name = "Limit"

    def __init__(self, n: int):
        self.n = n
        self._batches: List[DeviceTable] = []

    def open(self):
        self._batches = []

    def add_input(self, batch):
        self._batches.append(batch)
        return []

    def finish(self):
        table = concat_tables(self._batches)
        self._batches = []
        return [_head(table, self.n)]


# ---------------------------------------------------------------------------
# Scalar broadcast (uncorrelated scalar subqueries: Q11, Q15, Q22)
# ---------------------------------------------------------------------------

@table_op(n_tables=2)
def _attach_scalar(batch: DeviceTable, scalar: DeviceTable, columns):
    s = scalar.compact()
    out = batch
    for n in columns:
        v = s.columns[n][0]
        out = out.with_column(n, jnp.broadcast_to(v, (batch.capacity,)),
                              s.schema[n])
    return out


class ScalarBroadcast(Operator):
    """Attach the single row of a materialized table to every input row."""

    name = "ScalarBroadcast"

    def __init__(self, columns: Sequence[str]):
        self.columns = tuple(columns)
        self._scalar: Optional[DeviceTable] = None

    def set_scalar(self, table: DeviceTable):
        """Provide the materialized 1-row table to attach."""
        self._scalar = table

    def add_input(self, batch):
        assert self._scalar is not None
        return [_attach_scalar(batch, self._scalar, self.columns)]


# ---------------------------------------------------------------------------
# Host/device conversions (CudfToVelox / CudfFromVelox analogues)
# ---------------------------------------------------------------------------

class HostRoundTrip(Operator):
    """D2H + H2D conversion pair around a host-only operator.

    The paper inserts CudfToVelox/CudfFromVelox when a pipeline contains an
    operator without a GPU version; this models that round trip so its cost
    is measurable. ``stats`` accumulates staged bytes.
    """

    name = "HostRoundTrip"
    is_device = False

    def __init__(self, stats: Optional[dict] = None):
        self.stats = stats if stats is not None else {}

    def add_input(self, batch):
        import numpy as np
        host_cols = {n: np.asarray(a) for n, a in batch.columns.items()}
        validity = np.asarray(batch.validity)          # device -> host
        nbytes = sum(a.nbytes for a in host_cols.values()) + validity.nbytes
        self.stats["bytes"] = self.stats.get("bytes", 0) + 2 * nbytes
        cols = {n: jnp.asarray(a) for n, a in host_cols.items()}   # host -> device
        return [DeviceTable(cols, jnp.asarray(validity), batch.schema)]
