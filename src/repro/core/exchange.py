"""Inter-worker data exchange: the paper's central contribution, on TPU.

Distributed state convention: a *worker-stacked* DeviceTable has arrays of
shape [W, cap, ...] — axis 0 is the worker axis, sharded over the mesh's
``workers`` axis when a mesh is present. Each worker owns one [cap, ...]
slice, exactly like one Presto-native worker owns one GPU in the paper.

Two protocols, mirroring the paper's HttpExchange vs UcxExchange contrast:

* ``HostExchange``  — the HttpExchange analogue. Every cross-worker transfer
  is staged through host memory: device→host copy, host-side partitioning,
  page serialization (request/response pages of a configured size), then
  host→device copy. This is what the paper measures as the CPU-staging
  bottleneck.

* ``ICIExchange``   — the UcxExchange analogue. Repartitioning happens
  entirely on device: a metadata phase (per-partition row counts — the
  paper's "metadata first to determine allocation size" rendezvous
  handshake) sizes the receive buffers; the data phase is a single XLA
  program whose worker-axis transpose lowers to an all-to-all over ICI.
  Data never leaves device memory.

Both implement vector compaction (merge small batches before transmission;
paper §3.3.2) and count-based flow control.
"""

from __future__ import annotations

import dataclasses
import functools
import pickle
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops as kernel_ops
from . import relational as rel
from .table import DeviceTable


@dataclasses.dataclass
class ExchangeStats:
    """Counters for one exchange protocol instance (rounds, rows/bytes
    moved, and -- for the host-staged baseline -- bytes through host)."""

    rounds: int = 0
    rows_moved: int = 0
    bytes_moved: int = 0            # payload bytes that crossed the exchange
    host_staged_bytes: int = 0      # bytes that round-tripped through host
    seconds: float = 0.0

    def reset(self):
        """Zero all counters (benchmarks reuse one protocol instance)."""
        self.rounds = self.rows_moved = self.bytes_moved = 0
        self.host_staged_bytes = 0
        self.seconds = 0.0


def _hash32_np(x: np.ndarray) -> np.ndarray:
    """numpy mirror of relational.hash32 (host-side partitioning for the
    HttpExchange baseline, which partitions on the CPU)."""
    x = x.astype(np.uint32)
    x ^= x >> np.uint32(16)
    x *= np.uint32(0x85EBCA6B)
    x ^= x >> np.uint32(13)
    x *= np.uint32(0xC2B2AE35)
    x ^= x >> np.uint32(16)
    return (x & np.uint32(0x7FFFFFFE)).astype(np.int32)


def _hash_combine_np(cols) -> np.ndarray:
    n = np.asarray(cols[0]).shape[0]
    h = np.zeros((n,), dtype=np.uint32)

    def mix(h, c):
        hc = _hash32_np(np.asarray(c, dtype=np.int32)).astype(np.uint32)
        return h ^ (hc + np.uint32(0x9E3779B9) + (h << np.uint32(6))
                    + (h >> np.uint32(2)))

    for c in cols:
        c = np.asarray(c)
        if c.ndim == 2:   # bytes column: fold byte lanes (mirrors jnp path)
            folded = np.zeros((n,), dtype=np.uint32)
            for j in range(c.shape[1]):
                folded = folded * np.uint32(31) + c[:, j].astype(np.uint32)
            h = mix(h, folded)
        else:
            h = mix(h, c)
    return (h & np.uint32(0x7FFFFFFE)).astype(np.int32)


def _row_bytes(table: DeviceTable) -> int:
    per_row = 1  # validity byte
    for name, arr in table.columns.items():
        width = int(np.prod(arr.shape[2:])) if arr.ndim > 2 else 1
        per_row += arr.dtype.itemsize * width
    return per_row


# ---------------------------------------------------------------------------
# device-side partitioning programs (shared by both protocols' accounting)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _partition_counts(table: DeviceTable, key_names, num_workers: int,
                      backend: str = "jnp"):
    """Metadata phase: rows each src worker holds for each dst partition.

    Under the 'pallas' kernel backend the per-worker histogram is the
    ``radix_histogram`` MXU kernel (invalid rows masked to the dropped
    ``num_workers`` bin); the jnp one-hot sum is its oracle."""

    def per_worker(t: DeviceTable):
        pids = rel.partition_ids([t.columns[k] for k in key_names],
                                 t.validity, num_workers)
        if backend == "pallas":
            masked = jnp.where(t.validity, pids, num_workers)
            return kernel_ops.radix_histogram(masked, num_workers)
        onehot = jax.nn.one_hot(pids, num_workers, dtype=jnp.int32)
        return jnp.sum(onehot * t.validity[:, None].astype(jnp.int32), axis=0)

    return jax.vmap(per_worker)(table)          # [W_src, W_dst]


@functools.partial(jax.jit, static_argnums=(1,))
def _compact_stacked(table: DeviceTable, cap: int) -> DeviceTable:
    """Vector compaction (paper §3.3.2): per worker, move valid rows to the
    front and truncate to ``cap`` slots. Exchanges call this with ``cap``
    sized from the metadata phase, so dead padding (e.g. unused
    ``max_groups`` slots of an aggregation output) is not transmitted and
    not carried into downstream operators.

    The gather indices are built with a stream-compaction scatter (stable
    rank via cumsum) so only ``cap`` output rows are ever gathered — a full
    argsort-based compact would gather the whole padded capacity, which is
    exactly the cost this call exists to avoid."""

    def per_worker(t: DeviceTable):
        n = t.validity.shape[0]
        csum = jnp.cumsum(t.validity.astype(jnp.int32))
        # j-th valid row = first position where the running count hits j+1
        # (binary-search inversion; XLA CPU scatter is a scalar loop)
        gather = jnp.searchsorted(
            csum, jnp.arange(1, cap + 1, dtype=jnp.int32), side="left")
        out_valid = gather < n
        idx = jnp.minimum(gather, n - 1).astype(jnp.int32)
        cols = {name: jnp.take(a, idx, axis=0)
                for name, a in t.columns.items()}
        return DeviceTable(cols, out_valid, t.schema)

    return jax.vmap(per_worker)(table)


def _pow2(n: int) -> int:
    return int(2 ** np.ceil(np.log2(max(n, 1))))


def maybe_compact(table: DeviceTable) -> DeviceTable:
    """Vector compaction when it at least halves capacity (§3.3.2): trims
    per-worker rows to pow2(max per-worker valid count). Shared by the
    mesh exchange paths and the driver's blocking operators (a sort over
    ``max_groups`` padding costs more than this one metadata sync)."""
    per_worker = np.asarray(table.validity.sum(axis=1))
    cap = _pow2(int(per_worker.max()) if per_worker.size else 1)
    if cap * 2 <= table.validity.shape[1]:
        return _compact_stacked(table, cap)
    return table


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _partition_layout_table(table: DeviceTable, key_names, num_workers: int,
                            part_cap: int) -> DeviceTable:
    """Data phase step 1: scatter rows into [W_dst, part_cap] send buffers."""

    def per_worker(t: DeviceTable):
        pids = rel.partition_ids([t.columns[k] for k in key_names],
                                 t.validity, num_workers)
        gather, out_valid = rel.partition_layout(pids, t.validity, num_workers,
                                                 part_cap)
        cols = {n: jnp.take(a, gather, axis=0).reshape(
                    (num_workers, part_cap) + a.shape[1:])
                for n, a in t.columns.items()}
        return DeviceTable(cols, out_valid.reshape(num_workers, part_cap),
                           t.schema)

    return jax.vmap(per_worker)(table)          # leaves [W_src, W_dst, cap, ...]


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _repartition_fused(table: DeviceTable, key_names, num_workers: int,
                       out_cap: int) -> DeviceTable:
    """Single-device fast path: the whole shuffle as index arithmetic plus
    ONE gather per column, straight into compacted [W_dst, out_cap] output.

    The staged path (`_partition_layout_table` + `_exchange_data`)
    materializes a [W_src, W_dst, cap] send buffer, transposes it (the ICI
    all-to-all when a mesh is present), and compacts — three passes over
    the column bytes. Off-mesh those passes share one memory space, so the
    destination row of every source row can be computed up front (stable
    rank within its (src, dst) bucket + exclusive prefix of bucket counts
    over sources) and each column moved exactly once. ``out_cap`` comes
    from the metadata phase: >= the largest per-destination row total, so
    no row is dropped.
    """
    w, cap = table.validity.shape

    def ids(t: DeviceTable):
        return rel.partition_ids([t.columns[k] for k in key_names],
                                 t.validity, num_workers)

    pids = jax.vmap(ids)(table)                              # [W, cap]
    # per-destination running counts over the flattened (src-major) row
    # order; the j-th row received by dst d is the first flat position
    # whose running dst-d count reaches j+1 (binary-search inversion — no
    # sort, no scatter: XLA CPU is slow at both)
    onehot = ((pids[..., None] == jnp.arange(num_workers, dtype=jnp.int32))
              & table.validity[..., None]).astype(jnp.int32)
    csum = jnp.cumsum(onehot.reshape(w * cap, num_workers), axis=0)
    queries = jnp.arange(1, out_cap + 1, dtype=jnp.int32)
    gmap = jax.vmap(
        lambda col: jnp.searchsorted(col, queries, side="left"),
        in_axes=1)(csum)                                     # [D, out_cap]
    out_valid = gmap < w * cap
    idx = jnp.minimum(gmap, w * cap - 1).astype(jnp.int32)
    cols = {}
    for n, a in table.columns.items():
        flat = a.reshape((w * cap,) + a.shape[2:])
        cols[n] = jnp.take(flat, idx, axis=0).reshape(
            (num_workers, out_cap) + a.shape[2:])
    return DeviceTable(cols, out_valid, table.schema)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _broadcast_fused(table: DeviceTable, num_workers: int,
                     out_cap: int) -> DeviceTable:
    """Single-device fast path for broadcast: compact all valid rows with
    one gather per column, then replicate by broadcast (no per-worker
    copies of dead padding). ``out_cap`` >= total valid rows."""
    w, cap = table.validity.shape
    flatv = table.validity.reshape(-1)
    csum = jnp.cumsum(flatv.astype(jnp.int32))
    gmap = jnp.searchsorted(
        csum, jnp.arange(1, out_cap + 1, dtype=jnp.int32), side="left")
    out_valid = gmap < w * cap
    idx = jnp.minimum(gmap, w * cap - 1).astype(jnp.int32)
    cols = {}
    for n, a in table.columns.items():
        flat = a.reshape((w * cap,) + a.shape[2:])
        row = jnp.take(flat, idx, axis=0)
        cols[n] = jnp.broadcast_to(row[None], (num_workers,) + row.shape)
    valid = jnp.broadcast_to(out_valid[None], (num_workers, out_cap))
    return DeviceTable(cols, valid, table.schema)


class ExchangeProtocol:
    """Contract for moving worker-stacked tables between workers; the two
    implementations below mirror the paper's UcxExchange (device-native)
    vs HttpExchange (host-staged) contrast."""

    name = "exchange"

    def __init__(self):
        self.stats = ExchangeStats()

    def repartition(self, table: DeviceTable, key_names: Sequence[str],
                    num_workers: int) -> DeviceTable:
        """Hash-partition rows on ``key_names`` so equal keys land on the
        same worker (the shuffle between join/aggregation stages)."""
        raise NotImplementedError

    def broadcast(self, table: DeviceTable, num_workers: int) -> DeviceTable:
        """Replicate every worker's valid rows to all workers."""
        raise NotImplementedError

    def clone(self) -> "ExchangeProtocol":
        """Fresh instance with the same configuration but zeroed stats
        (the scheduler gives each concurrent query its own clone)."""
        return type(self)()

    # -- shared flow control ------------------------------------------------
    def _choose_part_cap(self, counts: np.ndarray) -> int:
        """Receive-buffer sizing from the metadata phase (flow control);
        pow2 for layout friendliness."""
        return _pow2(int(counts.max()) if counts.size else 1)

    @staticmethod
    def _ensure_rows(table: DeviceTable) -> DeviceTable:
        """Pad zero-capacity tables to one (dead) row per worker.

        A fragment can legitimately produce a [W, 0] table (all rows
        filtered, empty partition after a skewed shuffle); the layout/gather
        paths and downstream operators need at least one row slot."""
        if table.validity.shape[-1] > 0:
            return table

        def pad(a):
            widths = [(0, 0)] * a.ndim
            widths[1] = (0, 1)
            return jnp.pad(a, widths)

        return DeviceTable({n: pad(a) for n, a in table.columns.items()},
                           pad(table.validity), table.schema)


class ICIExchange(ExchangeProtocol):
    """Device-native exchange: UcxExchange on TPU interconnect.

    When a mesh is provided, the worker axis is sharded and the transpose in
    the data phase lowers to an ICI all-to-all (verified in the dry-run HLO);
    without a mesh the same program runs on one device (degenerate SPMD).
    """

    name = "ici"

    def __init__(self, mesh: Optional[Mesh] = None, axis: str = "workers"):
        super().__init__()
        self.mesh = mesh
        self.axis = axis

    def clone(self) -> "ICIExchange":
        """Fresh ICI protocol on the same mesh/axis, zeroed stats."""
        return type(self)(self.mesh, self.axis)

    def _constrain(self, tree):
        if self.mesh is None:
            return tree
        spec = NamedSharding(self.mesh, P(self.axis))
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, spec), tree)

    @functools.partial(jax.jit, static_argnums=(0, 2, 3))
    def _exchange_data(self, staged: DeviceTable, num_workers: int,
                       part_cap: int) -> DeviceTable:
        staged = self._constrain(staged)

        def swap(x):  # [W_src, W_dst, cap, ...] -> [W_dst, W_src*cap, ...]
            x = jnp.swapaxes(x, 0, 1)           # lowers to all-to-all on ICI
            return x.reshape((num_workers, num_workers * part_cap) + x.shape[3:])

        cols = {n: swap(a) for n, a in staged.columns.items()}
        out = DeviceTable(cols, swap(staged.validity), staged.schema)
        return self._constrain(out)

    def repartition(self, table, key_names, num_workers):
        t0 = time.perf_counter()
        table = self._ensure_rows(table)
        key_names = tuple(key_names)
        # metadata phase (rendezvous handshake): size the receive buffers
        backend = kernel_ops.current_backend()
        counts = np.asarray(
            _partition_counts(table, key_names, num_workers, backend))
        if backend == "pallas":
            kernel_ops.count_dispatch("partition")
        out_cap = _pow2(int(counts.sum(axis=0).max()) if counts.size else 1)
        if self.mesh is None:
            # off-mesh: one fused index-math + gather program per round
            out = _repartition_fused(table, key_names, num_workers, out_cap)
        else:
            # on-mesh: staged send buffers whose worker-axis transpose
            # lowers to the ICI all-to-all, then receive-side compaction
            # (vector compaction, §3.3.2). Compaction preserves each row's
            # source worker and keys, so the metadata counts above stay
            # valid — no second metadata pass
            table = maybe_compact(table)
            part_cap = self._choose_part_cap(counts)
            staged = _partition_layout_table(table, key_names, num_workers,
                                             part_cap)
            out = self._exchange_data(staged, num_workers, part_cap)
            if out_cap < out.validity.shape[1]:
                out = _compact_stacked(out, out_cap)
        self.stats.rounds += 1
        moved = int(counts.sum() - np.trace(counts))  # off-diagonal rows move
        self.stats.rows_moved += moved
        self.stats.bytes_moved += moved * _row_bytes(table)
        self.stats.seconds += time.perf_counter() - t0
        return out

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def _broadcast_data(self, table: DeviceTable, num_workers: int):
        table = self._constrain(table)
        cap = table.validity.shape[1]

        def bcast(x):  # [W, cap, ...] -> every worker sees all rows
            flat = x.reshape((1, num_workers * cap) + x.shape[2:])
            return jnp.broadcast_to(flat, (num_workers,) + flat.shape[1:])

        cols = {n: bcast(a) for n, a in table.columns.items()}
        out = DeviceTable(cols, bcast(table.validity), table.schema)
        return self._constrain(out)

    def broadcast(self, table, num_workers):
        t0 = time.perf_counter()
        table = self._ensure_rows(table)
        # metadata phase: valid counts size the replica buffers, so dead
        # padding is compacted away before replication W-fold
        rows = int(table.num_valid())
        if self.mesh is None:
            out = _broadcast_fused(table, num_workers, _pow2(rows))
        else:
            out = self._broadcast_data(maybe_compact(table), num_workers)
        self.stats.rounds += 1
        self.stats.rows_moved += rows * (num_workers - 1)
        self.stats.bytes_moved += rows * (num_workers - 1) * _row_bytes(table)
        self.stats.seconds += time.perf_counter() - t0
        return out


class HostExchange(ExchangeProtocol):
    """Host-staged exchange: the HttpExchange baseline.

    Faithful to the paper's description of Presto's protocol: results are
    serialized into *pages* (smallest unit of transmission, configurable
    size), the consumer fetches pages with a request/reply protocol, and all
    of it transits CPU memory. We reproduce the serialize → page → fetch →
    deserialize path with pickle as the page codec.
    """

    name = "host"

    def __init__(self, page_rows: int = 4096):
        super().__init__()
        self.page_rows = page_rows

    def clone(self) -> "HostExchange":
        """Fresh host-staged protocol at the same page size, zeroed stats."""
        return type(self)(self.page_rows)

    def _to_pages(self, cols: dict, validity: np.ndarray) -> List[bytes]:
        n = validity.shape[0]
        pages = []
        for lo in range(0, max(n, 1), self.page_rows):
            hi = min(lo + self.page_rows, n)
            page = {k: v[lo:hi] for k, v in cols.items()}
            page["__validity"] = validity[lo:hi]
            pages.append(pickle.dumps(page, protocol=4))
        return pages

    def repartition(self, table, key_names, num_workers):
        t0 = time.perf_counter()
        table = self._ensure_rows(table)
        # device -> host staging (the cost the paper eliminates)
        host_cols = {n: np.asarray(a) for n, a in table.columns.items()}
        validity = np.asarray(table.validity)
        self.stats.host_staged_bytes += sum(a.nbytes for a in host_cols.values())

        w = num_workers
        key_cols = [host_cols[k] for k in key_names]
        flat_keys = [k.reshape(-1, k.shape[-1]) if k.ndim == 3
                     else k.reshape(-1) for k in key_cols]
        hashed = _hash_combine_np(flat_keys).reshape(validity.shape)
        pid = hashed % w

        # upstream: serialize each (src, dst) partition into pages
        inboxes: List[List[bytes]] = [[] for _ in range(w)]
        for src in range(w):
            mask = validity[src]
            for dst in range(w):
                sel = mask & (pid[src] == dst)
                if not sel.any():
                    continue
                part = {n: a[src][sel] for n, a in host_cols.items()}
                inboxes[dst].extend(self._to_pages(part, np.ones(sel.sum(), bool)))

        # downstream: fetch + deserialize pages, assemble worker tables
        per_worker = []
        total_bytes = 0
        for dst in range(w):
            rows = {n: [] for n in host_cols}
            vals = []
            for page_bytes in inboxes[dst]:
                total_bytes += len(page_bytes)
                page = pickle.loads(page_bytes)
                v = page.pop("__validity")
                vals.append(v)
                for n, a in page.items():
                    rows[n].append(a)
            cnt = sum(v.shape[0] for v in vals) if vals else 0
            per_worker.append((rows, vals, cnt))

        cap = _pow2(max(c for _, _, c in per_worker))
        out_cols = {n: np.zeros((w, cap) + host_cols[n].shape[2:],
                                dtype=host_cols[n].dtype) for n in host_cols}
        out_valid = np.zeros((w, cap), dtype=bool)
        for dst, (rows, vals, cnt) in enumerate(per_worker):
            if cnt == 0:
                continue
            for n in host_cols:
                out_cols[n][dst, :cnt] = np.concatenate(rows[n], axis=0)
            out_valid[dst, :cnt] = np.concatenate(vals)

        # host -> device staging
        out = DeviceTable({n: jnp.asarray(a) for n, a in out_cols.items()},
                          jnp.asarray(out_valid), table.schema)
        self.stats.rounds += 1
        self.stats.bytes_moved += total_bytes
        self.stats.rows_moved += int(validity.sum())
        self.stats.host_staged_bytes += sum(a.nbytes for a in out_cols.values())
        self.stats.seconds += time.perf_counter() - t0
        return out

    def broadcast(self, table, num_workers):
        t0 = time.perf_counter()
        table = self._ensure_rows(table)
        host_cols = {n: np.asarray(a) for n, a in table.columns.items()}
        validity = np.asarray(table.validity)
        self.stats.host_staged_bytes += sum(a.nbytes for a in host_cols.values())
        w = num_workers
        flat_valid = validity.reshape(-1)
        flat_cols = {n: a.reshape((-1,) + a.shape[2:]) for n, a in host_cols.items()}
        pages = self._to_pages({n: a[flat_valid] for n, a in flat_cols.items()},
                               np.ones(int(flat_valid.sum()), bool))
        total = sum(len(p) for p in pages) * (w - 1)
        cnt = int(flat_valid.sum())
        cap = _pow2(cnt)
        out_cols = {}
        for n, a in flat_cols.items():
            buf = np.zeros((cap,) + a.shape[1:], dtype=a.dtype)
            buf[:cnt] = a[flat_valid]
            out_cols[n] = jnp.asarray(np.broadcast_to(buf, (w,) + buf.shape).copy())
        ov = np.zeros(cap, bool)
        ov[:cnt] = True
        out = DeviceTable(out_cols, jnp.asarray(np.broadcast_to(ov, (w, cap)).copy()),
                          table.schema)
        self.stats.rounds += 1
        self.stats.bytes_moved += total
        self.stats.rows_moved += cnt * (w - 1)
        self.stats.host_staged_bytes += sum(np.asarray(a).nbytes
                                            for a in out_cols.values())
        self.stats.seconds += time.perf_counter() - t0
        return out
