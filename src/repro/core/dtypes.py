"""Column dtypes for the device-resident query engine.

The paper's cuDF tables are Arrow-columnar in GPU memory. On TPU/XLA every
array must have a static shape, so the engine works with:

* numeric columns   -- plain jnp arrays (int32, float32, ...)
* date columns      -- int32 days since 1970-01-01 (Arrow date32)
* dict strings      -- int32 codes + a host-side dictionary (Arrow dictionary
                       encoding; the paper dict-encodes strings as data+offset
                       column pairs, we keep the dictionary in host metadata)
* fixed-width bytes -- uint8[N, W] matrices for LIKE-style predicates

TPC-H contains no nulls (the paper ignores them as well); validity is a
table-level row mask, not a per-column bitmap.
"""

from __future__ import annotations

import dataclasses
import datetime
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

_EPOCH = datetime.date(1970, 1, 1)


@dataclasses.dataclass(frozen=True)
class DType:
    """Logical column type."""

    name: str                      # int32 | int64 | float32 | float64 | bool |
                                   # date32 | dict32 | bytes
    width: int = 0                 # only for 'bytes': fixed row width
    dictionary: Optional[Tuple[str, ...]] = None   # only for 'dict32'

    # -- classification helpers -------------------------------------------
    @property
    def is_numeric(self) -> bool:
        """True for plain int/float columns (arithmetic allowed)."""
        return self.name in ("int32", "int64", "float32", "float64")

    @property
    def is_string(self) -> bool:
        """True for dict-encoded or fixed-width-bytes string columns."""
        return self.name in ("dict32", "bytes")

    def np_dtype(self) -> np.dtype:
        """Numpy storage dtype for one element of this column."""
        return np.dtype(
            {
                "int32": np.int32,
                "int64": np.int64,
                "float32": np.float32,
                "float64": np.float64,
                "bool": np.bool_,
                "date32": np.int32,
                "dict32": np.int32,
                "bytes": np.uint8,
            }[self.name]
        )

    def jnp_dtype(self):
        """JAX dtype for one element of this column."""
        return jnp.dtype(self.np_dtype())

    def storage_shape(self, num_rows: int) -> tuple:
        """Array shape for ``num_rows`` values ([N, W] for bytes)."""
        if self.name == "bytes":
            return (num_rows, self.width)
        return (num_rows,)

    def decode(self, code: int) -> str:
        """dict32 code -> string (host-side dictionary lookup)."""
        assert self.name == "dict32" and self.dictionary is not None
        return self.dictionary[code]

    def encode(self, value: str) -> int:
        """dict32 string -> code (host-side dictionary lookup)."""
        assert self.name == "dict32" and self.dictionary is not None
        return self.dictionary.index(value)

    def __repr__(self) -> str:  # keep dictionaries out of reprs
        if self.name == "bytes":
            return f"bytes[{self.width}]"
        if self.name == "dict32":
            n = len(self.dictionary) if self.dictionary else 0
            return f"dict32[{n}]"
        return self.name


INT32 = DType("int32")
INT64 = DType("int64")
FLOAT32 = DType("float32")
FLOAT64 = DType("float64")
BOOL = DType("bool")
DATE32 = DType("date32")


def dict32(values) -> DType:
    """Dictionary-encoded string type over a fixed value domain."""
    return DType("dict32", dictionary=tuple(values))


def bytes_(width: int) -> DType:
    """Fixed-width byte-string type (uint8[N, width] storage)."""
    return DType("bytes", width=width)


# -- date helpers ----------------------------------------------------------

def date_to_i32(iso: str) -> int:
    """'1995-03-15' -> days since epoch (int)."""
    y, m, d = (int(p) for p in iso.split("-"))
    return (datetime.date(y, m, d) - _EPOCH).days


def i32_to_date(days: int) -> str:
    """int32 days-since-epoch -> 'YYYY-MM-DD'."""
    return (_EPOCH + datetime.timedelta(days=int(days))).isoformat()


def encode_bytes(strings, width: int) -> np.ndarray:
    """Encode python strings into a fixed-width uint8 matrix (space padded)."""
    out = np.full((len(strings), width), ord(" "), dtype=np.uint8)
    for i, s in enumerate(strings):
        b = s.encode("ascii", "replace")[:width]
        out[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


def decode_bytes(row: np.ndarray) -> str:
    """One uint8 row -> python string (space padding stripped)."""
    return bytes(np.asarray(row, dtype=np.uint8)).decode("ascii").rstrip()
