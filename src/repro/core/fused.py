"""Fused per-morsel pipeline kernel (filter → project → probe in one
Pallas dispatch).

The paper's GPU wins come from keeping whole operator pipelines on-device
with no intermediate materialization; "Rethinking Analytical Processing in
the GPU Era" (PAPERS.md) makes the sharper point that per-operator kernel
launches dominate once scans are fast. This module is the TPU analogue:
instead of one ``table_op`` dispatch per FilterProject/HashJoin probe, the
driver's ``StreamingScan`` collapses a run of non-compacting
FilterProjects (optionally ending in an eligible open-addressing probe)
into a single ``pallas_call`` per morsel. Expressions evaluate on VMEM
blocks — each row block flows filter → project → probe without touching
HBM in between.

Only single-match probes fuse (semi/anti/unique-build inner/outer): their
output capacity equals the morsel capacity, so the fused kernel keeps the
block-per-block shape contract. Expansion probes keep their standalone
kernel. Off-TPU the kernel runs in interpret mode like every other kernel
in ``kernels/``.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..kernels import ops as kernel_ops
from ..kernels.hash_probe import probe_loop
from . import relational as rel
from .table import DeviceTable

ROW_BLOCK = 1024

# one fused stage = one FilterProject's (filter_expr, projections)
Stage = Tuple[object, Optional[Tuple[Tuple[str, object], ...]]]


def apply_stages(table: DeviceTable, stages: Sequence[Stage]) -> DeviceTable:
    """Replay a run of FilterProject stages on ``table`` — the exact
    per-stage semantics of ``operators._filter_project`` without compact.
    Runs both under ``jax.eval_shape`` (to size the kernel outputs) and
    inside the kernel body on block-shaped tables."""
    for filter_expr, projections in stages:
        if filter_expr is not None:
            table = table.filter(filter_expr.evaluate(table))
        if projections is not None:
            cols, schema = {}, {}
            for out_name, e in projections:
                v = e.evaluate(table)
                if v.ndim == 0:   # literal: broadcast to rows
                    v = jnp.broadcast_to(v, (table.capacity,))
                cols[out_name] = v
                schema[out_name] = e.out_dtype(table.schema)
            table = DeviceTable(cols, table.validity, schema)
    return table


def probe_key(table: DeviceTable, key_names, pack, empty_key: int):
    """Single-lane probe key: the raw int key, or the injective composite
    pack (``relational.packed_key``) when ``pack`` is set."""
    cols = [table.columns[k] for k in key_names]
    if pack is not None:
        return rel.packed_key(cols, pack, empty_key=empty_key)
    key, _ = rel.join_key(cols)
    return key


def _block_spec(shape, row_block):
    if len(shape) == 1:
        return pl.BlockSpec((row_block,), lambda i: (i,))
    w = shape[1]
    return pl.BlockSpec((row_block, w), lambda i: (i, 0))


def fused_morsel_program(table: DeviceTable, stages: Sequence[Stage],
                         probe: Optional[dict] = None,
                         row_block: int = ROW_BLOCK,
                         interpret: Optional[bool] = None):
    """Run ``stages`` (and optionally a single-match hash probe) over
    ``table`` in one Pallas dispatch.

    ``probe``, when given, is a dict with keys ``tk``/``tv`` (the
    open-addressing table arrays, VMEM-resident across row blocks),
    ``probe_keys`` (post-stage column names), ``pack`` (composite-key
    windows or None), ``empty_key`` and ``max_probes``.

    Returns ``(out_table, found, bidx)``; ``found``/``bidx`` are None
    without a probe. ``found`` already masks invalid rows and probe keys
    equal to the empty sentinel (the PR-5 regression), so callers consume
    it directly.
    """
    if interpret is None:
        interpret = not kernel_ops.on_tpu()
    kernel_ops.mark_kernel("fused")

    cap = int(table.validity.shape[0])
    names = tuple(table.column_names)
    in_schema = dict(table.schema)
    row_block = min(row_block, cap)
    pad = (-cap) % row_block
    in_arrays = [table.columns[n] for n in names] + [table.validity]
    if pad:   # padded rows carry validity False, so stages/probe drop them
        in_arrays = [jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
                     for a in in_arrays]
    n_pad = cap + pad

    out_struct = jax.eval_shape(lambda t: apply_stages(t, tuple(stages)),
                                table)
    out_names = tuple(out_struct.column_names)
    out_schema = dict(out_struct.schema)
    n_in = len(names)

    def kernel(*refs):
        col_refs, valid_ref = refs[:n_in], refs[n_in]
        pos = n_in + 1
        if probe is not None:
            tk_ref, tv_ref = refs[pos], refs[pos + 1]
            pos += 2
        out_refs = refs[pos:]
        t = DeviceTable({n: r[...] for n, r in zip(names, col_refs)},
                        valid_ref[...], dict(in_schema))
        t = apply_stages(t, tuple(stages))
        for k, n in enumerate(out_names):
            out_refs[k][...] = t.columns[n]
        out_refs[len(out_names)][...] = t.validity
        if probe is not None:
            key = probe_key(t, probe["probe_keys"], probe["pack"],
                            probe["empty_key"])
            found, bidx = probe_loop(
                tk_ref[...], tv_ref[...], key,
                table_size=probe["tk"].shape[0],
                empty_key=probe["empty_key"],
                max_probes=probe["max_probes"])
            # a probe key equal to the empty sentinel reads an empty slot
            # as a hit; no such key occupies the table (seal_build falls
            # back otherwise), so masking it is exact
            found = found & t.validity & (key != probe["empty_key"])
            out_refs[len(out_names) + 1][...] = found
            out_refs[len(out_names) + 2][...] = bidx

    in_specs = [_block_spec(a.shape, row_block) for a in in_arrays]
    operands = list(in_arrays)
    if probe is not None:
        t_slots = probe["tk"].shape[0]
        in_specs += [pl.BlockSpec((t_slots,), lambda i: (0,)),
                     pl.BlockSpec((t_slots,), lambda i: (0,))]
        operands += [probe["tk"], probe["tv"]]

    out_shapes, out_specs = [], []
    for n in out_names:
        s = out_struct.columns[n]
        shape = (n_pad,) + s.shape[1:]
        out_shapes.append(jax.ShapeDtypeStruct(shape, s.dtype))
        out_specs.append(_block_spec(shape, row_block))
    # validity, then (found, bidx) when probing
    for dtype in ([jnp.bool_] if probe is None
                  else [jnp.bool_, jnp.bool_, jnp.int32]):
        out_shapes.append(jax.ShapeDtypeStruct((n_pad,), dtype))
        out_specs.append(pl.BlockSpec((row_block,), lambda i: (i,)))

    outs = pl.pallas_call(
        kernel, grid=(n_pad // row_block,),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shapes,
        interpret=interpret,
    )(*operands)
    outs = [o[:cap] for o in outs]

    out_table = DeviceTable(dict(zip(out_names, outs)),
                            outs[len(out_names)], out_schema)
    if probe is None:
        return out_table, None, None
    return out_table, outs[len(out_names) + 1], outs[len(out_names) + 2]


def fused_batch_program(table: DeviceTable, params: Tuple,
                        eval_fn, n_members: int,
                        row_block: int = ROW_BLOCK,
                        interpret: Optional[bool] = None):
    """Inter-query batched variant of ``fused_morsel_program``: evaluate
    ``n_members`` stacked queries' predicate lanes plus their shared
    projections over one morsel in ONE Pallas dispatch.

    ``eval_fn(table, params) -> (out_table, masks[n_members, capacity])``
    is the batched stage walk (``core.batch.apply_batched_stages`` bound
    to a program — injected as a callable so this module stays free of a
    circular import on ``core.batch``). ``params`` is a tuple of
    ``[n_members]`` scalar arrays, one per parameter slot; each lane's
    scalars are broadcast whole into every row block.
    """
    if interpret is None:
        interpret = not kernel_ops.on_tpu()
    kernel_ops.mark_kernel("fused_batch")

    cap = int(table.validity.shape[0])
    names = tuple(table.column_names)
    in_schema = dict(table.schema)
    row_block = min(row_block, cap)
    pad = (-cap) % row_block
    in_arrays = [table.columns[n] for n in names] + [table.validity]
    if pad:   # padded rows carry validity False → masked in every lane
        in_arrays = [jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
                     for a in in_arrays]
    n_pad = cap + pad

    out_struct, mask_struct = jax.eval_shape(eval_fn, table, params)
    del mask_struct
    out_names = tuple(out_struct.column_names)
    out_schema = dict(out_struct.schema)
    n_in = len(names)
    n_par = len(params)

    def kernel(*refs):
        col_refs, valid_ref = refs[:n_in], refs[n_in]
        par_refs = refs[n_in + 1:n_in + 1 + n_par]
        out_refs = refs[n_in + 1 + n_par:]
        t = DeviceTable({n: r[...] for n, r in zip(names, col_refs)},
                        valid_ref[...], dict(in_schema))
        block, masks = eval_fn(t, tuple(r[...] for r in par_refs))
        for k, n in enumerate(out_names):
            out_refs[k][...] = block.columns[n]
        out_refs[len(out_names)][...] = block.validity
        out_refs[len(out_names) + 1][...] = masks

    in_specs = [_block_spec(a.shape, row_block) for a in in_arrays]
    # every parameter lane rides whole into each grid step
    in_specs += [pl.BlockSpec((n_members,), lambda i: (0,))
                 for _ in params]
    operands = list(in_arrays) + list(params)

    out_shapes, out_specs = [], []
    for n in out_names:
        s = out_struct.columns[n]
        shape = (n_pad,) + s.shape[1:]
        out_shapes.append(jax.ShapeDtypeStruct(shape, s.dtype))
        out_specs.append(_block_spec(shape, row_block))
    out_shapes.append(jax.ShapeDtypeStruct((n_pad,), jnp.bool_))
    out_specs.append(pl.BlockSpec((row_block,), lambda i: (i,)))
    out_shapes.append(jax.ShapeDtypeStruct((n_members, n_pad), jnp.bool_))
    out_specs.append(
        pl.BlockSpec((n_members, row_block), lambda i: (0, i)))

    outs = pl.pallas_call(
        kernel, grid=(n_pad // row_block,),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shapes,
        interpret=interpret,
    )(*operands)

    cols = [o[:cap] for o in outs[:len(out_names)]]
    validity = outs[len(out_names)][:cap]
    masks = outs[len(out_names) + 1][:, :cap]
    out_table = DeviceTable(dict(zip(out_names, cols)), validity,
                            out_schema)
    return out_table, masks
