"""Device-resident distributed query engine (the paper's contribution).

Public API:

    from repro.core import dtypes, plan, expr
    from repro.core.session import Session, Catalog, ExecutionOptions
    from repro.core.builder import QueryBuilder, table
    from repro.core.sql import lower_sql            # or: Session.sql(text)
    from repro.core.optimizer import optimize, explain
    from repro.core.exchange import ICIExchange, HostExchange
    from repro.core.scheduler import QueryScheduler, SchedulerConfig
"""

from . import dtypes, expr, plan  # noqa: F401
from .builder import QueryBuilder, SchemaError, table  # noqa: F401
from .exchange import HostExchange, ICIExchange  # noqa: F401
from .optimizer import OptimizerConfig, explain, optimize  # noqa: F401
from .scheduler import (QueryHandle, QueryRejected,  # noqa: F401
                        QueryScheduler, SchedulerConfig)
from .session import (Catalog, ExecutionOptions,  # noqa: F401
                      Session, TableSource)
from .sql import SqlUnsupportedError, lower_sql  # noqa: F401
from .sqlast import SqlParseError  # noqa: F401
from .streaming import MorselPrefetcher, ScanStats  # noqa: F401
from .table import DeviceTable, concat_tables  # noqa: F401
