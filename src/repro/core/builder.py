"""Fluent, schema-propagating query builder: the engine's public frontend.

Queries are composed as method chains that validate every step against the
propagated schema at *build* time -- unknown columns, type mismatches and
malformed aggregations fail immediately with the available columns in the
error, instead of surfacing as shape errors deep inside the driver:

    (session.table("lineitem")
        .filter(col("l_shipdate") <= date_lit("1998-09-02"))
        .project("l_returnflag", rev=col("l_extendedprice") * 0.9)
        .group_by("l_returnflag")
        .agg(revenue=("sum", "rev"))
        .order_by("revenue", descending=[True])
        .collect())

Each step produces the existing ``PlanNode`` IR (``.plan`` exposes it), so
the ``Driver`` executes builder queries unchanged; ``.collect()`` runs the
plan through the rule-based logical optimizer first (see ``optimizer.py``).
Builders are immutable: every method returns a new builder.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from . import dtypes as dt
from . import plan as P
from .expr import (BinaryOp, BytesMatch, Expr, IsIn, Literal,
                   UnaryOp, col)
from . import optimizer as opt


class SchemaError(ValueError):
    """A builder step referenced a column or type the schema cannot satisfy."""


_ARITH_OPS = ("add", "sub", "mul", "div")
_AGG_KINDS = ("sum", "avg", "min", "max", "count", "first")


def _fmt_cols(schema: Dict[str, dt.DType]) -> str:
    return ", ".join(f"{n}: {t}" for n, t in schema.items())


def _check_expr(e: Expr, schema: Dict[str, dt.DType], ctx: str) -> dt.DType:
    """Validate references and operand types; return the output dtype."""
    unknown = sorted(e.references() - set(schema))
    if unknown:
        raise SchemaError(
            f"{ctx}: unknown column(s) {unknown}; "
            f"available: [{_fmt_cols(schema)}]")
    _check_types(e, schema, ctx)
    return e.out_dtype(schema)


def _check_types(e: Expr, schema: Dict[str, dt.DType], ctx: str) -> None:
    if isinstance(e, BinaryOp):
        _check_types(e.lhs, schema, ctx)
        _check_types(e.rhs, schema, ctx)
        if e.op in _ARITH_OPS:
            for side in (e.lhs, e.rhs):
                t = side.out_dtype(schema)
                if t.is_string:
                    raise SchemaError(
                        f"{ctx}: arithmetic '{e.op}' on {t} operand {side}; "
                        f"string columns support only comparisons and "
                        f"pattern predicates")
    elif isinstance(e, UnaryOp):
        _check_types(e.operand, schema, ctx)
        if e.op == "neg" and e.operand.out_dtype(schema).is_string:
            raise SchemaError(f"{ctx}: cannot negate {e.operand}")
    elif isinstance(e, IsIn):
        _check_types(e.operand, schema, ctx)
    elif isinstance(e, BytesMatch):
        _check_types(e.operand, schema, ctx)
        if e.operand.out_dtype(schema).name != "bytes":
            raise SchemaError(
                f"{ctx}: pattern predicate '{e.mode}' needs a bytes column, "
                f"got {e.operand.out_dtype(schema)} for {e.operand}")
    else:
        for child in getattr(e, "__dict__", {}).values():
            if isinstance(child, Expr):
                _check_types(child, schema, ctx)


def _key_family(t: dt.DType) -> str:
    """Join keys hash by raw value: only same-family keys can ever match."""
    if t.name in ("int32", "int64", "date32", "dict32", "bool"):
        return "int"
    if t.name in ("float32", "float64"):
        return "float"
    return "bytes"


class QueryBuilder:
    """Immutable fluent wrapper around a ``PlanNode`` + its output schema.

    Every step validates against the propagated schema at build time and
    returns a *new* builder; ``.plan`` exposes the logical IR at any point::

        q = (session.table("lineitem")
             .filter(col("l_quantity") < 5.0)
             .group_by("l_returnflag")
             .agg(n=("count", None)))
        out = q.collect()                 # optimize + execute on this thread
        handle = q.submit(priority=1)     # or: schedule it concurrently
        out = handle.result()
    """

    # set on the FINAL builder only (by Session.sql / lower_sql), never
    # propagated by _derive: the SQL text a builder was lowered from (a
    # scheduler cache-key prefix) and its attached ExecutionOptions
    sql_text: Optional[str] = None
    _options = None

    def __init__(self, plan: P.PlanNode, schema: Dict[str, dt.DType],
                 catalog, session=None):
        self.plan = plan
        self.schema = dict(schema)
        self._catalog = catalog
        self._session = session

    # -- constructors -------------------------------------------------------
    @classmethod
    def scan(cls, catalog, table: str,
             columns: Optional[Sequence[str]] = None,
             session=None) -> "QueryBuilder":
        """Root builder over a catalog table (all columns by default)."""
        try:
            src = catalog.get(table)
        except KeyError:
            raise SchemaError(
                f"table('{table}'): unknown table; "
                f"catalog has {sorted(catalog.tables())}") from None
        if columns is not None:
            unknown = sorted(set(columns) - set(src.schema))
            if unknown:
                raise SchemaError(
                    f"table('{table}'): unknown column(s) {unknown}; "
                    f"available: [{_fmt_cols(src.schema)}]")
        schema = {c: src.schema[c] for c in (columns or src.schema)}
        return cls(P.TableScan(table, columns=list(columns) if columns else None),
                   schema, catalog, session)

    def _derive(self, plan: P.PlanNode,
                schema: Dict[str, dt.DType]) -> "QueryBuilder":
        return QueryBuilder(plan, schema, self._catalog, self._session)

    # -- row-level steps ----------------------------------------------------
    def filter(self, predicate: Expr) -> "QueryBuilder":
        """Keep rows satisfying a boolean expression:
        ``.filter(col("l_quantity") < 24)``."""
        t = _check_expr(predicate, self.schema, "filter")
        if t.name != "bool":
            raise SchemaError(
                f"filter: predicate {predicate} has type {t}, expected bool")
        return self._derive(P.Filter(self.plan, predicate), self.schema)

    where = filter

    def project(self, *columns: Union[str, Tuple[str, Expr]],
                **named: Expr) -> "QueryBuilder":
        """Positional strings pass columns through; kwargs compute new ones."""
        projections: List[Tuple[str, Expr]] = []
        for c in columns:
            if isinstance(c, str):
                projections.append((c, col(c)))
            else:
                name, e = c
                projections.append((name, e))
        for name, e in named.items():
            projections.append((name, e if isinstance(e, Expr) else Literal(e)))
        if not projections:
            raise SchemaError("project: no columns given")
        schema = {}
        for name, e in projections:
            schema[name] = _check_expr(e, self.schema, f"project({name})")
        return self._derive(P.Project(self.plan, projections), schema)

    select = project

    def with_column(self, name: str, e: Expr) -> "QueryBuilder":
        """Append one computed column, keeping every existing column."""
        return self.project(*self.schema, **{name: e})

    # -- aggregation --------------------------------------------------------
    def group_by(self, *keys: str) -> "GroupedBuilder":
        """Start a grouped aggregation; follow with ``.agg(...)``."""
        for k in keys:
            if k not in self.schema:
                raise SchemaError(
                    f"group_by: unknown column '{k}'; "
                    f"available: [{_fmt_cols(self.schema)}]")
        return GroupedBuilder(self, keys)

    def agg(self, **aggs) -> "QueryBuilder":
        """Global (no group keys) aggregation: ``.agg(total=('sum', 'x'))``."""
        return self.group_by().agg(**aggs)

    def distinct(self, *keys: str) -> "QueryBuilder":
        """Unique rows over ``keys`` (all columns when omitted)."""
        keys = keys or tuple(self.schema)
        for k in keys:
            if k not in self.schema:
                raise SchemaError(
                    f"distinct: unknown column '{k}'; "
                    f"available: [{_fmt_cols(self.schema)}]")
        return self._derive(P.Distinct(self.plan, list(keys)),
                            {k: self.schema[k] for k in keys})

    # -- joins --------------------------------------------------------------
    def join(self, build: "QueryBuilder", left_on: Sequence[str],
             right_on: Sequence[str], payload: Sequence[str] = (),
             how: str = "inner",
             build_rows: Optional[int] = None) -> "QueryBuilder":
        """Hash join; ``self`` streams as the probe side, ``build`` is
        materialized. ``payload`` names build columns carried into the
        output (semi/anti joins carry none). ``build_rows`` optionally
        asserts an upper bound on valid build-side rows (sizes the kernel
        backend's probe table); when omitted the optimizer derives one
        from catalog statistics."""
        if how not in ("inner", "left_semi", "left_anti", "left_outer"):
            raise SchemaError(f"join: unknown join type '{how}'")
        if build_rows is not None and build_rows <= 0:
            raise SchemaError(
                f"join: build_rows must be positive, got {build_rows}")
        if len(left_on) != len(right_on) or not left_on:
            raise SchemaError(
                f"join: key lists must be equal-length and non-empty, "
                f"got {list(left_on)} vs {list(right_on)}")
        for k in left_on:
            if k not in self.schema:
                raise SchemaError(
                    f"join: unknown probe key '{k}'; "
                    f"available: [{_fmt_cols(self.schema)}]")
        for k in right_on:
            if k not in build.schema:
                raise SchemaError(
                    f"join: unknown build key '{k}'; "
                    f"available: [{_fmt_cols(build.schema)}]")
        for lk, rk in zip(left_on, right_on):
            lt, rt = self.schema[lk], build.schema[rk]
            if _key_family(lt) != _key_family(rt):
                raise SchemaError(
                    f"join: key type mismatch {lk}: {lt} vs {rk}: {rt}")
        if how in ("left_semi", "left_anti") and payload:
            raise SchemaError(f"join: {how} joins carry no build payload")
        for c in payload:
            if c not in build.schema:
                raise SchemaError(
                    f"join: unknown payload column '{c}'; "
                    f"build side has: [{_fmt_cols(build.schema)}]")
        schema = dict(self.schema)
        for c in payload:
            schema[c] = build.schema[c]
        if how == "left_outer":
            schema["__matched"] = dt.BOOL
        return self._derive(
            P.Join(probe=self.plan, build=build.plan,
                   probe_keys=list(left_on), build_keys=list(right_on),
                   build_payload=list(payload), join_type=how,
                   build_rows=build_rows),
            schema)

    def semi_join(self, build: "QueryBuilder", left_on: Sequence[str],
                  right_on: Sequence[str]) -> "QueryBuilder":
        """Keep probe rows with at least one build match (EXISTS)."""
        return self.join(build, left_on, right_on, how="left_semi")

    def anti_join(self, build: "QueryBuilder", left_on: Sequence[str],
                  right_on: Sequence[str]) -> "QueryBuilder":
        """Keep probe rows with no build match (NOT EXISTS)."""
        return self.join(build, left_on, right_on, how="left_anti")

    def attach_scalar(self, scalar: "QueryBuilder",
                      columns: Sequence[str]) -> "QueryBuilder":
        """Attach columns of a 1-row subquery result to every row
        (uncorrelated scalar subqueries: Q11/Q15/Q22 shapes)."""
        for c in columns:
            if c not in scalar.schema:
                raise SchemaError(
                    f"attach_scalar: unknown column '{c}'; "
                    f"scalar side has: [{_fmt_cols(scalar.schema)}]")
        schema = dict(self.schema)
        for c in columns:
            schema[c] = scalar.schema[c]
        return self._derive(
            P.ScalarBroadcast(self.plan, scalar.plan, list(columns)), schema)

    # -- ordering / limiting ------------------------------------------------
    def order_by(self, *keys: str, descending: Optional[Sequence[bool]] = None,
                 limit: Optional[int] = None) -> "QueryBuilder":
        """Sort by ``keys`` (per-key ``descending`` flags, optional
        top-``limit``): ``.order_by("revenue", descending=[True])``."""
        for k in keys:
            if k not in self.schema:
                raise SchemaError(
                    f"order_by: unknown column '{k}'; "
                    f"available: [{_fmt_cols(self.schema)}]")
        if descending is not None and len(descending) != len(keys):
            raise SchemaError(
                f"order_by: {len(keys)} keys but {len(descending)} "
                f"descending flags")
        return self._derive(
            P.OrderBy(self.plan, list(keys),
                      list(descending) if descending else None, limit),
            self.schema)

    def limit(self, n: int) -> "QueryBuilder":
        """Keep the first ``n`` rows (fuses into a preceding order_by)."""
        if n <= 0:
            raise SchemaError(f"limit: n must be positive, got {n}")
        plan = self.plan
        if isinstance(plan, P.OrderBy) and plan.limit is None:
            return self._derive(dataclasses.replace(plan, limit=n), self.schema)
        return self._derive(P.Limit(plan, n), self.schema)

    # -- terminal steps ------------------------------------------------------
    def to_plan(self) -> P.PlanNode:
        """The logical ``PlanNode`` tree built so far (unoptimized)."""
        return self.plan

    def _config(self) -> opt.OptimizerConfig:
        """Session-bound builders plan for the session's worker count, so
        explain()/optimized() show the plan collect() actually executes."""
        if self._session is not None:
            return self._session.optimizer_config()
        return opt.DEFAULT_CONFIG

    def optimized(self, config: Optional[opt.OptimizerConfig] = None
                  ) -> P.PlanNode:
        """The plan after the rule-based optimizer pipeline (including
        exchange placement when the bound session is distributed)."""
        return opt.optimize(self.plan, self._catalog,
                            config=config or self._config())

    def explain(self, analyze: bool = False) -> str:
        """Plan tree before and after the optimizer pipeline.

        Session-bound builders (including every ``session.sql(...)`` query)
        delegate to ``Session.explain``, so ``analyze=True`` additionally
        executes the plan and annotates it with live operator metrics —
        one explain surface for builder and SQL queries alike. Unbound
        builders fall back to the logical before/after text
        (``analyze=True`` then raises, as there is no session to run on).
        """
        if self._session is not None:
            return self._session.explain(self.plan, analyze=analyze)
        if analyze:
            raise RuntimeError(
                "explain(analyze=True) needs a session-bound builder; "
                "build via session.table(...) or session.sql(...)")
        return opt.explain_before_after(self.plan, self._catalog,
                                        config=self._config())

    def collect(self, optimize: bool = True, options=None):
        """Optimize and execute; requires a session-bound builder
        (``session.table(...)`` / ``session.sql(...)``). Optimization uses
        the session's worker count, so distributed sessions run
        exchange-placed fragment plans. ``options`` (an
        ``ExecutionOptions``) overrides worker count / kernel backend /
        optimize for this call; when omitted, options attached by
        ``session.sql(..., options=...)`` apply."""
        if self._session is None:
            raise RuntimeError(
                "collect() needs a session-bound builder; build via "
                "session.table(...) or execute to_plan()/optimized() yourself")
        opts = options if options is not None else self._options
        if opts is not None and opts.optimize is not None:
            optimize = opts.optimize
        sess = self._session._with_options(opts)
        plan = sess.optimize(self.plan) if optimize else self.plan
        return sess.execute(plan)

    execute = collect

    def submit(self, priority: int = 0, options=None):
        """Schedule this query concurrently; returns a ``QueryHandle``.

        Routes through the session's ``QueryScheduler`` (admission control,
        plan/result caches); requires a session-bound builder::

            h = session.table("orders").limit(10).submit()
            rows = h.result()

        ``options`` (an ``ExecutionOptions``) overrides priority / worker
        count / kernel backend / optimize for this query; SQL-born builders
        additionally key the scheduler caches by their SQL text.
        """
        if self._session is None:
            raise RuntimeError(
                "submit() needs a session-bound builder; build via "
                "session.table(...) or submit the plan to a session yourself")
        return self._session.submit(self, priority=priority, options=options)

    def __repr__(self):
        return (f"QueryBuilder[{_fmt_cols(self.schema)}]\n"
                + opt.explain(self.plan))


class GroupedBuilder:
    """Intermediate ``group_by`` state; ``agg`` produces the aggregation."""

    def __init__(self, parent: QueryBuilder, keys: Sequence[str]):
        self._parent = parent
        self._keys = tuple(keys)

    def agg(self, **aggs: Tuple[str, Optional[str]]) -> QueryBuilder:
        """Each kwarg is ``out_name=(kind, in_column)``; ``count`` takes
        ``None`` as its input column."""
        if not aggs:
            raise SchemaError("agg: no aggregations given")
        parent, schema = self._parent, self._parent.schema
        specs: List[Tuple[str, str, Optional[str]]] = []
        out_schema = {k: schema[k] for k in self._keys}
        for name, spec in aggs.items():
            if not isinstance(spec, tuple) or len(spec) != 2:
                raise SchemaError(
                    f"agg({name}): expected (kind, column) tuple, got {spec!r}")
            kind, in_col = spec
            if kind not in _AGG_KINDS:
                raise SchemaError(
                    f"agg({name}): unknown kind '{kind}'; "
                    f"one of {_AGG_KINDS}")
            if kind == "count":
                if in_col is not None:
                    raise SchemaError(
                        f"agg({name}): count takes None as its input column")
                out_schema[name] = dt.INT32
            else:
                if in_col not in schema:
                    raise SchemaError(
                        f"agg({name}): unknown column '{in_col}'; "
                        f"available: [{_fmt_cols(schema)}]")
                t = schema[in_col]
                if kind in ("sum", "avg") and not (t.is_numeric
                                                   or t.name == "bool"):
                    raise SchemaError(
                        f"agg({name}): {kind} over non-numeric column "
                        f"'{in_col}' of type {t}")
                if kind in ("min", "max") and t.name == "bytes":
                    raise SchemaError(
                        f"agg({name}): {kind} over bytes column '{in_col}' "
                        f"is unsupported")
                out_schema[name] = dt.FLOAT32 if kind == "avg" else t
            specs.append((name, kind, in_col))
        return parent._derive(
            P.Aggregation(parent.plan, list(self._keys), specs), out_schema)


def table(catalog, name: str,
          columns: Optional[Sequence[str]] = None) -> QueryBuilder:
    """Catalog-bound builder entry point (no session needed to build)."""
    return QueryBuilder.scan(catalog, name, columns)
