"""Expression trees: the Velox TypedExpr -> CudfExpression translation layer.

The paper translates Velox expression trees into cuDF AST expressions so a
whole projection/filter evaluates as one fused kernel (cudf::compute_column),
falling back to standalone per-op kernels when the AST lacks an operation.

In JAX the analogue is direct: an expression tree evaluates to a single
traced jnp computation, and XLA fuses it into one kernel. ``Expr.evaluate``
is the fused path; string predicates over fixed-width byte matrices are the
"standalone function" fallbacks (they lower to their own dot/reduce ops).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dtypes as dt
from .table import DeviceTable


class Expr:
    """Base class. Build with col()/lit() and python operators."""

    # -- operator sugar -----------------------------------------------------
    def _bin(self, op, other) -> "Expr":
        return BinaryOp(op, self, _wrap(other))

    def __add__(self, o): return self._bin("add", o)
    def __radd__(self, o): return BinaryOp("add", _wrap(o), self)
    def __sub__(self, o): return self._bin("sub", o)
    def __rsub__(self, o): return BinaryOp("sub", _wrap(o), self)
    def __mul__(self, o): return self._bin("mul", o)
    def __rmul__(self, o): return BinaryOp("mul", _wrap(o), self)
    def __truediv__(self, o): return self._bin("div", o)
    def __eq__(self, o): return self._bin("eq", o)          # type: ignore
    def __ne__(self, o): return self._bin("ne", o)          # type: ignore
    def __lt__(self, o): return self._bin("lt", o)
    def __le__(self, o): return self._bin("le", o)
    def __gt__(self, o): return self._bin("gt", o)
    def __ge__(self, o): return self._bin("ge", o)
    def __and__(self, o): return self._bin("and", o)
    def __or__(self, o): return self._bin("or", o)
    def __invert__(self): return UnaryOp("not", self)
    def __neg__(self): return UnaryOp("neg", self)
    def __hash__(self):  # __eq__ overload breaks default hash
        return id(self)

    def isin(self, values: Sequence[Any]) -> "Expr":
        """SQL ``IN``: true where the value equals any of ``values``."""
        return IsIn(self, tuple(values))

    def between(self, lo, hi) -> "Expr":
        """SQL ``BETWEEN``: inclusive range predicate."""
        return (self >= lo) & (self <= hi)

    def contains(self, *parts: str) -> "Expr":
        """LIKE '%a%b%' over a bytes column (ordered substring match)."""
        return BytesMatch(self, tuple(parts), "contains")

    def startswith(self, prefix: str) -> "Expr":
        """LIKE 'prefix%' over a bytes column."""
        return BytesMatch(self, (prefix,), "startswith")

    def endswith(self, suffix: str) -> "Expr":
        """LIKE '%suffix' over a (space-padded) bytes column."""
        return BytesMatch(self, (suffix,), "endswith")

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, table: DeviceTable) -> jax.Array:
        """Value of this expression over a batch (one traced jnp array;
        XLA fuses the whole tree into a single kernel)."""
        raise NotImplementedError

    def out_dtype(self, schema) -> dt.DType:
        """Result dtype given an input ``name -> DType`` schema."""
        raise NotImplementedError

    def references(self) -> set:
        """Set of column names this expression reads."""
        raise NotImplementedError


def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Literal(v)


@dataclasses.dataclass(eq=False)
class ColumnRef(Expr):
    """Reference to an input column by name (``col("l_quantity")``)."""

    name: str

    def evaluate(self, table):
        return table.columns[self.name]

    def out_dtype(self, schema):
        return schema[self.name]

    def references(self):
        return {self.name}

    def __repr__(self):
        return f"col({self.name})"


@dataclasses.dataclass(eq=False)
class Literal(Expr):
    """Constant scalar; dtype inferred from the python value if absent."""

    value: Any
    dtype: dt.DType = None  # inferred if None

    def __post_init__(self):
        if self.dtype is None:
            if isinstance(self.value, bool):
                self.dtype = dt.BOOL
            elif isinstance(self.value, (int, np.integer)):
                self.dtype = dt.INT32
            elif isinstance(self.value, float):
                self.dtype = dt.FLOAT32
            else:
                raise TypeError(f"cannot infer literal dtype for {self.value!r}")

    def evaluate(self, table):
        return jnp.asarray(self.value, dtype=self.dtype.jnp_dtype())

    def out_dtype(self, schema):
        return self.dtype

    def references(self):
        return set()

    def __repr__(self):
        return f"lit({self.value})"


_CMP = {"eq": jnp.equal, "ne": jnp.not_equal, "lt": jnp.less,
        "le": jnp.less_equal, "gt": jnp.greater, "ge": jnp.greater_equal}
_ARITH = {"add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
          "div": jnp.divide}
_BOOLOP = {"and": jnp.logical_and, "or": jnp.logical_or}


@dataclasses.dataclass(eq=False)
class BinaryOp(Expr):
    """Arithmetic/comparison/boolean operator over two subexpressions."""

    op: str
    lhs: Expr
    rhs: Expr

    def evaluate(self, table):
        a = self.lhs.evaluate(table)
        b = self.rhs.evaluate(table)
        if self.op in _CMP:
            return _CMP[self.op](a, b)
        if self.op in _BOOLOP:
            return _BOOLOP[self.op](a, b)
        fn = _ARITH[self.op]
        if self.op == "div":
            a = a.astype(jnp.float32) if jnp.issubdtype(a.dtype, jnp.integer) else a
        return fn(a, b)

    def out_dtype(self, schema):
        if self.op in _CMP or self.op in _BOOLOP:
            return dt.BOOL
        lt_ = self.lhs.out_dtype(schema)
        rt_ = self.rhs.out_dtype(schema)
        if self.op == "div" or "float" in (lt_.name, rt_.name) \
                or lt_.name.startswith("float") or rt_.name.startswith("float"):
            return dt.FLOAT32 if "float64" not in (lt_.name, rt_.name) else dt.FLOAT64
        # wider int wins
        return lt_ if lt_.np_dtype().itemsize >= rt_.np_dtype().itemsize else rt_

    def references(self):
        return self.lhs.references() | self.rhs.references()

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass(eq=False)
class UnaryOp(Expr):
    """``not`` / ``neg`` over one subexpression."""

    op: str
    operand: Expr

    def evaluate(self, table):
        v = self.operand.evaluate(table)
        return jnp.logical_not(v) if self.op == "not" else jnp.negative(v)

    def out_dtype(self, schema):
        return dt.BOOL if self.op == "not" else self.operand.out_dtype(schema)

    def references(self):
        return self.operand.references()


@dataclasses.dataclass(eq=False)
class IsIn(Expr):
    """Membership against a small literal set (SQL ``IN``)."""

    operand: Expr
    values: Tuple[Any, ...]

    def evaluate(self, table):
        v = self.operand.evaluate(table)
        out = jnp.zeros(v.shape, dtype=bool)
        for val in self.values:
            out = out | (v == val)
        return out

    def out_dtype(self, schema):
        return dt.BOOL

    def references(self):
        return self.operand.references()


@dataclasses.dataclass(eq=False)
class BytesMatch(Expr):
    """Substring predicates over fixed-width uint8 columns.

    contains('a','b') implements SQL LIKE '%a%b%': the parts must appear in
    order, non-overlapping. Implemented with vectorized sliding-window
    equality — the "standalone kernel" fallback path of the paper's mixed
    AST translation.
    """

    operand: Expr
    parts: Tuple[str, ...]
    mode: str  # contains | startswith | endswith

    def evaluate(self, table):
        data = self.operand.evaluate(table)  # uint8[N, W]
        n, width = data.shape
        if self.mode == "startswith":
            pat = np.frombuffer(self.parts[0].encode(), dtype=np.uint8)
            return jnp.all(data[:, : len(pat)] == jnp.asarray(pat), axis=1)
        if self.mode == "endswith":
            pat = np.frombuffer(self.parts[0].encode(), dtype=np.uint8)
            # rows are space padded; match against the trimmed end per row
            lengths = _row_lengths(data)
            idx = lengths[:, None] - len(pat) + jnp.arange(len(pat))[None, :]
            ok = idx >= 0
            gathered = jnp.take_along_axis(data, jnp.clip(idx, 0, width - 1), axis=1)
            return jnp.all((gathered == jnp.asarray(pat)) & ok, axis=1)
        # ordered multi-part contains
        earliest = jnp.zeros((n,), dtype=jnp.int32)  # min start for next part
        found_all = jnp.ones((n,), dtype=bool)
        for part in self.parts:
            pat = np.frombuffer(part.encode(), dtype=np.uint8)
            hits = _find_first(data, pat, earliest)  # -1 if absent
            found_all = found_all & (hits >= 0)
            earliest = jnp.where(hits >= 0, hits + len(pat), earliest)
        return found_all

    def out_dtype(self, schema):
        return dt.BOOL

    def references(self):
        return self.operand.references()


def _row_lengths(data: jax.Array) -> jax.Array:
    """Length of each space-padded row = 1 + last non-space position."""
    non_space = data != ord(" ")
    pos = jnp.arange(data.shape[1])[None, :]
    return jnp.max(jnp.where(non_space, pos + 1, 0), axis=1)


def _find_first(data: jax.Array, pat: np.ndarray, earliest: jax.Array) -> jax.Array:
    """First index >= earliest where ``pat`` occurs in each row, else -1."""
    n, width = data.shape
    m = len(pat)
    if m > width:
        return jnp.full((n,), -1, dtype=jnp.int32)
    nwin = width - m + 1
    # windows[i, j, k] = data[i, j + k]
    idx = jnp.arange(nwin)[:, None] + jnp.arange(m)[None, :]
    windows = data[:, idx]                                   # [N, nwin, m]
    match = jnp.all(windows == jnp.asarray(pat)[None, None, :], axis=2)
    match = match & (jnp.arange(nwin)[None, :] >= earliest[:, None])
    first = jnp.argmax(match, axis=1).astype(jnp.int32)
    any_ = jnp.any(match, axis=1)
    return jnp.where(any_, first, -1)


_YEAR_STARTS = np.array(
    [(np.datetime64(f"{y}-01-01") - np.datetime64("1970-01-01"))
     .astype("timedelta64[D]").astype(np.int32) for y in range(1970, 2040)],
    dtype=np.int32)


@dataclasses.dataclass(eq=False)
class Year(Expr):
    """EXTRACT(YEAR FROM date32) via searchsorted on year-start days."""

    operand: Expr

    def evaluate(self, table):
        days = self.operand.evaluate(table)
        idx = jnp.searchsorted(jnp.asarray(_YEAR_STARTS), days, side="right") - 1
        return (idx + 1970).astype(jnp.int32)

    def out_dtype(self, schema):
        return dt.INT32

    def references(self):
        return self.operand.references()


@dataclasses.dataclass(eq=False)
class PrefixCode(Expr):
    """First ``n`` bytes of a bytes column, decoded as a base-10 integer
    (SQL: cast(substring(col, 1, n) as int); used by Q22 country codes)."""

    operand: Expr
    n: int

    def evaluate(self, table):
        data = self.operand.evaluate(table)   # uint8[N, W]
        out = jnp.zeros(data.shape[0], dtype=jnp.int32)
        for i in range(self.n):
            out = out * 10 + (data[:, i].astype(jnp.int32) - ord("0"))
        return out

    def out_dtype(self, schema):
        return dt.INT32

    def references(self):
        return self.operand.references()


def year(e: Expr) -> Year:
    """EXTRACT(YEAR) from a date32 expression."""
    return Year(e)


def prefix_code(e: Expr, n: int) -> PrefixCode:
    """Integer decode of the first ``n`` bytes of a bytes column."""
    return PrefixCode(e, n)


def col(name: str) -> ColumnRef:
    """Reference a column by name: ``col("l_quantity") * 2.0``."""
    return ColumnRef(name)


def lit(value, dtype: dt.DType = None) -> Literal:
    """Literal scalar (dtype inferred from the python type if omitted)."""
    return Literal(value, dtype)


def date_lit(iso: str) -> Literal:
    """Date literal from 'YYYY-MM-DD', as int32 days since epoch."""
    return Literal(dt.date_to_i32(iso), dt.DATE32)
