"""Concurrent query scheduler: admission control + plan/result caching.

The paper's Presto integration is a *serving* system: the coordinator admits
many concurrent queries and the GPU workers multiplex them under a fixed
device-memory budget. This module is that layer for the repro engine — it
turns the one-query-at-a-time ``Session`` into a serving engine:

* **Admission control** — every query's peak device-memory footprint is
  estimated from its optimized plan (``optimizer.estimate_memory_breakdown``:
  scan prefetch windows, ``max_groups``/``max_matches`` capacities, join
  build sides). Queries are admitted only while the sum of in-flight
  estimates fits ``SchedulerConfig.memory_budget``; the rest wait in a
  bounded priority queue. A query whose footprint exceeds the whole budget
  is **admitted with spilling** instead of rejected: it runs under a
  per-query ``core.spill.SpillManager`` (the tiered-memory hierarchy:
  device reservations -> pinned host buffers -> paged disk files) and pays
  a priced slowdown (``QueryHandle.spill_plan``). Only a footprint past
  ``SchedulerConfig.spill_disk_ceiling`` — beyond what even the disk tier
  absorbs — or a full wait queue is rejected (``QueryRejected``), so
  callers get backpressure instead of unbounded latency; the rejection
  message carries the per-operator footprint breakdown and spill-cost
  estimate so it is explainable from the exception alone.

* **Interleaved execution** — admitted queries run on a pool of
  ``max_concurrency`` worker threads, each driving its own ``Driver``.
  Because every scan goes through ``MorselPrefetcher`` (a background
  storage-read + device-put thread per scan), the morsel pipelines of
  different queries overlap: query A's operators compute while query B's
  scan reads from storage.

* **Plan cache** — optimization is skipped for repeated query *shapes*:
  the canonicalized logical plan (``plan.fingerprint``) maps to its
  optimized tree. Entries snapshot the versions of every referenced table
  (optimizer decisions depend on catalog stats) and are invalidated when a
  table is re-registered.

* **Result cache** — a bounded LRU from plan fingerprint to collected
  result, also version-snapshotted: re-registering any referenced table
  invalidates the entry (the tests cover exactly this). Hits complete
  without reserving memory or occupying a worker. Identical queries
  submitted *while one is still in flight* coalesce onto the running
  handle instead of executing twice.

Entry points live on ``Session``: ``submit()`` returns a ``QueryHandle``
future, ``gather()`` awaits many, ``run()`` is the synchronous wrapper.
``examples/serve_queries.py`` demonstrates N concurrent TPC-H clients;
``benchmarks/bench_concurrency.py`` measures throughput and latency.
"""

from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from ..kernels import ops as kernel_ops
from . import batch as _batch
from . import plan as P
from ..kernels import segmented_agg as _segagg
from .driver import Driver, empty_executor_stats
from .feedback import qerror
from .optimizer import estimate_memory_breakdown, feedback_estimates, optimize


class QueryRejected(RuntimeError):
    """Admission control refused the query (footprint beyond even the
    spill disk ceiling, or queue full). The message carries the
    per-operator footprint breakdown and spill-cost estimate."""


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Knobs for admission control and the two caches.

    The defaults suit a CPU-JAX dev box; a real deployment sets
    ``memory_budget`` to the device's free HBM and ``max_concurrency`` to
    the number of independent query pipelines the device can overlap.
    """

    # total device-memory budget admitted queries may collectively pin
    memory_budget: int = 1 << 30
    # worker threads driving admitted queries (concurrent pipelines)
    max_concurrency: int = 8
    # bounded wait queue: submits beyond this are rejected (backpressure)
    max_queue: int = 64
    # LRU capacities for the two caches (entries, not bytes)
    plan_cache_size: int = 64
    result_cache_size: int = 64
    # serve repeated identical queries from the result cache
    cache_results: bool = True
    # anti-starvation: after the queue head has been passed over this many
    # times for smaller queries, backfilling stops until the head fits
    max_head_skips: int = 16
    # tiered-memory spill for over-budget queries (core.spill): host-tier
    # cap, the only hard limit (footprint past the disk ceiling rejects),
    # and where the paged spill files go (None = per-query temp dirs)
    spill_host_budget: int = 1 << 31
    spill_disk_ceiling: int = 1 << 38
    spill_dir: Optional[str] = None
    # inter-query batching (core.batch): when True, a worker that dequeues
    # a batchable query (single-table filter/project/agg shape, W=1, no
    # feedback store, no spill) waits up to batch_window_ms for compatible
    # pending queries — same interned program, kernel backend, and catalog
    # snapshot — and launches up to max_batch of them as ONE stacked
    # execution, splitting results per handle on the way out. Strictly
    # opt-in: when False no query grows batch state and the dispatch path
    # is byte-for-byte the solo one.
    batching: bool = False
    batch_window_ms: float = 2.0
    max_batch: int = 16
    # adaptive re-planning: a cached plan whose believed cardinalities
    # (static bounds, or the feedback observations it was planned from)
    # miss the fresh post-execution observations by more than this q-error
    # is evicted from the plan cache, so the next identical submit
    # re-optimizes against the updated feedback store. Feedback-planned
    # entries converge (estimate == observation) and stay cached.
    feedback_qerror_limit: float = 4.0


class QueryHandle:
    """Future-style handle for one submitted query.

    ``result()`` blocks until the query finishes and returns the collected
    numpy dict (or re-raises the query's error / rejection). Timing fields
    (``submitted_at``/``started_at``/``finished_at``, absent until reached)
    let callers derive queue wait and run time; ``cache_hit`` says the
    result came from the result cache.
    """

    def __init__(self, query_id: int, plan: P.PlanNode, priority: int,
                 estimate: int):
        self.query_id = query_id
        self.plan = plan
        self.priority = priority
        self.estimate = estimate       # bytes charged against the budget
        self.footprint = estimate      # un-capped estimated peak footprint
        # optimizer.MemoryEstimate per-operator breakdown (None for
        # result-cache hits, which never reach estimation)
        self.memory_breakdown = None
        # admit-with-spill pricing (spill_cost dict) when the footprint
        # exceeded the memory budget; None for in-budget queries
        self.spill_plan: Optional[Dict] = None
        self.cache_hit = False
        self.plan_cache_hit = False
        # kernel backend pinned at submit time (None until admitted)
        self.kernel_backend: Optional[str] = None
        # worker count pinned at submit time (exchange placement and the
        # plan/result cache keys depend on it)
        self.num_workers: int = 1
        self._queue_skips = 0          # times passed over by backfilling
        self._versions: tuple = ()     # admission-time catalog snapshot
        # adaptive execution: the feedback store resolved at submit time,
        # the plan-cache key of the optimized entry, and the cardinalities
        # the plan was optimized under (store key -> believed rows) — the
        # post-execution q-error check compares these against the fresh
        # observations and evicts the cached plan when they drifted
        self._feedback = None
        self._plan_key: str = ""
        self._est_map: Dict[str, int] = {}
        # inter-query batching: the extracted stacked-program membership
        # (core.batch.BatchShape) and the compatibility key the worker
        # groups on — (interned program identity, kernel backend); both
        # None when batching is off or the plan is ineligible
        self._batch_shape = None
        self._batch_key: Optional[tuple] = None
        self.submitted_at = time.perf_counter()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # same key shape as driver.empty_executor_stats() until the query
        # runs, so callers can index the dict without a done() check
        self.executor_stats: Dict[str, object] = empty_executor_stats()
        self._done = threading.Event()
        self._result: Optional[Dict] = None
        self._error: Optional[BaseException] = None

    # -- completion (scheduler side) ----------------------------------------
    def _complete(self, result=None, error=None) -> None:
        self._result, self._error = result, error
        self.finished_at = time.perf_counter()
        self._done.set()

    # -- consumption (client side) ------------------------------------------
    def done(self) -> bool:
        """True once the query finished (successfully or not)."""
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Dict:
        """Block until finished; return the collected columns dict.

        Re-raises the query's exception on failure; raises ``TimeoutError``
        if ``timeout`` (seconds) elapses first. The returned arrays may be
        shared with the result cache and coalesced handles — treat them as
        read-only.
        """
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query {self.query_id} still running after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-finish seconds (None while still running)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class _VersionedLRU:
    """Bounded LRU whose entries carry a catalog-version snapshot.

    A lookup re-validates the snapshot against the live catalog; any bumped
    table version evicts the entry (re-registered table == new data).
    Internally locked: client threads get/put concurrently with workers.
    """

    def __init__(self, capacity: int):
        self.capacity = max(int(capacity), 0)
        self._od: "OrderedDict[str, Tuple[tuple, object]]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str, catalog):
        with self._lock:
            entry = self._od.get(key)
            if entry is not None:
                versions, value = entry
                if catalog.versions([n for n, _ in versions]) == versions:
                    self._od.move_to_end(key)
                    self.hits += 1
                    return value
                del self._od[key]       # stale: a table was re-registered
            self.misses += 1
            return None

    def put(self, key: str, versions: tuple, value) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._od[key] = (versions, value)
            self._od.move_to_end(key)
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)

    def invalidate(self, key: str) -> None:
        """Drop ``key`` if present (the adaptive q-error eviction path)."""
        with self._lock:
            self._od.pop(key, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)


def referenced_tables(plan: P.PlanNode) -> List[str]:
    """Catalog tables a plan reads (cache-invalidation scope)."""
    names: List[str] = []

    def visit(node: P.PlanNode) -> None:
        if isinstance(node, P.TableScan):
            names.append(node.table)
        for c in node.children():
            visit(c)

    visit(plan)
    return sorted(set(names))


class QueryScheduler:
    """Admits, caches, and concurrently executes queries for one Session.

    Example (synchronous clients are threads; the scheduler interleaves
    their pipelines)::

        from repro.core import Session
        from repro.core.scheduler import SchedulerConfig
        from repro.tpch import dbgen, queries

        session = Session(dbgen.load_catalog(sf=0.002))
        session.scheduler_config = SchedulerConfig(memory_budget=256 << 20)
        handles = [session.submit(queries.build_query(q, session.catalog))
                   for q in (1, 6, 14)]
        results = session.gather(*handles)   # list of numpy dicts

    Thread-safe; one instance serves arbitrarily many client threads.
    """

    def __init__(self, session, config: Optional[SchedulerConfig] = None):
        self.session = session
        self.config = config or SchedulerConfig()
        self.plan_cache = _VersionedLRU(self.config.plan_cache_size)
        self.result_cache = _VersionedLRU(
            self.config.result_cache_size if self.config.cache_results else 0)
        self._cond = threading.Condition()
        self._pending: List[Tuple[int, int, QueryHandle]] = []   # heap
        self._mem_in_use = 0
        self._running = 0
        self._closed = False
        self._seq = itertools.count()
        self._ids = itertools.count(1)
        self._threads: List[threading.Thread] = []
        # in-flight coalescing: fingerprint -> queued/running handle, so N
        # simultaneous identical queries execute once and share the result
        self._inflight: Dict[str, QueryHandle] = {}
        # served-query counters (exposed via stats())
        self.completed = 0
        self.failed = 0
        self.rejected = 0
        self.coalesced = 0
        self.spill_admitted = 0
        self.batches = 0           # stacked launches (>= 2 members each)
        self.batched_queries = 0   # queries served via a stacked launch

    # -- public API ---------------------------------------------------------
    def submit(self, plan: P.PlanNode, priority: int = 0,
               sql: Optional[str] = None,
               num_workers: Optional[int] = None,
               kernel_backend: Optional[str] = None,
               optimize: Optional[bool] = None,
               feedback: Optional[object] = None,
               batching: Optional[bool] = None) -> QueryHandle:
        """Admit ``plan`` for execution; returns a ``QueryHandle``.

        Raises ``QueryRejected`` when the query could never fit the memory
        budget, or when the wait queue is full (backpressure). Higher
        ``priority`` dequeues first; ties run in submission order. A
        duplicate of an in-flight query coalesces onto its handle (raising
        that handle's queue priority if the duplicate's is higher).

        ``sql``/``num_workers``/``kernel_backend``/``optimize`` carry
        per-query ``ExecutionOptions`` overrides: queries born from SQL
        text prefix their plan/result cache keys with a hash of that text,
        worker-count and backend overrides are pinned on the handle (and
        keyed), and ``optimize=False`` runs the raw plan as-is.

        ``batching=False`` opts this query out of inter-query batching
        even when ``SchedulerConfig.batching`` is on (it has no effect
        when the config flag is off — batching is strictly opt-in).
        """
        # the kernel backend is resolved ONCE, here at submit time (the
        # per-query override, else the session's setting, else the
        # submitting thread's use_backend() scope / env default), and
        # pinned on the handle: the worker's ExecutionContext executes
        # with exactly this backend, and the cache keys carry it -- so
        # flipping the backend between submit and execution can never
        # serve (or store) a result under the wrong backend's key, and
        # ``with use_pallas(): session.run(q)`` behaves like the batch
        # path
        backend = (kernel_backend
                   or self.session.kernel_backend
                   or kernel_ops.current_backend())
        w = num_workers if num_workers is not None \
            else self.session.num_workers
        # adaptive execution: resolve the feedback store once, here, and
        # pin it on the handle (the per-query override, else the session's
        # store). True means an ephemeral per-query store; False disables
        # the session store for this query.
        if feedback is None:
            fb = self.session.feedback_store()
        elif feedback is True:
            from .feedback import FeedbackStore
            fb = FeedbackStore()
        elif feedback is False:
            fb = None
        else:
            fb = feedback
        # SQL-born queries prefix their cache keys with the text's hash:
        # two different SQL texts that happen to lower to the same logical
        # plan still share nothing, so a frontend fix that changes the
        # lowering can never serve a stale result cached under the old
        # reading of the same text
        sql_prefix = ""
        if sql is not None:
            digest = hashlib.sha1(sql.encode("utf-8")).hexdigest()[:16]
            sql_prefix = f"sql={digest}:"
        # the feedback flag is part of the key: a warm (feedback-planned)
        # tree and the static plan of the same query differ, so neither
        # cache may serve one where the other was requested
        key = (f"{sql_prefix}w{w}:k={backend}:fb{int(fb is not None)}:"
               f"{P.fingerprint(plan)}")
        # result cache first: a hit skips optimization entirely
        cached = self.result_cache.get(key, self.session.catalog)
        if cached is not None:
            handle = QueryHandle(next(self._ids), plan, priority, 0)
            handle.kernel_backend = backend
            handle.num_workers = w
            handle.cache_hit = True
            handle.started_at = time.perf_counter()
            handle._complete(result=cached)
            with self._cond:
                self.completed += 1
            return handle

        if optimize is False:
            optimized, est_map, plan_hit = plan, {}, False
        else:
            optimized, est_map, plan_hit = self._optimized(plan, key, w, fb)
        try:
            breakdown = estimate_memory_breakdown(
                optimized, self.session.catalog,
                num_workers=w,
                batch_rows=self.session.batch_rows,
                prefetch_depth=self.session.prefetch_depth,
                feedback=fb)
            est = breakdown.total
        except TypeError:
            if optimize is not False:
                raise
            # un-optimized plans may lack derived capacities; admit them
            # conservatively with no estimate rather than refuse
            breakdown, est = None, 0
        # over-budget queries are admitted with spilling: they charge the
        # whole budget (running effectively alone) and degrade through the
        # host/disk tiers instead of being refused
        handle = QueryHandle(next(self._ids), optimized, priority,
                             min(est, self.config.memory_budget))
        handle.footprint = est
        handle.memory_breakdown = breakdown
        handle.plan_cache_hit = plan_hit
        handle.kernel_backend = backend
        handle.num_workers = w
        handle._feedback = fb
        handle._plan_key = "opt:" + key
        handle._est_map = est_map
        # version snapshot taken NOW: if a table is re-registered while the
        # query runs, the snapshot no longer matches at the next lookup and
        # the (stale) result is never served from cache
        handle._versions = self.session.catalog.versions(
            referenced_tables(optimized))

        if est > self.config.spill_disk_ceiling:
            with self._cond:
                self.rejected += 1
            raise QueryRejected(
                f"query footprint ~{est} B exceeds the scheduler's "
                f"memory budget of {self.config.memory_budget} B and the "
                f"spill disk ceiling of {self.config.spill_disk_ceiling} B; "
                f"raise SchedulerConfig.spill_disk_ceiling or shrink the "
                "query\n"
                + breakdown.describe(self.config.memory_budget,
                                     self.config.spill_host_budget))
        if est > self.config.memory_budget:
            handle.spill_plan = breakdown.spill_cost(
                self.config.memory_budget, self.config.spill_host_budget)
            with self._cond:
                self.spill_admitted += 1
        # inter-query batching: only when the config opts in (so the
        # disabled path never even inspects the plan), the query didn't
        # opt out, and the execution mode is the simple one a stacked
        # launch can reproduce exactly — optimized W=1 plan, no feedback
        # store (batched runs skip feedback harvesting), no spill
        if (self.config.batching and batching is not False
                and optimize is not False and fb is None
                and handle.spill_plan is None and w == 1):
            shape = _batch.extract_shape(optimized)
            if shape is not None:
                handle._batch_shape = shape
                handle._batch_key = (shape.program, backend)
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if self.config.cache_results:
                existing = self._inflight.get(key)
                if (existing is not None and not existing.done()
                        and self.session.catalog.versions(
                            [n for n, _ in existing._versions])
                        == existing._versions):
                    # identical query already queued/running against
                    # still-current table versions: share its handle
                    # instead of executing twice (request coalescing);
                    # a more urgent duplicate promotes the queued entry.
                    # A version mismatch (table re-registered since the
                    # in-flight query was admitted) falls through to a
                    # fresh execution — coalescing never serves stale data.
                    self.coalesced += 1
                    if priority > existing.priority:
                        existing.priority = priority
                        for i, (_, seq, h) in enumerate(self._pending):
                            if h is existing:
                                self._pending[i] = (-priority, seq, h)
                                heapq.heapify(self._pending)
                                break
                    return existing
            if len(self._pending) >= self.config.max_queue:
                self.rejected += 1
                raise QueryRejected(
                    f"wait queue full ({self.config.max_queue} queries); "
                    f"retry later (backpressure)")
            handle._result_key = key
            self._inflight[key] = handle
            heapq.heappush(self._pending,
                           (-priority, next(self._seq), handle))
            self._ensure_workers()
            self._cond.notify_all()
        return handle

    def gather(self, *handles: QueryHandle) -> List[Dict]:
        """Wait for every handle; returns results in argument order.

        Re-raises the first failed query's exception (after all have
        finished, so no work is silently abandoned).
        """
        for h in handles:
            h._done.wait()
        return [h.result() for h in handles]

    def run(self, plan: P.PlanNode, priority: int = 0) -> Dict:
        """Synchronous submit-and-wait (the serving path for one query)."""
        return self.submit(plan, priority).result()

    def stats(self) -> Dict[str, int]:
        """Served/rejected counters and cache hit/miss totals."""
        with self._cond:
            return {
                "completed": self.completed,
                "failed": self.failed,
                "rejected": self.rejected,
                "coalesced": self.coalesced,
                "spill_admitted": self.spill_admitted,
                "batches": self.batches,
                "batched_queries": self.batched_queries,
                "queued": len(self._pending),
                "running": self._running,
                "mem_in_use": self._mem_in_use,
                "plan_cache_hits": self.plan_cache.hits,
                "plan_cache_misses": self.plan_cache.misses,
                "result_cache_hits": self.result_cache.hits,
                "result_cache_misses": self.result_cache.misses,
            }

    def close(self, wait: bool = True) -> None:
        """Stop accepting queries; optionally wait for workers to drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if wait:
            for t in self._threads:
                t.join(timeout=30.0)

    # -- internals ----------------------------------------------------------
    def _optimized(self, plan: P.PlanNode, raw_key: str, w: int,
                   fb: Optional[object]
                   ) -> Tuple[P.PlanNode, Dict[str, int], bool]:
        """Optimized plan via the plan cache. ``raw_key`` already carries
        the SQL-text prefix (when the query came from ``Session.sql``), the
        planned worker count (exchange placement makes the physical plan
        W-dependent), the backend, the feedback flag, and the raw tree's
        fingerprint. Versions are snapshot *before* optimization, which
        reads catalog stats. Entries store ``(optimized, est_map)`` where
        ``est_map`` is the per-node cardinality belief the plan was
        derived under (``optimizer.feedback_estimates``); the q-error
        check after execution compares it against fresh observations."""
        key = "opt:" + raw_key
        cached = self.plan_cache.get(key, self.session.catalog)
        if cached is not None:
            optimized, est_map = cached
            return optimized, est_map, True
        versions = self.session.catalog.versions(referenced_tables(plan))
        config = dataclasses.replace(self.session.optimizer_config(),
                                     num_workers=w, feedback=fb)
        optimized = optimize(plan, self.session.catalog, config=config)
        est_map = (feedback_estimates(optimized, self.session.catalog, config)
                   if fb is not None else {})
        self.plan_cache.put(key, versions, (optimized, est_map))
        return optimized, est_map, False

    def _ensure_workers(self) -> None:
        """Lazily grow the worker pool up to ``max_concurrency`` (held lock)."""
        alive = sum(1 for t in self._threads if t.is_alive())
        want = min(self.config.max_concurrency,
                   len(self._pending) + self._running)
        for i in range(alive, want):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"query-sched-{i}")
            t.start()
            self._threads.append(t)

    def _pick(self) -> Optional[QueryHandle]:
        """Highest-priority pending query that fits the remaining budget
        (held lock). Skipping an over-budget head is deadlock-free: when
        nothing is running the full budget is free, and submit() already
        rejected anything larger than that. To prevent a big head being
        starved by a stream of small backfills, a head that has been
        skipped ``max_head_skips`` times blocks further backfilling until
        it fits (the budget drains as running queries finish)."""
        if not self._pending:
            return None
        remaining = self.config.memory_budget - self._mem_in_use
        head = min(self._pending)               # heap order: priority, FIFO
        if head[2].estimate <= remaining:
            entry = head
        else:
            if head[2]._queue_skips >= self.config.max_head_skips:
                return None                     # drain until the head fits
            fits = [e for e in self._pending if e[2].estimate <= remaining]
            if not fits:
                return None
            # the head is genuinely passed over for a smaller query: only
            # real backfills age it, not idle worker polls
            head[2]._queue_skips += 1
            entry = min(fits)
        self._pending.remove(entry)
        heapq.heapify(self._pending)
        return entry[2]

    def _worker(self) -> None:
        while True:
            with self._cond:
                handle = self._pick()
                while handle is None:
                    if self._closed and not self._pending:
                        return
                    self._cond.wait(timeout=0.1)
                    handle = self._pick()
                self._mem_in_use += handle.estimate
                self._running += 1
                members = [handle]
                if self.config.batching and handle._batch_key is not None:
                    members += self._claim_batch(handle)
            try:
                if len(members) > 1:
                    self._execute_batch(members)
                else:
                    self._execute(handle)
            finally:
                with self._cond:
                    for m in members:
                        self._mem_in_use -= m.estimate
                        self._running -= 1
                        if self._inflight.get(m._result_key) is m:
                            del self._inflight[m._result_key]
                    self._cond.notify_all()

    def _claim_batch(self, leader: QueryHandle) -> List[QueryHandle]:
        """Claim pending queries compatible with ``leader`` for one stacked
        launch (held lock). Compatibility is the leader's batch key — the
        interned program identity (which encodes table, columns, stage
        shape, aggregation, and W=1) plus the kernel backend — and an
        identical catalog-version snapshot, so a batch can never mix data
        generations. The worker waits up to ``batch_window_ms`` for
        stragglers; a keyed aggregation caps the batch at
        ``kernels.segmented_agg.stacked_group_capacity`` so the stacked
        segmented problem stays inside the kernel dispatch bound (a query
        whose ``max_groups`` alone exceeds it degrades to solo execution).
        Claimed members charge their full admission estimates — a
        conservative over-charge, since the stacked run shares one scan."""
        limit = self._batch_limit(leader._batch_shape.program)
        members: List[QueryHandle] = []
        deadline = time.perf_counter() + self.config.batch_window_ms / 1000.0
        while True:
            if len(members) + 1 < limit:
                claimed = []
                for entry in self._pending:
                    h = entry[2]
                    if (h._batch_key == leader._batch_key
                            and h._versions == leader._versions):
                        claimed.append(entry)
                        if len(members) + 1 + len(claimed) >= limit:
                            break
                for entry in claimed:
                    self._pending.remove(entry)
                    h = entry[2]
                    self._mem_in_use += h.estimate
                    self._running += 1
                    members.append(h)
                if claimed:
                    heapq.heapify(self._pending)
            if len(members) + 1 >= limit:
                break
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            # releases the lock: submits land while we wait, and the loop
            # top sweeps them up (one final sweep after the window closes)
            self._cond.wait(remaining)
        return members

    def _batch_limit(self, program) -> int:
        """Per-program member cap for one stacked launch: ``max_batch``,
        tightened for keyed aggregations so the stacked segmented problem
        (``lanes * max_groups`` groups) stays inside the kernel dispatch
        bound."""
        limit = self.config.max_batch
        if program.group_keys:
            limit = min(limit,
                        _segagg.stacked_group_capacity(program.max_groups))
        return limit

    def _execute_batch(self, members: List[QueryHandle]) -> None:
        """Run a claimed group as ONE stacked execution, scattering the
        per-member results (and per-query stats attribution) back onto
        each handle. Any stacked failure falls back to per-member solo
        execution — a query that would succeed alone must never receive
        a batched error."""
        t_launch = time.perf_counter()
        for m in members:
            m.started_at = t_launch
        try:
            leader = members[0]
            sess = self.session
            if leader.num_workers != sess.num_workers:
                sess = dataclasses.replace(
                    sess, num_workers=leader.num_workers)
            ctx = sess.context()
            ctx = dataclasses.replace(
                ctx, kernel_backend=leader.kernel_backend, feedback=None)
            if self.session.exchange is not None:
                ctx = dataclasses.replace(
                    ctx, exchange=self.session.exchange.clone())
            driver = Driver(ctx)
            # lane count pinned to the per-program cap, not the claimed
            # size: every launch of this program reuses ONE compiled
            # stacked executable no matter how the claim races land
            lanes = _batch.padded_members(
                self._batch_limit(members[0]._batch_shape.program))
            results = driver.collect_batch(
                [m._batch_shape for m in members], lanes=lanes)
            stats = driver.executor_stats()
            for m, result in zip(members, results):
                es = dict(stats)
                es["batch"] = {"size": len(members),
                               "queue_delay_s": t_launch - m.submitted_at}
                m.executor_stats = es
                self.result_cache.put(m._result_key, m._versions, result)
                m._complete(result=result)
            with self._cond:
                self.completed += len(members)
                self.batches += 1
                self.batched_queries += len(members)
        except BaseException:  # noqa: BLE001 -- solo fallback delivers
            for m in members:
                self._execute(m)

    def _execute(self, handle: QueryHandle) -> None:
        """Run one admitted query on this worker thread's own Driver."""
        handle.started_at = time.perf_counter()
        try:
            sess = self.session
            if handle.num_workers != sess.num_workers:
                # per-query worker-count override: rebuild the context
                # from a session clone so the exchange/mesh wiring matches
                # the W the plan was optimized for
                sess = dataclasses.replace(
                    sess, num_workers=handle.num_workers)
            ctx = sess.context()
            # pin the backend resolved at submit time (the cache key was
            # computed from it; the worker thread's ambient default may
            # differ by now)
            ctx = dataclasses.replace(
                ctx, kernel_backend=handle.kernel_backend,
                feedback=handle._feedback)
            if self.session.exchange is not None:
                # don't share one protocol's mutable stats across
                # concurrent queries: each Driver gets a fresh clone
                ctx = dataclasses.replace(
                    ctx, exchange=self.session.exchange.clone())
            if handle.spill_plan is not None and ctx.spill is None:
                # admitted over budget: run under a per-query spill
                # manager whose device budget is the scheduler's whole
                # budget (the query charged all of it, so it runs alone)
                from .spill import SpillManager
                ctx = dataclasses.replace(ctx, spill=SpillManager(
                    self.config.memory_budget,
                    self.config.spill_host_budget,
                    spill_dir=self.config.spill_dir,
                    disk_ceiling=self.config.spill_disk_ceiling))
            driver = Driver(ctx)
            result = driver.collect(handle.plan)
            handle.executor_stats = driver.executor_stats()
            self._check_feedback(handle)
            self.result_cache.put(handle._result_key, handle._versions,
                                  result)
            handle._complete(result=result)
            with self._cond:
                self.completed += 1
        except BaseException as exc:  # noqa: BLE001 -- delivered via handle
            handle._complete(error=exc)
            with self._cond:
                self.failed += 1

    def _check_feedback(self, handle: QueryHandle) -> None:
        """Adaptive plan-cache invalidation: after a feedback-enabled
        query runs, compare the cardinalities its cached plan was derived
        under (``handle._est_map``) against the observations the driver
        just harvested. A q-error past ``feedback_qerror_limit`` on any
        node means the plan's capacities/ordering were priced from stale
        beliefs — evict the entry so the next identical submit re-plans
        from the updated store. Warm (feedback-planned) entries have
        estimate == observation and survive, so the loop converges."""
        fb = handle._feedback
        if fb is None or not handle._est_map:
            return
        worst = 1.0
        for key, est in handle._est_map.items():
            entry = fb.get(key)
            if entry is not None:
                worst = max(worst, qerror(est, entry.rows))
        if worst > self.config.feedback_qerror_limit:
            self.plan_cache.invalidate(handle._plan_key)
