"""Morsel-driven streaming scan infrastructure (paper §2.2, challenge 1).

The paper's first critical challenge is moving data from storage into GPU
operators fast enough that the devices never starve: their minimal
column-chunk format reached 95% of the hardware I/O bound *because* reads
overlap with device compute. This module supplies the pieces shared by every
``TableSource``:

* ``HostMorsel``       -- one scan unit (a worker-stacked chunk of columns)
                          still in host memory, before the device transfer.
* ``MorselPrefetcher`` -- a bounded-queue background producer: while the
                          consumer computes on morsel N, the prefetch thread
                          reads morsel N+1 from storage and places it on the
                          device (double buffering at the default depth 2).
* ``ScanStats``        -- per-scan counters (bytes read, bytes transferred,
                          chunks skipped, prefetch overlap) surfaced through
                          ``Session.explain(plan, analyze=True)``.

Storage backends implement ``TableSource._host_morsels`` (pure host-side
reads); ``TableSource.scan``/``TableSource.stream`` in ``session.py`` wrap
that generator synchronously or through a prefetcher respectively.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .table import DeviceTable


@dataclasses.dataclass
class ScanStats:
    """Counters for one table's scan activity within a query."""

    bytes_read: int = 0          # bytes read from storage (post-skipping)
    bytes_transferred: int = 0   # bytes placed into device memory
    chunks_total: int = 0        # chunks considered by the scan
    chunks_skipped: int = 0      # chunks pruned by zone-map stats
    morsels: int = 0             # morsels produced
    read_seconds: float = 0.0    # producer: storage read + host->device put
    wait_seconds: float = 0.0    # consumer: blocked waiting on the queue
    compute_seconds: float = 0.0 # consumer: time between dequeues

    @property
    def prefetch_overlap(self) -> float:
        """Fraction of read+transfer time hidden behind consumer compute."""
        if self.read_seconds <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.wait_seconds / self.read_seconds)

    def summary(self) -> Dict[str, float]:
        """Counters as a plain dict, with derived ``prefetch_overlap``."""
        d = dataclasses.asdict(self)
        d["prefetch_overlap"] = round(self.prefetch_overlap, 4)
        return d


@dataclasses.dataclass
class HostMorsel:
    """One scan unit in host memory: worker-stacked ``[W, cap, ...]`` column
    buffers plus validity, ready for a single interpretation-free device put
    (the paper's memmap -> device_put read path)."""

    columns: Dict[str, np.ndarray]
    validity: np.ndarray
    schema: Dict[str, object]

    def nbytes(self) -> int:
        """Host bytes this morsel occupies (columns + validity)."""
        total = self.validity.nbytes
        for a in self.columns.values():
            total += a.nbytes
        return int(total)


def empty_morsel(schema: Dict[str, object], num_workers: int) -> HostMorsel:
    """A capacity-1, zero-valid-rows morsel with the scan's schema (keeps
    downstream operator shapes alive when a scan prunes everything)."""
    cols = {}
    for c, d in schema.items():
        shape = ((num_workers, 1, d.width) if d.name == "bytes"
                 else (num_workers, 1))
        cols[c] = np.zeros(shape, dtype=d.np_dtype())
    return HostMorsel(cols, np.zeros((num_workers, 1), dtype=bool),
                      dict(schema))


def stacked_morsel(cols, schema, num_workers: int, assigned, cap: int,
                   read) -> HostMorsel:
    """Stack one storage chunk per worker into a ``[W, cap]`` host morsel.

    ``assigned`` lists the chunk ids for workers 0..len(assigned)-1 (a final
    short round leaves the remaining workers all-invalid); ``read(col,
    chunk)`` returns that chunk's column values. Shared by the chunked
    storage backends.
    """
    cap = max(cap, 1)
    validity = np.zeros((num_workers, cap), dtype=bool)
    out = {}
    for c in cols:
        d = schema[c]
        shape = ((num_workers, cap, d.width) if d.name == "bytes"
                 else (num_workers, cap))
        buf = np.zeros(shape, dtype=d.np_dtype())
        for wi, k in enumerate(assigned):
            arr = read(c, k)
            buf[wi, : len(arr)] = arr
            validity[wi, : len(arr)] = True
        out[c] = buf
    return HostMorsel(out, validity, {c: schema[c] for c in cols})


def morsel_to_device(morsel, sharding=None) -> DeviceTable:
    """Place a host morsel into device memory (optionally mesh-sharded).
    Tables that are already on device pass through (legacy sources whose
    scan() yields DeviceTables directly)."""
    if isinstance(morsel, DeviceTable):
        return (jax.device_put(morsel, sharding) if sharding is not None
                else morsel)
    if sharding is not None:
        put = lambda a: jax.device_put(a, sharding)  # noqa: E731
    else:
        put = jnp.asarray
    cols = {n: put(a) for n, a in morsel.columns.items()}
    return DeviceTable(cols, put(morsel.validity), dict(morsel.schema))


_SENTINEL = object()


class MorselPrefetcher:
    """Async double-buffered storage->device prefetcher.

    A daemon thread drains ``host_morsels`` (storage reads), performs the
    host->device transfer, and pushes ready ``DeviceTable`` morsels into a
    bounded queue of ``depth`` slots: while the consumer computes on morsel
    N, morsel N+1 is being read and transferred. The queue bound caps device
    memory at ``depth`` in-flight morsels beyond the one being computed.

    The bound is additionally **bytes-aware**: with a ``host_budget``
    (``core.spill.HostMemoryBudget``, shared with the spill manager's host
    tier) or a private ``max_bytes`` cap, the producer blocks until the
    buffered morsels' host bytes fit the budget -- so prefetch participates
    in the same host-memory accounting as spilled partitions instead of
    only counting morsels.

    Iteration is single-consumer. Abandoning the iterator early (e.g. a
    Limit downstream) stops the producer; producer exceptions re-raise in
    the consumer.
    """

    def __init__(self, host_morsels: Iterator[HostMorsel], depth: int = 2,
                 sharding=None, stats: Optional[ScanStats] = None,
                 host_budget=None, max_bytes: Optional[int] = None):
        self.stats = stats if stats is not None else ScanStats()
        self._gen = host_morsels
        self._sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=max(int(depth), 1))
        if host_budget is None and max_bytes is not None:
            from .spill import HostMemoryBudget
            host_budget = HostMemoryBudget(max_bytes)
        self._budget = host_budget
        self._closed = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="morsel-prefetch")

    # -- producer (background thread) ---------------------------------------
    def _put(self, item) -> bool:
        while not self._closed.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self) -> None:
        try:
            it = iter(self._gen)
            while not self._closed.is_set():
                t0 = time.perf_counter()
                try:
                    host = next(it)
                except StopIteration:
                    break
                nbytes = host.nbytes()   # HostMorsel or DeviceTable alike
                if self._budget is not None:
                    # bytes-aware backpressure: stall the storage read
                    # until the buffered morsels fit the host budget
                    if not self._budget.acquire(nbytes,
                                                stop=self._closed.is_set):
                        return
                table = morsel_to_device(host, self._sharding)
                self.stats.read_seconds += time.perf_counter() - t0
                self.stats.bytes_transferred += nbytes
                self.stats.morsels += 1
                if not self._put((table, nbytes)):
                    if self._budget is not None:
                        self._budget.release(nbytes)
                    return
            self._put(_SENTINEL)
        except BaseException as exc:  # noqa: BLE001 -- re-raised by consumer
            self._put(exc)

    # -- consumer ------------------------------------------------------------
    def close(self) -> None:
        """Stop the producer thread (also called when iteration ends)."""
        self._closed.set()
        if self._budget is not None:
            # return budget held by undrained queued morsels
            while True:
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, tuple):
                    self._budget.release(item[1])

    def __iter__(self) -> Iterator[DeviceTable]:
        self._thread.start()
        try:
            last = None
            while True:
                t0 = time.perf_counter()
                item = self._q.get()
                now = time.perf_counter()
                self.stats.wait_seconds += now - t0
                if last is not None:
                    self.stats.compute_seconds += t0 - last
                last = now
                if item is _SENTINEL:
                    return
                if isinstance(item, BaseException):
                    raise item
                table, nbytes = item
                if self._budget is not None:
                    self._budget.release(nbytes)
                yield table
        finally:
            self.close()
