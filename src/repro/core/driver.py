"""Distributed query driver: plays the Presto coordinator + Velox drivers.

``Driver`` walks a logical plan, splits it into stages at exchange
boundaries (Aggregation auto-lowering, partitioned/broadcast joins, explicit
Exchange nodes), and executes each stage as a pipeline of device operators
over worker-stacked batches ([W, cap, ...] arrays; axis 0 sharded over the
mesh's worker axis).

Driver adaptation (paper §3.1): every operator here has a device
implementation, matching the paper's goal state ("all 22 TPC-H queries run
entirely on GPUs"). To *measure* the cost the paper eliminates,
``ExecutionContext.host_only_ops`` lists operator names whose device version
is declared unavailable -- the driver then inserts a HostRoundTrip
conversion around them, exactly like CudfToVelox/CudfFromVelox insertion.
"""

from __future__ import annotations

import contextlib
import dataclasses
import inspect
import time
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

from ..kernels import ops as kernel_ops
from . import operators as ops
from . import plan as P
from .exchange import ExchangeProtocol, ICIExchange
from .streaming import ScanStats
from .table import DeviceTable, concat_tables

# smallest device reservation granted to a memory-hungry operator under
# pressure: enough to make progress (one partition / a few groups resident)
# without letting small operators monopolise the budget
_MIN_GRANT = 1 << 10


@dataclasses.dataclass
class ExecutionContext:
    """Immutable per-query execution config (worker count, exchange,
    batching, streaming knobs) snapshot from a ``Session``. One Driver is
    built per query; the scheduler additionally clones any explicitly
    configured exchange protocol so concurrent queries never share its
    mutable stats."""

    catalog: "object"                       # repro.core.session.Catalog
    num_workers: int = 1
    exchange: Optional[ExchangeProtocol] = None
    batch_rows: int = 8192
    # operators whose device version is "unavailable" (forces host round trip)
    host_only_ops: frozenset = frozenset()
    collect_stats: bool = True
    mesh: Optional[object] = None           # jax Mesh with a 'workers' axis
    # morsel-driven scans: async storage->device prefetch with a bounded
    # queue of `prefetch_depth` morsels (False = synchronous baseline)
    streaming: bool = True
    prefetch_depth: int = 2
    # physical kernel backend for the hot relational primitives:
    # 'jnp' | 'pallas'. None resolves at snapshot time to the calling
    # thread's kernels.ops.current_backend() — an enclosing use_pallas()
    # scope, else the REPRO_KERNEL_BACKEND env default
    kernel_backend: Optional[str] = None
    # tiered-memory spill manager (core.spill). None = in-memory-only
    # execution (the pre-spill contract); set, the memory-hungry operators
    # run spill-aware: joins whose build side exceeds its reservation go
    # grace-partitioned, aggregations flush accumulator runs to the host
    # tier, and oversized exchange send buffers stage through the store.
    spill: Optional[object] = None
    # runtime-feedback store (core.feedback.FeedbackStore). Set, the driver
    # counts each plan node's observed output cardinality while streaming
    # and harvests the counts (plus join build-key multiplicities and
    # zone-map skip fractions) into the store after the query completes,
    # so the next optimization of the same plan shape re-plans warm.
    feedback: Optional[object] = None

    def __post_init__(self):
        if self.exchange is None:
            self.exchange = ICIExchange(mesh=self.mesh)
        if self.kernel_backend is None:
            self.kernel_backend = kernel_ops.current_backend()

    def host_budget(self):
        """Shared host-memory budget (prefetch + spill host tier), if any."""
        return self.spill.host if self.spill is not None else None

    def worker_sharding(self):
        """NamedSharding over the mesh's 'workers' axis (None off-mesh)."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self.mesh, PartitionSpec("workers"))


@dataclasses.dataclass
class Stream:
    """A stage output: an iterator of worker-stacked batches + distribution.

    ``scan`` is set while the stream is still the raw output of a
    ``StreamingScan`` stage: downstream Filter/Project nodes fuse into the
    stage (per-morsel execution) instead of wrapping another pipeline.
    """
    batches: Iterator[DeviceTable]
    dist: str                               # 'partitioned' | 'replicated'
    scan: Optional["StreamingScan"] = None


class StreamingScan:
    """Morsel-driven scan stage (paper §2.2, challenge 1).

    Drains the bounded prefetch queue of a ``TableSource.stream`` and runs
    the scan-fused operator pipeline (pushed-down filter, projections, and
    any other fused stages) on each morsel *as it arrives* -- storage read
    and host->device transfer of morsel N+1 overlap the compute on morsel N,
    instead of the concat-then-run baseline where I/O, transfer and compute
    fully serialize.
    """

    def __init__(self, table: str, morsels: Iterator[DeviceTable],
                 stats: ScanStats, op_seconds: Optional[Dict[str, float]] = None):
        self.table = table
        self.morsels = morsels
        self.stats = stats
        self.pipe = ops.Pipeline()
        self._op_seconds = op_seconds if op_seconds is not None else {}

    def fuse(self, op: ops.Operator) -> None:
        """Append an operator to the per-morsel scan pipeline (must be
        called before iteration starts, i.e. during plan walking)."""
        self.pipe.ops.append(op)

    def batches(self) -> Iterator[DeviceTable]:
        """Drain the prefetch queue through the fused per-morsel pipeline."""
        spent = 0.0
        # collapse filter->project->probe runs into single-dispatch fused
        # kernels; runs here (not in fuse()) so every fused stage -- and
        # the query's backend scope -- is in place before the first morsel
        ops.fuse_morsel_pipeline(self.pipe)
        self.pipe.open()
        for morsel in self.morsels:
            t0 = time.perf_counter()
            outs = self.pipe.add_input(morsel)
            spent += time.perf_counter() - t0
            yield from outs
        t0 = time.perf_counter()
        outs = self.pipe.finish()
        spent += time.perf_counter() - t0
        self._op_seconds["StreamingScan"] = (
            self._op_seconds.get("StreamingScan", 0.0) + spent)
        yield from outs


def empty_executor_stats() -> Dict[str, object]:
    """The executor-stats dict shape before any query has run.

    ``Session.executor_stats()`` (no query yet) and
    ``QueryHandle.executor_stats`` (not yet executed) both return this, so
    callers can read ``stats['kernel_dispatch']`` etc. without guarding on
    which serving path produced the dict or whether anything ran.
    """
    return {
        "tables": {},
        "op_seconds": {},
        "conversions": {},
        "exchange_protocol": "",
        "exchanges": {},
        "kernel_backend": "",
        "kernel_dispatch": {},
        "spill": {},
        "spill_staged_exchanges": 0,
        "feedback": {},
    }


class Driver:
    """Executes one logical plan as streaming operator pipelines.

    Plays the Presto coordinator + Velox drivers: walks the plan tree,
    splits it into stages at exchange boundaries, and streams batches
    through device operators. A Driver instance is single-query and
    single-use; the scheduler creates one per admitted query (its
    ``executor_stats`` are then reported on that query's handle).
    """

    def __init__(self, ctx: ExecutionContext):
        self.ctx = ctx
        self.op_seconds: Dict[str, float] = {}
        self.conversion_stats: Dict[str, int] = {}
        self.scan_stats: Dict[str, ScanStats] = {}
        # per-query kernel dispatch counts (kind -> executions of a pallas
        # kernel: 'probe', 'agg', 'compact', 'partition', 'build')
        self.kernel_dispatch: Dict[str, int] = {}
        # per-fragment exchange stats: one entry per exchange executed, in
        # execution order ("#0 Repartition(l_orderkey)" -> counter deltas)
        self.exchange_stats: Dict[str, Dict[str, float]] = {}
        self._frag_seq = 0
        # exchanges whose send buffer was staged through the spill store
        self.spill_staged_exchanges = 0
        self._spill_seq = 0
        # runtime-feedback observation state: per-node valid-row counters
        # filled by the counting generators `_observe` wraps streams in,
        # plus exact-key build multiplicities sampled in `_exec_join`
        self._feedback_obs: list = []
        self._feedback_matches: Dict[int, int] = {}

    def executor_stats(self) -> Dict[str, object]:
        """Per-query executor stats: scan counters, operator timings,
        kernel backend + dispatch counts, per-fragment exchange counters
        (rows/bytes moved, host staging), per-tier spill counters, and the
        feedback-store summary. Same key shape as
        ``empty_executor_stats()``."""
        return {
            "tables": {t: s.summary() for t, s in self.scan_stats.items()},
            "op_seconds": dict(self.op_seconds),
            "conversions": dict(self.conversion_stats),
            "exchange_protocol": self.ctx.exchange.name,
            "exchanges": {k: dict(v) for k, v in self.exchange_stats.items()},
            "kernel_backend": self.ctx.kernel_backend,
            "kernel_dispatch": dict(self.kernel_dispatch),
            "spill": (self.ctx.spill.stats.summary()
                      if self.ctx.spill is not None else {}),
            "spill_staged_exchanges": self.spill_staged_exchanges,
            "feedback": (self.ctx.feedback.summary()
                         if self.ctx.feedback is not None else {}),
        }

    def _kernel_scope(self):
        """Backend + dispatch-accounting scope one query runs under."""
        scope = contextlib.ExitStack()
        scope.enter_context(kernel_ops.use_backend(self.ctx.kernel_backend))
        scope.enter_context(
            kernel_ops.collect_dispatches(self.kernel_dispatch))
        return scope

    # -- public API ----------------------------------------------------------
    def execute(self, node: P.PlanNode) -> DeviceTable:
        """Run the plan; return the result as one device-resident table."""
        try:
            with self._kernel_scope():
                stream = self._stream(node)
                table = self._materialize(stream)
            self._harvest_feedback()
            return table
        finally:
            self._close_spill()

    def collect(self, node: P.PlanNode) -> Dict[str, np.ndarray]:
        """Run the plan; return valid rows as host numpy columns
        (deduplicated to worker 0 for replicated results)."""
        try:
            with self._kernel_scope():
                stream = self._stream(node)
                table = self._materialize_table(stream.batches)
            out = self._collect_host(stream, table)
            self._harvest_feedback()
            return out
        finally:
            self._close_spill()

    def collect_batch(self, shapes, lanes=None) -> "list":
        """Run a group of compatible queries (``core.batch.BatchShape``
        sharing one interned program) as a single stacked execution;
        returns one host-numpy result dict per member, in order.
        ``lanes`` pins the member-lane count of the stacked program (the
        scheduler passes its per-program cap so every launch reuses one
        compiled executable); None sizes it to the group."""
        from . import batch   # lazy: batch imports operators/fused
        try:
            with self._kernel_scope():
                return batch.run_batch(self, shapes, lanes=lanes)
        finally:
            self._close_spill()

    def _close_spill(self) -> None:
        """Delete this query's spill files (counters survive in stats)."""
        if self.ctx.spill is not None:
            self.ctx.spill.close()

    def _collect_host(self, stream: "Stream",
                      table: DeviceTable) -> Dict[str, np.ndarray]:
        if stream.dist == "replicated":
            # all workers hold identical results; take worker 0
            one = DeviceTable(
                {n: a[0] for n, a in table.columns.items()},
                table.validity[0], table.schema)
            return one.to_numpy()
        # partitioned: concatenate every worker's valid rows
        out: Dict[str, np.ndarray] = {}
        validity = np.asarray(table.validity).reshape(-1)
        for n, a in table.columns.items():
            flat = np.asarray(a).reshape((-1,) + a.shape[2:])
            out[n] = flat[validity]
        return out

    # -- plumbing --------------------------------------------------------------
    def _materialize_table(self, batches: Iterator[DeviceTable]) -> DeviceTable:
        got = list(batches)
        assert got, "empty stream"
        return got[0] if len(got) == 1 else concat_tables(got)

    def _materialize(self, stream: Stream) -> DeviceTable:
        return self._materialize_table(stream.batches)

    def _rebatch(self, table: DeviceTable) -> Iterator[DeviceTable]:
        """Split a stacked table back into batch_rows-sized batches."""
        cap = table.validity.shape[1]
        step = self.ctx.batch_rows
        if cap <= step:
            yield table
            return
        for lo in range(0, cap, step):
            hi = min(lo + step, cap)
            cols = {n: a[:, lo:hi] for n, a in table.columns.items()}
            yield DeviceTable(cols, table.validity[:, lo:hi], table.schema)

    def _run_pipeline(self, op: ops.Operator, stream: Iterator[DeviceTable]
                      ) -> Iterator[DeviceTable]:
        wrapped = self._maybe_host_wrap(op)
        t0 = time.perf_counter()
        op.open()
        for batch in stream:
            for pre in wrapped["pre"]:
                batch = pre.add_input(batch)[0]
            for out in op.add_input(batch):
                for post in wrapped["post"]:
                    out = post.add_input(out)[0]
                yield out
        for out in op.finish():
            for post in wrapped["post"]:
                out = post.add_input(out)[0]
            yield out
        self.op_seconds[op.name] = (self.op_seconds.get(op.name, 0.0)
                                    + time.perf_counter() - t0)

    def _maybe_host_wrap(self, op: ops.Operator):
        if op.name in self.ctx.host_only_ops:
            rt = ops.HostRoundTrip(self.conversion_stats)
            return {"pre": [rt], "post": []}
        return {"pre": [], "post": []}

    @property
    def _w(self) -> int:
        return self.ctx.num_workers

    def _maybe_stage(self, table: DeviceTable) -> DeviceTable:
        """Stage an oversized exchange send buffer through the spill store
        (device -> host -> paged disk as the tiers fill) instead of pinning
        it in device memory alongside the receive buffers."""
        spill = self.ctx.spill
        if spill is None or not spill.should_stage(table.nbytes()):
            return table
        key = ("exchange-stage", self._spill_seq)
        self._spill_seq += 1
        spill.spill_table(key, table)
        self.spill_staged_exchanges += 1
        return spill.restore(key)

    def _repartition(self, table: DeviceTable, keys: Sequence[str],
                     label: str = "repartition") -> DeviceTable:
        return self._tracked(
            f"{label}({','.join(keys)})",
            lambda: self.ctx.exchange.repartition(
                self._maybe_stage(table), tuple(keys), self._w))

    def _broadcast(self, table: DeviceTable,
                   label: str = "broadcast") -> DeviceTable:
        return self._tracked(
            label, lambda: self.ctx.exchange.broadcast(
                self._maybe_stage(table), self._w))

    def _tracked(self, label: str, fn):
        """Run one exchange, recording its stats delta as a fragment entry
        (surfaced through ``Session.explain(analyze=True)``)."""
        st = self.ctx.exchange.stats
        before = dataclasses.replace(st)
        out = fn()
        self.exchange_stats[f"#{self._frag_seq} {label}"] = {
            "rounds": st.rounds - before.rounds,
            "rows_moved": st.rows_moved - before.rows_moved,
            "bytes_moved": st.bytes_moved - before.bytes_moved,
            "host_staged_bytes": (st.host_staged_bytes
                                  - before.host_staged_bytes),
            "seconds": st.seconds - before.seconds,
        }
        self._frag_seq += 1
        return out

    # -- recursive plan execution ----------------------------------------------
    def _stream(self, node: P.PlanNode) -> Stream:
        method = getattr(self, f"_exec_{type(node).__name__.lower()}")
        stream = method(node)
        if (self.ctx.feedback is None
                or isinstance(node, (P.Repartition, P.Broadcast, P.Exchange))):
            # exchange nodes are keyed through (plan.feedback_key looks at
            # their child), so counting them would double-observe the child
            return stream
        return self._observe(node, stream)

    def _observe(self, node: P.PlanNode, stream: Stream) -> Stream:
        """Wrap a stage output in a valid-row counting generator; counts
        are harvested into the feedback store after the query completes.
        Scans with fused Filter/Project stages count post-fusion rows (an
        under-count of the raw scan — safe, scan rows only feed memory
        pricing, never a correctness-critical capacity)."""
        box = {"rows": 0}

        def counted(src):
            for batch in src:
                box["rows"] += int(batch.num_valid())
                yield batch

        self._feedback_obs.append((node, box, stream.dist))
        return Stream(counted(stream.batches), stream.dist, scan=stream.scan)

    def _observe_join_build(self, node: P.Join, build: DeviceTable,
                            dist: str) -> None:
        """Record the exact-key build multiplicity for a join: the maximum
        number of valid build rows sharing one key value, which bounds
        matches per probe row. Only sampled for single int-like keys —
        equality there is exact (no hash collisions), so the bound is
        sound as a warm ``max_matches``; hashed composite keys are never
        tightened."""
        kt = [build.schema[k] for k in node.build_keys]
        if len(kt) != 1 or kt[0].name not in ("int32", "date32", "dict32"):
            return
        keys = np.asarray(build.columns[node.build_keys[0]])
        valid = np.asarray(build.validity)
        if dist == "replicated" and self._w > 1:
            keys, valid = keys[0], valid[0]     # identical worker replicas
        vals = keys[valid]
        m = 1 if vals.size == 0 else int(
            np.max(np.unique(vals, return_counts=True)[1]))
        self._feedback_matches[id(node)] = m

    def _harvest_feedback(self) -> None:
        """Flush the per-node observations into the feedback store (called
        once, after the result materialized — both the direct-session and
        the scheduler path run through here)."""
        fb = self.ctx.feedback
        if fb is None or not self._feedback_obs:
            return
        from .optimizer import row_bound
        for node, box, dist in self._feedback_obs:
            rows = box["rows"]
            if dist == "replicated" and self._w > 1:
                rows //= self._w                # identical worker replicas
            try:
                est = row_bound(node, self.ctx.catalog)
            except Exception:
                est = None                      # exchange-wrapped subtree
            skip = None
            if isinstance(node, P.TableScan):
                stats = self.scan_stats.get(node.table)
                if stats is not None and stats.chunks_total:
                    skip = stats.chunks_skipped / stats.chunks_total
            fb.record(fb.key_for(node, self.ctx.catalog, self._w), rows,
                      estimated=est,
                      max_matches=self._feedback_matches.get(id(node)),
                      skip_fraction=skip)
        self._feedback_obs = []
        self._feedback_matches = {}

    def _place(self, batches: Iterator[DeviceTable]) -> Iterator[DeviceTable]:
        """Pin scan output to the worker mesh axis (one shard per worker,
        the paper's one-worker-per-GPU discipline)."""
        sharding = self.ctx.worker_sharding()
        if sharding is None:
            yield from batches
            return
        import jax
        for b in batches:
            yield jax.device_put(b, sharding)

    def _exec_tablescan(self, node: P.TableScan) -> Stream:
        src = self.ctx.catalog.get(node.table)
        stats = self.scan_stats.setdefault(node.table, ScanStats())
        if self.ctx.streaming and hasattr(src, "stream"):
            kwargs = {}
            if "host_budget" in inspect.signature(src.stream).parameters:
                # prefetch participates in the spill manager's host budget
                kwargs["host_budget"] = self.ctx.host_budget()
            morsels = src.stream(self._w, node.columns, self.ctx.batch_rows,
                                 filter_expr=node.filter,
                                 prefetch_depth=self.ctx.prefetch_depth,
                                 sharding=self.ctx.worker_sharding(),
                                 stats=stats, **kwargs)
            scan = StreamingScan(node.table, morsels, stats, self.op_seconds)
            if node.filter is not None:
                fp = ops.FilterProject(node.filter)
                if fp.name in self.ctx.host_only_ops:
                    return Stream(self._run_pipeline(fp, scan.batches()),
                                  "partitioned")
                scan.fuse(fp)
            return Stream(scan.batches(), "partitioned", scan=scan)
        # synchronous baseline: read + transfer inline with compute
        kwargs = {}
        if "stats" in inspect.signature(src.scan).parameters:
            kwargs["stats"] = stats
        batches = self._place(src.scan(self._w, node.columns,
                                       self.ctx.batch_rows,
                                       filter_expr=node.filter, **kwargs))
        if node.filter is not None:
            fp = ops.FilterProject(node.filter)
            return Stream(self._run_pipeline(fp, batches), "partitioned")
        return Stream(batches, "partitioned")

    def _exec_inmemorysource(self, node: P.InMemorySource) -> Stream:
        from .session import InMemoryTable
        src = InMemoryTable(node.name, node.data, node.schema)
        return Stream(src.scan(self._w, None, self.ctx.batch_rows), "partitioned")

    def _exec_filter(self, node: P.Filter) -> Stream:
        child = self._stream(node.child)
        fp = ops.FilterProject(node.predicate, None, node.compact)
        if child.scan is not None and fp.name not in self.ctx.host_only_ops:
            child.scan.fuse(fp)          # per-morsel, inside the scan stage
            return child
        return Stream(self._run_pipeline(fp, child.batches), child.dist)

    def _exec_project(self, node: P.Project) -> Stream:
        child = self._stream(node.child)
        fp = ops.FilterProject(None, node.projections)
        if child.scan is not None and fp.name not in self.ctx.host_only_ops:
            child.scan.fuse(fp)          # per-morsel, inside the scan stage
            return child
        return Stream(self._run_pipeline(fp, child.batches), child.dist)

    def _release_after(self, batches: Iterator[DeviceTable],
                       op_key: str) -> Iterator[DeviceTable]:
        """Yield through ``batches``; return the operator's device
        reservation to the spill manager when the stream is drained."""
        try:
            yield from batches
        finally:
            self.ctx.spill.release(op_key)

    def _agg_spill(self, node: P.Aggregation) -> dict:
        """Spill kwargs for one HashAggregation: reserve the accumulator's
        footprint; a shortfall runs the operator in flush-to-host mode with
        the flush point scaled to the granted fraction."""
        spill = self.ctx.spill
        if spill is None:
            return {}
        from .optimizer import infer_schema, row_width
        try:
            width = row_width(infer_schema(node, self.ctx.catalog))
        except (TypeError, KeyError):
            width = 64
        # accumulator + the concat-merge scratch copy, per worker
        want = 2 * width * node.max_groups * self._w
        op_key = f"agg{self._spill_seq}"
        self._spill_seq += 1
        granted = spill.reserve(op_key, want, minimum=min(want, _MIN_GRANT))
        if granted >= want:
            spill.release(op_key)
            return {}
        flush = max(1, (node.max_groups * granted) // max(want, 1))
        return {"spill": spill, "spill_flush_groups": flush,
                "op_key": op_key}

    def _exec_aggregation(self, node: P.Aggregation) -> Stream:
        child = self._stream(node.child)
        mode = node.mode
        if mode == "auto":
            mode = "single" if (self._w == 1 or child.dist == "replicated") \
                else "two_phase"

        def pipeline(agg_mode, batches):
            sk = self._agg_spill(node)
            op_key = sk.pop("op_key", None)
            agg = ops.HashAggregation(node.group_keys, node.aggs, agg_mode,
                                      node.max_groups, **sk)
            out = self._run_pipeline(agg, batches)
            return self._release_after(out, op_key) if op_key else out

        if mode in ("single", "partial", "final"):
            return Stream(pipeline(mode, child.batches), child.dist)

        # two-phase: partial -> exchange on keys -> final  (Velox's
        # Partial/Final modes with a Presto exchange between the stages)
        partial_out = list(pipeline("partial", child.batches))
        table = self._materialize_table(iter(partial_out))
        if node.group_keys:
            exchanged = self._repartition(table, node.group_keys, "agg")
            dist = "partitioned"
        else:
            # global agg: replicate partials
            exchanged = self._broadcast(table, "agg-broadcast")
            dist = "replicated"
        return Stream(pipeline("final", self._rebatch(exchanged)), dist)

    def _exec_distinct(self, node: P.Distinct) -> Stream:
        child = self._stream(node.child)
        d1 = ops.Distinct(node.keys, node.max_groups)
        out = list(self._run_pipeline(d1, child.batches))
        # explicit partial/final fragments (planner-placed exchange between
        # them) run the local dedup only; 'auto' keeps the runtime exchange
        if (node.mode in ("partial", "final") or self._w == 1
                or child.dist == "replicated"):
            return Stream(iter(out), child.dist)
        table = self._materialize_table(iter(out))
        exchanged = self._repartition(table, node.keys, "distinct")
        d2 = ops.Distinct(node.keys, node.max_groups)
        return Stream(self._run_pipeline(d2, self._rebatch(exchanged)),
                      "partitioned")

    def _exec_join(self, node: P.Join) -> Stream:
        build_stream = self._stream(node.build)
        build = self._materialize(build_stream)
        if self.ctx.feedback is not None:
            self._observe_join_build(node, build, build_stream.dist)

        probe_stream = self._stream(node.probe)
        dist = probe_stream.dist
        probe_batches = probe_stream.batches
        probe_scan = probe_stream.scan

        if self._w > 1:
            if node.distribution == "broadcast":
                if build_stream.dist != "replicated":
                    build = self._broadcast(build, "join-build-broadcast")
            elif node.distribution == "partitioned":
                if build_stream.dist != "replicated":
                    build = self._repartition(build, node.build_keys,
                                              "join-build")
                probe_tab = self._materialize_table(probe_batches)
                probe_tab = self._repartition(probe_tab, node.probe_keys,
                                              "join-probe")
                probe_batches = self._rebatch(probe_tab)
                probe_scan = None       # the scan is already drained
                dist = "partitioned"
            # 'local': co-partitioned already, no movement

        spill = self.ctx.spill
        op_key = None
        if spill is not None:
            # reserve the build side + hash state + probe headroom; a
            # shortfall routes the join through the grace-partitioned path
            want = 2 * build.nbytes()
            op_key = f"join{self._spill_seq}"
            self._spill_seq += 1
            granted = spill.reserve(op_key, want, minimum=min(want, _MIN_GRANT))
            if granted < want:
                join = ops.GraceHashJoin(
                    node.build_keys, node.probe_keys, node.build_payload,
                    node.join_type, node.max_matches,
                    build_rows=node.build_rows, spill=spill,
                    reservation=granted)
                join.open()
                join.add_build(build)
                join.seal_build()
                del build   # partitioned into the spill hierarchy
                out = self._run_pipeline(join, probe_batches)
                return Stream(self._release_after(out, op_key), dist)

        join = ops.HashJoin(node.build_keys, node.probe_keys,
                            node.build_payload, node.join_type,
                            node.max_matches, build_rows=node.build_rows)
        join.open()
        join.add_build(build)
        join.seal_build()
        if (probe_scan is not None and join._hash_state is not None
                and not join._multi
                and join.name not in self.ctx.host_only_ops):
            # fuse the single-match probe into the scan's per-morsel
            # pipeline: the iteration-start collapse folds it (plus any
            # preceding fused filter/project stages) into one Pallas
            # dispatch per morsel. The join's time folds into the
            # StreamingScan entry of op_seconds; the returned stream drops
            # the scan so downstream stages keep their own dispatches
            # (fusing past a join would also skew its feedback counts).
            probe_scan.fuse(join)
            out = probe_batches
        else:
            out = self._run_pipeline(join, probe_batches)
        if op_key is not None:
            out = self._release_after(out, op_key)
        return Stream(out, dist)

    def _exec_orderby(self, node: P.OrderBy) -> Stream:
        from .exchange import maybe_compact
        child = self._stream(node.child)
        # compact away dead padding (e.g. max_groups slots) before sorting
        table = maybe_compact(self._materialize_table(child.batches))
        ob = ops.OrderBy(node.keys, node.descending, node.limit)
        if node.local:
            # distributed top-N partial: each worker sorts/truncates its own
            # slice; the planner's Broadcast above gathers the candidates
            return Stream(self._run_pipeline(ob, iter([table])), child.dist)
        if self._w > 1 and child.dist != "replicated":
            # final ordering is global
            table = self._broadcast(table, "orderby-gather")
        return Stream(self._run_pipeline(ob, iter([table])), "replicated")

    def _exec_limit(self, node: P.Limit) -> Stream:
        child = self._stream(node.child)
        table = self._materialize_table(child.batches)
        if self._w > 1 and child.dist != "replicated":
            table = self._broadcast(table, "limit-gather")
        lim = ops.Limit(node.n)
        return Stream(self._run_pipeline(lim, iter([table])), "replicated")

    def _exec_scalarbroadcast(self, node: P.ScalarBroadcast) -> Stream:
        scalar_stream = self._stream(node.scalar)
        scalar = self._materialize(scalar_stream)
        if self._w > 1 and scalar_stream.dist != "replicated":
            scalar = self._broadcast(scalar, "scalar-broadcast")
        child = self._stream(node.child)
        sb = ops.ScalarBroadcast(node.columns)
        sb.set_scalar(scalar)
        return Stream(self._run_pipeline(sb, child.batches), child.dist)

    def _exec_exchange(self, node, label: str = "exchange") -> Stream:
        child = self._stream(node.child)
        table = self._materialize_table(child.batches)
        exchanged = self._repartition(table, node.keys, label)
        return Stream(self._rebatch(exchanged), "partitioned")

    def _exec_repartition(self, node: P.Repartition) -> Stream:
        """Planner-placed hash exchange: same execution as the legacy
        Exchange node, under its fragment label."""
        return self._exec_exchange(node, label="Repartition")

    def _exec_broadcast(self, node: P.Broadcast) -> Stream:
        """Planner-placed replication: every worker receives all valid rows
        of the child fragment (no-op when the stream is already replicated,
        which would otherwise multiply rows)."""
        child = self._stream(node.child)
        table = self._materialize_table(child.batches)
        if child.dist == "replicated":
            return Stream(self._rebatch(table), "replicated")
        out = self._broadcast(table, "Broadcast")
        return Stream(self._rebatch(out), "replicated")
