"""Tiered-memory spill subsystem: device -> pinned host -> paged disk.

The paper's pipeline assumes working sets that fit device memory; Theseus
and "Terabyte-Scale Analytics in the Blink of an Eye" (PAPERS.md) make the
opposite bet -- a memory *hierarchy* where operators degrade gracefully
instead of the coordinator refusing work. This module is that hierarchy for
the repro engine:

* ``SpillManager``     -- owns one query's device-memory budget. Operators
                          take *reservations* against it (grace join build
                          sides, aggregation accumulators, exchange send
                          buffers); partitions that do not fit move down the
                          hierarchy: device arrays are pulled into host
                          buffers, and when the host budget fills, victim
                          partitions are written as ``storage.paged`` files
                          on disk (the same page/row-group format
                          ``PagedTableSource`` reads). Every byte crossing a
                          tier boundary is accounted per tier.
* ``HostMemoryBudget`` -- the shared host-bytes meter: the spill manager's
                          host tier and every ``MorselPrefetcher`` bounded
                          queue draw from the same budget, so prefetched
                          morsels and spilled partitions cannot together
                          exceed the configured host memory.

Spilled partitions round-trip **bit-exactly**: integer columns are stored
through the paged format's plain-encoded byte pages (its delta encoding is
not wrap-safe for arbitrary int64 data), floats/bools/bytes are plain pages
already, and validity masks ride along as a ``bool`` column. Shapes
(worker-stacked ``[W, cap]`` or local ``[cap]``) are preserved through a
flatten/reshape recorded on the in-memory handle.

Victim selection is largest-first: when the host tier must make room, the
biggest resident partition is written to disk (fewest disk I/Os per byte
freed). ``SpillCapacityError`` is raised only when the *disk* ceiling is
exceeded -- the runtime counterpart of the scheduler's admission rule that
over-budget queries are admitted with a priced slowdown and rejected only
past the hard disk ceiling.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import dtypes as dt
from .table import DeviceTable


class SpillCapacityError(RuntimeError):
    """The spill hierarchy's *disk* ceiling was exceeded (the only tier
    with a hard limit; device/host overflow cascades downward instead)."""


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TierStats:
    """Byte/event counters for one tier boundary of the hierarchy."""

    spilled_bytes: int = 0      # bytes written into this tier
    restored_bytes: int = 0     # bytes read back out of this tier
    spills: int = 0             # partitions written
    restores: int = 0           # partitions read back

    def summary(self) -> Dict[str, int]:
        """Counters as a plain dict (for ``executor_stats`` reporting)."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SpillStats:
    """Per-tier accounting for one ``SpillManager`` (one query).

    ``host`` counts device->host movement (every spill lands here first);
    ``disk`` counts host->disk victim writes and their restores. Device
    pressure shows up as ``reserved_peak`` vs the budget.
    """

    host: TierStats = dataclasses.field(default_factory=TierStats)
    disk: TierStats = dataclasses.field(default_factory=TierStats)
    reserved_peak: int = 0      # high-water mark of device reservations
    reserve_denials: int = 0    # reservations that did not fit in full

    @property
    def spilled_bytes(self) -> int:
        """Total bytes that left the device tier (host + disk writes count
        once: disk writes are host-tier bytes moved further down)."""
        return self.host.spilled_bytes

    def summary(self) -> Dict[str, object]:
        """Nested per-tier counter dict (for ``executor_stats``/explain)."""
        return {
            "host": self.host.summary(),
            "disk": self.disk.summary(),
            "reserved_peak": self.reserved_peak,
            "reserve_denials": self.reserve_denials,
            "spilled_bytes": self.spilled_bytes,
        }


class HostMemoryBudget:
    """Shared host-bytes meter with blocking acquisition.

    One instance is shared by a query's spill manager (non-blocking
    ``try_acquire``: on denial the partition cascades to disk) and its
    ``MorselPrefetcher`` threads (blocking ``acquire``: storage reads stall
    until the consumer drains). Progress is guaranteed: a request is always
    admitted when nothing is currently held, so a single morsel or
    partition larger than the whole budget still flows (over-subscribed,
    never deadlocked).
    """

    def __init__(self, max_bytes: int):
        self.max_bytes = max(int(max_bytes), 0)
        self._in_use = 0
        self._cond = threading.Condition()
        # pressure relief valve: a blocked acquire() calls this (outside
        # the lock) to ask the holder of the budget to give some back.
        # The sharing SpillManager registers its evict-to-disk hook here,
        # so host bytes parked by spilled partitions can never deadlock a
        # prefetcher that shares the meter (the partitions sink to disk).
        self.pressure = None      # Optional[Callable[[], bool]]

    @property
    def in_use(self) -> int:
        """Bytes currently held against the budget."""
        with self._cond:
            return self._in_use

    def _fits(self, nbytes: int) -> bool:
        return self._in_use == 0 or self._in_use + nbytes <= self.max_bytes

    def try_acquire(self, nbytes: int) -> bool:
        """Non-blocking: reserve ``nbytes`` of host memory if it fits."""
        with self._cond:
            if self._fits(nbytes):
                self._in_use += nbytes
                return True
            return False

    def acquire(self, nbytes: int, stop=None) -> bool:
        """Block until ``nbytes`` fits (or ``stop()`` turns true),
        applying pressure to the spill store while waiting."""
        while True:
            with self._cond:
                if self._fits(nbytes):
                    self._in_use += nbytes
                    return True
                if stop is not None and stop():
                    return False
            relief = self.pressure
            if relief is not None and relief():
                continue              # something was evicted: retry now
            with self._cond:
                if self._fits(nbytes):
                    self._in_use += nbytes
                    return True
                if stop is not None and stop():
                    return False
                self._cond.wait(timeout=0.05)

    def release(self, nbytes: int) -> None:
        """Return ``nbytes`` to the budget and wake blocked acquirers."""
        with self._cond:
            self._in_use = max(0, self._in_use - nbytes)
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# spilled-partition payloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _HostPartition:
    """One spilled partition resident in the host tier: raw column arrays
    (validity included, shapes preserved) + schema."""

    columns: Dict[str, np.ndarray]
    validity: np.ndarray
    schema: Dict[str, dt.DType]
    nbytes: int


@dataclasses.dataclass
class _DiskPartition:
    """One spilled partition written to a paged file: the codec metadata
    needed to restore it bit-exactly -- per-column ``(shape, dtype_str)``
    of the *physical* arrays (which may differ from the logical schema:
    with ``jax_enable_x64`` off an INT64 column is physically int32)."""

    path_root: str
    file_name: str
    layout: Dict[str, tuple]        # name -> (shape, numpy dtype str)
    schema: Dict[str, dt.DType]
    nbytes: int


# physical float/bool dtypes the paged format plain-encodes as-is
_PLAIN_DTYPES = {"float32": dt.FLOAT32, "float64": dt.FLOAT64,
                 "bool": dt.BOOL}


def _flatten_codec(columns: Dict[str, np.ndarray], validity: np.ndarray,
                   schema: Dict[str, dt.DType]):
    """Encode a partition for the paged on-disk format, bit-exactly.

    The paged format delta-encodes integer columns with int32 deltas, which
    is not wrap-safe for arbitrary values -- so integer columns are stored
    as plain byte pages (``bytes`` dtype of the element width) and floats/
    bools as themselves (plain-encoded already). Encoding keys off each
    array's *physical* dtype (the logical schema may promise a wider type
    than the x64-disabled device holds); leading dims (worker stacking)
    are flattened, and shapes/dtypes return via the handle's layout.
    """
    data, disk_schema, layout = {}, {}, {}
    for name, arr in columns.items():
        d = schema[name]
        arr = np.ascontiguousarray(arr)
        layout[name] = (arr.shape, arr.dtype.str)
        if d.name == "bytes":
            data[name] = arr.reshape(-1, d.width)
            disk_schema[name] = dt.bytes_(d.width)
        elif str(arr.dtype) in _PLAIN_DTYPES:
            data[name] = arr.reshape(-1)
            disk_schema[name] = _PLAIN_DTYPES[str(arr.dtype)]
        else:
            item = arr.dtype.itemsize
            flat = arr.reshape(-1)
            data[name] = flat.view(np.uint8).reshape(len(flat), item)
            disk_schema[name] = dt.bytes_(item)
    validity = np.ascontiguousarray(validity).astype(bool, copy=False)
    layout["__validity"] = (validity.shape, validity.dtype.str)
    data["__validity"] = validity.reshape(-1)
    disk_schema["__validity"] = dt.BOOL
    return data, disk_schema, layout


def _restore_codec(reader, layout: Dict[str, tuple],
                   schema: Dict[str, dt.DType]):
    """Invert ``_flatten_codec`` from a ``storage.paged.PagedTable``."""
    columns = {}
    for name, d in schema.items():
        shape, dtype_str = layout[name]
        raw = np.asarray(reader.read_column(name))
        if d.name == "bytes" or str(raw.dtype) in _PLAIN_DTYPES:
            arr = raw
        else:
            arr = np.frombuffer(np.ascontiguousarray(raw).tobytes(),
                                dtype=np.dtype(dtype_str))
        columns[name] = arr.reshape(shape)
    v_shape, _ = layout["__validity"]
    validity = np.asarray(reader.read_column("__validity"),
                          dtype=bool).reshape(v_shape)
    return columns, validity


# ---------------------------------------------------------------------------
# SpillManager
# ---------------------------------------------------------------------------

class SpillManager:
    """Owns one query's device budget and the host/disk spill stores.

    * ``reserve``/``release`` track per-operator device-memory
      reservations against ``device_budget`` (best-effort grants: the
      caller sizes its working set -- e.g. grace-join partition count --
      from what it was granted).
    * ``spill_table``/``put_host`` move a partition out of device memory
      into the host store, cascading largest-first victims to paged disk
      files when the host budget fills.
    * ``restore`` brings a partition back as a ``DeviceTable`` (and drops
      it from the store); ``restore_host`` returns the raw host arrays.

    One manager serves one query (the scheduler builds one per admitted
    over-budget query); ``close()`` removes its spill directory.
    """

    def __init__(self, device_budget: int, host_budget: int = 1 << 31,
                 spill_dir: Optional[str] = None,
                 disk_ceiling: int = 1 << 38,
                 host_memory: Optional[HostMemoryBudget] = None):
        self.device_budget = max(int(device_budget), 0)
        self.disk_ceiling = int(disk_ceiling)
        self.host = host_memory or HostMemoryBudget(host_budget)
        self._spill_dir = spill_dir
        self._own_dir: Optional[str] = None
        self._lock = threading.RLock()
        self._reserved: Dict[str, int] = {}
        # host store kept in insertion order; victims picked largest-first
        self._host_store: Dict[object, _HostPartition] = {}
        self._disk_store: Dict[object, _DiskPartition] = {}
        self._disk_in_use = 0
        self._seq = 0
        self.stats = SpillStats()
        self.host.pressure = self._evict_one

    # -- device reservations -------------------------------------------------
    def reserve(self, op: str, want: int, minimum: int = 0) -> int:
        """Grant ``op`` between ``minimum`` and ``want`` bytes of the
        device budget (best effort). The grant never drops below
        ``minimum`` -- over-subscribing the budget if needed so operators
        always make progress -- and is recorded against ``op`` until
        ``release``."""
        want = max(int(want), 0)
        minimum = max(int(minimum), 0)
        with self._lock:
            available = self.device_budget - self.device_reserved()
            granted = max(min(want, available), minimum)
            if granted < want:
                self.stats.reserve_denials += 1
            self._reserved[op] = self._reserved.get(op, 0) + granted
            self.stats.reserved_peak = max(self.stats.reserved_peak,
                                           self.device_reserved())
            return granted

    def release(self, op: str, nbytes: Optional[int] = None) -> None:
        """Return ``op``'s reservation (all of it when ``nbytes`` is
        None)."""
        with self._lock:
            held = self._reserved.get(op, 0)
            if nbytes is None or nbytes >= held:
                self._reserved.pop(op, None)
            else:
                self._reserved[op] = held - nbytes

    def reserved(self, op: str) -> int:
        """Bytes currently reserved by ``op``."""
        with self._lock:
            return self._reserved.get(op, 0)

    def device_reserved(self) -> int:
        """Total device bytes reserved across operators."""
        return sum(self._reserved.values())

    def device_available(self) -> int:
        """Unreserved device budget (can go negative when over-subscribed
        via ``minimum`` grants)."""
        with self._lock:
            return self.device_budget - self.device_reserved()

    def should_stage(self, nbytes: int) -> bool:
        """True when a transient buffer of ``nbytes`` does not fit the
        unreserved device budget (the exchange path stages such buffers
        through the spill store)."""
        return nbytes > max(self.device_available(), 0)

    # -- spill / restore ------------------------------------------------------
    def spill_table(self, key, table: DeviceTable) -> int:
        """Move a device table into the spill hierarchy; returns the bytes
        that left the device tier."""
        columns = {n: np.asarray(a) for n, a in table.columns.items()}
        validity = np.asarray(table.validity)
        return self.put_host(key, columns, validity, table.schema)

    def put_host(self, key, columns: Dict[str, np.ndarray],
                 validity: np.ndarray, schema: Dict[str, dt.DType]) -> int:
        """Insert raw host arrays as a spilled partition under ``key``."""
        nbytes = int(validity.nbytes + sum(a.nbytes for a in columns.values()))
        part = _HostPartition(dict(columns), validity, dict(schema), nbytes)
        with self._lock:
            assert key not in self._host_store and key not in self._disk_store, \
                f"duplicate spill key {key!r}"
            self.stats.host.spilled_bytes += nbytes
            self.stats.host.spills += 1
            if self.host.try_acquire(nbytes):
                self._host_store[key] = part
                self._make_room()
            else:
                self._write_disk(key, part)
        return nbytes

    def _make_room(self) -> None:
        """Largest-first victim selection: while the host tier is over
        budget (prefetched morsels share the meter), write the biggest
        resident partition to disk (held lock). Unlike the prefetcher's
        blocking path, spilled partitions have a lower tier to fall to --
        so even a sole oversize partition is evicted rather than letting
        it squat over the budget."""
        while (self.host.in_use > self.host.max_bytes
               and self._host_store):
            victim = max(self._host_store, key=lambda k: self._host_store[k].nbytes)
            part = self._host_store.pop(victim)
            self.host.release(part.nbytes)
            self._write_disk(victim, part)

    def _evict_one(self) -> bool:
        """Host-budget pressure callback: sink the largest host-tier
        partition to disk so a blocked acquirer (e.g. a prefetcher
        sharing the meter) can proceed. Returns True when bytes moved."""
        with self._lock:
            if not self._host_store:
                return False
            victim = max(self._host_store,
                         key=lambda k: self._host_store[k].nbytes)
            part = self._host_store[victim]
            self._write_disk(victim, part)
            del self._host_store[victim]
            self.host.release(part.nbytes)
            return True

    def _write_disk(self, key, part: _HostPartition) -> None:
        if self._disk_in_use + part.nbytes > self.disk_ceiling:
            raise SpillCapacityError(
                f"spill of {part.nbytes} B would exceed the disk ceiling "
                f"({self.disk_ceiling} B, {self._disk_in_use} B in use)")
        from ..storage.paged import write_paged_table
        root = self._dir()
        name = f"spill{self._seq}"
        self._seq += 1
        data, disk_schema, layout = _flatten_codec(part.columns, part.validity,
                                                   part.schema)
        write_paged_table(root, name, data, disk_schema, row_groups=1)
        self._disk_store[key] = _DiskPartition(root, name, layout,
                                               part.schema, part.nbytes)
        self._disk_in_use += part.nbytes
        self.stats.disk.spilled_bytes += part.nbytes
        self.stats.disk.spills += 1

    def restore_host(self, key) -> Tuple[Dict[str, np.ndarray], np.ndarray,
                                         Dict[str, dt.DType]]:
        """Pop a spilled partition back to host arrays (columns, validity,
        schema), reading it from whichever tier holds it."""
        with self._lock:
            if key in self._host_store:
                part = self._host_store.pop(key)
                self.host.release(part.nbytes)
                self.stats.host.restored_bytes += part.nbytes
                self.stats.host.restores += 1
                return part.columns, part.validity, part.schema
            entry = self._disk_store.pop(key)
            self._disk_in_use -= entry.nbytes
        from ..storage.paged import PagedTable
        reader = PagedTable(entry.path_root, entry.file_name)
        disk_schema = {n: d for n, d in entry.schema.items()}
        columns, validity = _restore_codec(reader, entry.layout, disk_schema)
        with self._lock:
            self.stats.disk.restored_bytes += entry.nbytes
            self.stats.disk.restores += 1
            self.stats.host.restored_bytes += entry.nbytes
            self.stats.host.restores += 1
        try:
            os.remove(os.path.join(entry.path_root, f"{entry.file_name}.paged"))
        except OSError:
            pass
        return columns, validity, entry.schema

    def restore(self, key) -> DeviceTable:
        """Pop a spilled partition back into device memory."""
        import jax.numpy as jnp
        columns, validity, schema = self.restore_host(key)
        cols = {n: jnp.asarray(a) for n, a in columns.items()}
        return DeviceTable(cols, jnp.asarray(validity), dict(schema))

    def has(self, key) -> bool:
        """True if ``key`` is resident in the host or disk tier."""
        with self._lock:
            return key in self._host_store or key in self._disk_store

    def tier_of(self, key) -> Optional[str]:
        """'host' | 'disk' | None -- which tier currently holds ``key``."""
        with self._lock:
            if key in self._host_store:
                return "host"
            if key in self._disk_store:
                return "disk"
            return None

    def keys(self) -> List[object]:
        """All spilled partition keys, host tier first."""
        with self._lock:
            return list(self._host_store) + list(self._disk_store)

    def drop(self, key) -> None:
        """Discard a spilled partition without restoring it."""
        with self._lock:
            part = self._host_store.pop(key, None)
            if part is not None:
                self.host.release(part.nbytes)
                return
            entry = self._disk_store.pop(key, None)
            if entry is None:
                return
            self._disk_in_use -= entry.nbytes
        try:
            os.remove(os.path.join(entry.path_root, f"{entry.file_name}.paged"))
        except OSError:
            pass

    # -- lifecycle ------------------------------------------------------------
    def _dir(self) -> str:
        if self._spill_dir is not None:
            os.makedirs(self._spill_dir, exist_ok=True)
            return self._spill_dir
        if self._own_dir is None:
            self._own_dir = tempfile.mkdtemp(prefix="repro-spill-")
        return self._own_dir

    def close(self) -> None:
        """Release host bytes and delete this manager's spill files
        (counters survive for ``executor_stats``)."""
        self.host.pressure = None
        with self._lock:
            for part in self._host_store.values():
                self.host.release(part.nbytes)
            self._host_store.clear()
            self._disk_store.clear()
            self._disk_in_use = 0
            own, self._own_dir = self._own_dir, None
        if own is not None:
            shutil.rmtree(own, ignore_errors=True)


def spill_run_keys(prefix: str, n: int) -> Iterable[Tuple[str, int]]:
    """Key sequence for ``n`` spilled runs of one operator."""
    return [(prefix, i) for i in range(n)]
