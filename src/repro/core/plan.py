"""Logical query plans.

A plan is a tree of PlanNodes. The Presto coordinator's role (split the plan
into stages at exchange boundaries, hand fragments to workers) is played by
``driver.run``; the "driver adaptation" step (substitute device operators,
insert host/device conversions) is played by the planner in ``planner.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .expr import Expr
from .operators import AggSpec


@dataclasses.dataclass
class PlanNode:
    def children(self) -> List["PlanNode"]:
        return []


@dataclasses.dataclass
class TableScan(PlanNode):
    """Scan a catalog table. ``columns=None`` reads every column."""
    table: str
    columns: Optional[Sequence[str]] = None
    # pushed-down predicate evaluated inside the scan (data skipping uses
    # chunk min/max metadata against it when the storage layer has stats)
    filter: Optional[Expr] = None


@dataclasses.dataclass
class Filter(PlanNode):
    child: PlanNode
    predicate: Expr
    compact: bool = False

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Project(PlanNode):
    child: PlanNode
    projections: Sequence[Tuple[str, Expr]]

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Aggregation(PlanNode):
    """mode 'auto' lowers to partial -> exchange -> final when distributed."""
    child: PlanNode
    group_keys: Sequence[str]
    aggs: Sequence[AggSpec]
    max_groups: int = 4096
    mode: str = "auto"          # auto | partial | final | single

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Distinct(PlanNode):
    child: PlanNode
    keys: Sequence[str]
    max_groups: int = 4096

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Join(PlanNode):
    """Hash join; ``build`` is materialized, ``probe`` streams.

    distribution:
      'broadcast'   build side replicated to all workers (small build)
      'partitioned' both sides exchanged on the join keys (large-large)
      'local'       sides are already co-partitioned
    """
    probe: PlanNode
    build: PlanNode
    probe_keys: Sequence[str]
    build_keys: Sequence[str]
    build_payload: Sequence[str] = ()
    join_type: str = "inner"
    max_matches: int = 1
    distribution: str = "broadcast"

    def children(self):
        return [self.probe, self.build]


@dataclasses.dataclass
class OrderBy(PlanNode):
    child: PlanNode
    keys: Sequence[str]
    descending: Optional[Sequence[bool]] = None
    limit: Optional[int] = None

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Limit(PlanNode):
    child: PlanNode
    n: int

    def children(self):
        return [self.child]


@dataclasses.dataclass
class ScalarBroadcast(PlanNode):
    """Attach columns of a 1-row subquery result to every row of child."""
    child: PlanNode
    scalar: PlanNode
    columns: Sequence[str]

    def children(self):
        return [self.child, self.scalar]


@dataclasses.dataclass
class Exchange(PlanNode):
    """Explicit repartition on ``keys`` (hash exchange across workers)."""
    child: PlanNode
    keys: Sequence[str]

    def children(self):
        return [self.child]


@dataclasses.dataclass
class InMemorySource(PlanNode):
    """Source backed by host numpy dict (tests / intermediate results)."""
    name: str
    data: Dict[str, Any]
    schema: Dict[str, Any]
