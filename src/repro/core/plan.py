"""Logical query plans.

A plan is a tree of PlanNodes. The Presto coordinator's role (split the plan
into stages at exchange boundaries, hand fragments to workers) is played by
``driver.Driver``; the "driver adaptation" step (push predicates into scans,
choose join distributions, derive operator capacities) is played by the rule
pipeline in ``optimizer.py``.

``fingerprint`` produces a canonical string key for a plan tree — two
structurally identical queries fingerprint identically regardless of
list/tuple spelling — which the scheduler's plan and result caches key on.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .expr import Expr
from .operators import AggSpec


@dataclasses.dataclass
class PlanNode:
    """Base of the logical-plan tree; ``children()`` lists subtrees."""

    def children(self) -> List["PlanNode"]:
        return []


@dataclasses.dataclass
class TableScan(PlanNode):
    """Scan a catalog table. ``columns=None`` reads every column."""
    table: str
    columns: Optional[Sequence[str]] = None
    # pushed-down predicate evaluated inside the scan (data skipping uses
    # chunk min/max metadata against it when the storage layer has stats)
    filter: Optional[Expr] = None


@dataclasses.dataclass
class Filter(PlanNode):
    """Keep rows where ``predicate`` holds (marks the rest invalid;
    ``compact=True`` additionally stream-compacts survivors, §3.3.2)."""

    child: PlanNode
    predicate: Expr
    compact: bool = False

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Project(PlanNode):
    """Compute output columns as named expressions over the child."""

    child: PlanNode
    projections: Sequence[Tuple[str, Expr]]

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Aggregation(PlanNode):
    """mode 'auto' lowers to partial -> exchange -> final when distributed."""
    child: PlanNode
    group_keys: Sequence[str]
    aggs: Sequence[AggSpec]
    max_groups: int = 4096
    mode: str = "auto"          # auto | partial | final | single

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Distinct(PlanNode):
    """Unique rows over ``keys`` (grouped dedup, static capacity).

    mode 'auto' lets the driver insert the cross-worker dedup exchange at
    runtime; the optimizer's exchange placement lowers it to an explicit
    'partial' (worker-local dedup) -> Repartition -> 'final' fragment pair.
    """

    child: PlanNode
    keys: Sequence[str]
    max_groups: int = 4096
    mode: str = "auto"          # auto | partial | final

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Join(PlanNode):
    """Hash join; ``build`` is materialized, ``probe`` streams.

    distribution:
      'broadcast'   build side replicated to all workers (small build)
      'partitioned' both sides exchanged on the join keys (large-large)
      'local'       sides are already co-partitioned
    """
    probe: PlanNode
    build: PlanNode
    probe_keys: Sequence[str]
    build_keys: Sequence[str]
    build_payload: Sequence[str] = ()
    join_type: str = "inner"
    max_matches: int = 1
    distribution: str = "broadcast"
    # planner's upper bound on valid build-side rows (derive_capacities);
    # sizes the pallas backend's open-addressing probe table
    build_rows: Optional[int] = None

    def children(self):
        return [self.probe, self.build]


@dataclasses.dataclass
class OrderBy(PlanNode):
    """Global sort (optionally top-``limit``); blocking operator.

    ``local=True`` sorts each worker's slice independently (no gather) —
    the planner's distributed top-N lowering places a local OrderBy below
    the exchange so only ``W * limit`` candidate rows are broadcast.
    """

    child: PlanNode
    keys: Sequence[str]
    descending: Optional[Sequence[bool]] = None
    limit: Optional[int] = None
    local: bool = False

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Limit(PlanNode):
    """First ``n`` valid rows of the child."""

    child: PlanNode
    n: int

    def children(self):
        return [self.child]


@dataclasses.dataclass
class ScalarBroadcast(PlanNode):
    """Attach columns of a 1-row subquery result to every row of child."""
    child: PlanNode
    scalar: PlanNode
    columns: Sequence[str]

    def children(self):
        return [self.child, self.scalar]


@dataclasses.dataclass
class Exchange(PlanNode):
    """Explicit repartition on ``keys`` (hash exchange across workers)."""
    child: PlanNode
    keys: Sequence[str]

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Repartition(PlanNode):
    """Physical exchange: hash-partition the child's rows on ``keys`` so
    equal keys land on the same worker. Placed by the optimizer's
    ``place_exchanges`` rule (partitioned joins, two-phase aggregation);
    executed through the session's ``ExchangeProtocol``."""
    child: PlanNode
    keys: Sequence[str]

    def children(self):
        return [self.child]


@dataclasses.dataclass
class Broadcast(PlanNode):
    """Physical exchange: replicate every worker's valid rows to all
    ``num_workers`` workers (broadcast-join build sides, global-aggregation
    partials, scalar subqueries). Carries the planned worker count so plans
    placed for different cluster sizes fingerprint differently."""
    child: PlanNode
    num_workers: int = 1

    def children(self):
        return [self.child]


@dataclasses.dataclass
class InMemorySource(PlanNode):
    """Source backed by host numpy dict (tests / intermediate results)."""
    name: str
    data: Dict[str, Any]
    schema: Dict[str, Any]


# ---------------------------------------------------------------------------
# canonical plan keys
# ---------------------------------------------------------------------------

def _canon(v: Any, node_fn=None) -> str:
    """Canonical string for a plan-node field value.

    Normalizes list/tuple spelling (builders produce lists, hand-written
    plans often tuples), sorts dict keys, and digests numpy buffers so an
    ``InMemorySource`` keys on its actual data, not its object identity.
    ``node_fn`` is the recursion used for nested PlanNodes (``fingerprint``
    by default; ``feedback_key`` for capacity-normalized keys).
    """
    if node_fn is None:
        node_fn = fingerprint
    if isinstance(v, PlanNode):
        return node_fn(v)
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        inner = ",".join(
            f"{f.name}={_canon(getattr(v, f.name), node_fn)}"
            for f in dataclasses.fields(v))
        return f"{type(v).__name__}({inner})"
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(_canon(x, node_fn) for x in v) + "]"
    if isinstance(v, dict):
        items = sorted(v.items(), key=lambda kv: str(kv[0]))
        return ("{" + ",".join(f"{k}:{_canon(x, node_fn)}"
                               for k, x in items) + "}")
    if hasattr(v, "tobytes") and hasattr(v, "dtype"):      # numpy array
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(getattr(v, "shape", ())).encode())
        h.update(v.tobytes())
        return f"ndarray:{h.hexdigest()}"
    return repr(v)


def fingerprint(node: PlanNode) -> str:
    """Canonical cache key for a logical plan tree.

    Structurally identical plans (same node types, expressions, columns,
    capacities) produce identical fingerprints; the scheduler's plan cache
    and result cache both key on this::

        >>> a = TableScan("lineitem", columns=["l_quantity"])
        >>> b = TableScan("lineitem", columns=("l_quantity",))
        >>> fingerprint(a) == fingerprint(b)
        True
    """
    inner = ",".join(
        f"{f.name}={_canon(getattr(node, f.name))}"
        for f in dataclasses.fields(node))
    return f"{type(node).__name__}({inner})"


# fields the optimizer derives (and runtime feedback re-derives): two plans
# that differ only in these describe the same logical computation, so the
# feedback store must give them the same key
_FEEDBACK_SKIP = {
    "Aggregation": frozenset({"max_groups", "mode"}),
    "Distinct": frozenset({"max_groups", "mode"}),
    "Join": frozenset({"max_matches", "build_rows", "distribution"}),
}

# physical exchange placement is worker-count plumbing, not logic: the store
# keys through it so a pre-`place_exchanges` node being planned matches the
# exchange-wrapped node the driver observed on the previous run
_FEEDBACK_TRANSPARENT = ("Repartition", "Broadcast", "Exchange")


def feedback_key(node: PlanNode) -> str:
    """Capacity-normalized plan key for the runtime-feedback store.

    Like ``fingerprint`` but (a) skips optimizer-derived capacity fields
    (``max_groups``/``mode``, ``max_matches``/``build_rows``/
    ``distribution``) so a node keys the same before and after
    ``derive_capacities`` rewrites it — cold and warm plans of one query
    share feedback entries — and (b) looks through physical exchange
    nodes (``Repartition``/``Broadcast``/``Exchange``) so distributed
    fragment plans key onto their logical shape. Worker count still
    matters for observed cardinalities (partial aggregates emit per-worker
    groups), so ``FeedbackStore`` buckets entries per ``num_workers`` on
    top of this key.
    """
    while type(node).__name__ in _FEEDBACK_TRANSPARENT:
        node = node.child
    skip = _FEEDBACK_SKIP.get(type(node).__name__, frozenset())
    inner = ",".join(
        f"{f.name}={_canon(getattr(node, f.name), feedback_key)}"
        for f in dataclasses.fields(node) if f.name not in skip)
    return f"{type(node).__name__}({inner})"
