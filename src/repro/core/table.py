"""DeviceTable: the engine's CudfVector analogue.

A DeviceTable is a *batch* of rows resident in device memory:

* ``columns``   -- name -> jnp array, every array has the same leading
                   dimension ``capacity`` (static).
* ``validity``  -- bool[capacity]; rows with validity False are dead
                   (filtered out / padding). TPU has no dynamic shapes, so a
                   filter marks rows dead instead of shrinking the array;
                   ``compact()`` is the explicit stream-compaction step.
* ``schema``    -- name -> DType (host metadata, like the CPU-resident schema
                   part of the paper's two-part CudfVector transfer).

Like the paper's CudfVector (cudf table + CUDA stream), the device data and
host metadata travel together; XLA's async dispatch plays the role of the
CUDA stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from .dtypes import DType

Schema = Dict[str, DType]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTable:
    """One device-resident batch: equal-capacity columns + validity mask
    + host-side schema (the paper's CudfVector analogue; see module doc)."""

    columns: Dict[str, jax.Array]
    validity: jax.Array                  # bool[capacity]
    schema: Schema                       # aux data (host side)

    # -- pytree plumbing (schema is static) --------------------------------
    def tree_flatten(self):
        """jax pytree hook: arrays are leaves, schema is aux data."""
        names = tuple(sorted(self.columns.keys()))
        children = tuple(self.columns[n] for n in names) + (self.validity,)
        aux = (names, tuple((n, self.schema[n]) for n in sorted(self.schema)))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        """jax pytree hook: rebuild from leaves + static schema."""
        names, schema_items = aux
        cols = dict(zip(names, children[:-1]))
        return cls(cols, children[-1], dict(schema_items))

    # -- basic properties ---------------------------------------------------
    @property
    def capacity(self) -> int:
        """Static row capacity (valid + dead rows)."""
        return int(self.validity.shape[0])

    @property
    def column_names(self) -> List[str]:
        """Column names in insertion order."""
        return list(self.columns.keys())

    def num_valid(self) -> jax.Array:
        """Number of live rows (traced scalar)."""
        return jnp.sum(self.validity.astype(jnp.int32))

    def nbytes(self) -> int:
        """Device bytes pinned by this batch (columns + validity)."""
        total = self.validity.size * self.validity.dtype.itemsize
        for arr in self.columns.values():
            total += arr.size * arr.dtype.itemsize
        return int(total)

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_numpy(data: Dict[str, np.ndarray], schema: Schema,
                   capacity: Optional[int] = None) -> "DeviceTable":
        """Device-put host arrays, zero-padded up to ``capacity`` rows."""
        n = len(next(iter(data.values()))) if data else 0
        cap = capacity or max(n, 1)
        assert cap >= n, f"capacity {cap} < rows {n}"
        cols = {}
        for name, arr in data.items():
            dt = schema[name]
            arr = np.asarray(arr, dtype=dt.np_dtype())
            full_shape = dt.storage_shape(cap)
            out = np.zeros(full_shape, dtype=dt.np_dtype())
            out[:n] = arr
            cols[name] = jnp.asarray(out)
        validity = np.zeros(cap, dtype=bool)
        validity[:n] = True
        return DeviceTable(cols, jnp.asarray(validity), dict(schema))

    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Pull valid rows back to host (the CudfToVelox conversion)."""
        validity = np.asarray(self.validity)
        return {
            name: np.asarray(arr)[validity] for name, arr in self.columns.items()
        }

    # -- row ops ---------------------------------------------------------------
    def select(self, names) -> "DeviceTable":
        """Projection to the named columns (no copy)."""
        return DeviceTable(
            {n: self.columns[n] for n in names},
            self.validity,
            {n: self.schema[n] for n in names},
        )

    def rename(self, mapping: Dict[str, str]) -> "DeviceTable":
        """Rename columns via ``{old: new}`` (no copy)."""
        cols = {mapping.get(n, n): a for n, a in self.columns.items()}
        schema = {mapping.get(n, n): d for n, d in self.schema.items()}
        return DeviceTable(cols, self.validity, schema)

    def with_column(self, name: str, arr: jax.Array, dtype: DType) -> "DeviceTable":
        """Attach one computed column (same capacity)."""
        cols = dict(self.columns)
        cols[name] = arr
        schema = dict(self.schema)
        schema[name] = dtype
        return DeviceTable(cols, self.validity, schema)

    def filter(self, mask: jax.Array) -> "DeviceTable":
        """Mark rows dead where ``mask`` is false (no compaction)."""
        return DeviceTable(self.columns, self.validity & mask, self.schema)

    def gather(self, idx: jax.Array, valid: jax.Array) -> "DeviceTable":
        """Take rows at ``idx`` (new capacity = len(idx)); ``valid`` marks live
        output rows. Gathered validity is ANDed with the source row's."""
        cols = {n: jnp.take(a, idx, axis=0) for n, a in self.columns.items()}
        v = jnp.take(self.validity, idx, axis=0) & valid
        return DeviceTable(cols, v, self.schema)

    def compact(self) -> "DeviceTable":
        """Stream compaction: move valid rows to the front (stable).

        cuDF's apply_boolean_mask shrinks the table; with static shapes we
        keep capacity and push dead rows to the tail so downstream kernels
        touch a dense prefix. Under the 'pallas' kernel backend the
        compaction addresses come from the ``block_prefix_sum`` kernel
        (two-level MXU scan) and rows move with one scatter + gather; the
        jnp path is a stable argsort on the validity mask. Valid rows land
        identically on both paths (dead-tail contents may differ).
        """
        if kernel_ops.current_backend() == "pallas":
            n = self.capacity
            pos, total = kernel_ops.block_prefix_sum(self.validity)
            slot = jnp.where(self.validity, pos, n)
            gather = jnp.zeros((n,), jnp.int32).at[slot].set(
                jnp.arange(n, dtype=jnp.int32), mode="drop")
            cols = {name: jnp.take(a, gather, axis=0)
                    for name, a in self.columns.items()}
            return DeviceTable(cols, jnp.arange(n) < total, self.schema)
        order = jnp.argsort(~self.validity, stable=True)
        cols = {n: jnp.take(a, order, axis=0) for n, a in self.columns.items()}
        return DeviceTable(cols, jnp.take(self.validity, order), self.schema)

    def pad_to(self, capacity: int) -> "DeviceTable":
        """Grow to ``capacity`` rows by appending dead padding rows."""
        if capacity == self.capacity:
            return self
        assert capacity > self.capacity
        pad = capacity - self.capacity
        cols = {
            n: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
            for n, a in self.columns.items()
        }
        return DeviceTable(cols, jnp.pad(self.validity, (0, pad)), self.schema)


def concat_tables(tables: List[DeviceTable]) -> DeviceTable:
    """Concatenate batches along the row axis (the paper's vector-compaction
    primitive). For worker-stacked tables ([W, cap, ...]) the row axis is 1;
    the worker axis is never concatenated."""
    assert tables, "concat of zero tables"
    if len(tables) == 1:
        return tables[0]
    schema = tables[0].schema
    names = tables[0].column_names
    axis = tables[0].validity.ndim - 1
    cols = {
        n: jnp.concatenate([t.columns[n] for t in tables], axis=axis)
        for n in names
    }
    validity = jnp.concatenate([t.validity for t in tables], axis=axis)
    return DeviceTable(cols, validity, dict(schema))


def empty_like_schema(schema: Schema, capacity: int) -> DeviceTable:
    """All-dead table of ``capacity`` rows with the given schema."""
    cols = {
        n: jnp.zeros(dt.storage_shape(capacity), dtype=dt.jnp_dtype())
        for n, dt in schema.items()
    }
    return DeviceTable(cols, jnp.zeros(capacity, dtype=bool), dict(schema))
