"""Pure-JAX relational algorithms on static-shape columnar batches.

These are the TPU adaptations of cuDF's SIMT primitives (DESIGN.md §2):
dynamic hash tables become sort-based segmenting / open-addressing in fixed
buffers, dynamic output sizes become static-capacity expansions with planner
hints. The Pallas kernels in repro.kernels accelerate the hot spots; these
functions double as their oracles.

All functions operate on raw jnp arrays + a validity mask so they can be
reused by operators, kernels' ref.py, and the exchange partitioner.
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops

INT32_MAX = jnp.iinfo(jnp.int32).max

# the pallas segmented-agg kernels accumulate in GROUP_BLOCK slabs; past
# this capacity (or for 8-byte values) the jnp segment_* path is both
# faster to trace and exact, so dispatch falls back. Inclusive bound,
# matching the VMEM sizing note in kernels/segmented_agg.py: exactly
# 1 << 16 groups still dispatches to the kernels; all accumulators
# (float sum, int sum, min/max) share it.
PALLAS_AGG_GROUP_LIMIT = 1 << 16


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------

def hash32(x: jax.Array) -> jax.Array:
    """Murmur3-style finalizer; output restricted to [0, 2^31-1)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x & jnp.uint32(0x7FFFFFFE)).astype(jnp.int32)


def hash_combine(cols: Sequence[jax.Array]) -> jax.Array:
    """Combine >=1 columns into a 31-bit hash key (verify-after-join).
    2-D columns (fixed-width bytes) hash by folding their byte lanes."""
    n = cols[0].shape[0]
    h = jnp.zeros((n,), dtype=jnp.uint32)

    def mix(h, c):
        hc = hash32(c.astype(jnp.int32)).astype(jnp.uint32)
        return h ^ (hc + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))

    for c in cols:
        if c.ndim == 2:       # bytes column: fold 4-byte words then mix
            folded = jnp.zeros((n,), dtype=jnp.uint32)
            for j in range(c.shape[1]):
                folded = folded * jnp.uint32(31) + c[:, j].astype(jnp.uint32)
            h = mix(h, folded)
        else:
            h = mix(h, c)
    return (h & jnp.uint32(0x7FFFFFFE)).astype(jnp.int32)


def join_key(cols: Sequence[jax.Array]) -> Tuple[jax.Array, bool]:
    """Single int32 join key. Exact for one int column, hashed otherwise.

    Returns (key, exact). When not exact the caller must re-verify equality
    of the original columns after the join (hash-bucket-then-verify, as a
    real hash join does).
    """
    if len(cols) == 1 and jnp.issubdtype(cols[0].dtype, jnp.integer):
        return cols[0].astype(jnp.int32), True
    return hash_combine(cols), False


def packed_key(cols: Sequence[jax.Array], pack: Sequence[Tuple[int, int]],
               empty_key: int = -1) -> jax.Array:
    """Injectively pack int columns into one nonnegative int32 key.

    ``pack`` gives a ``(lo, span)`` window per column — the valid build
    side's observed value range, derived host-side at ``seal_build``
    (eligible only when the spans' product fits 2^31 - 1, so the fold
    below never overflows int32). In-range rows map to a *unique* key in
    ``[0, prod(spans))`` — strictly nonnegative, so a packed key can never
    alias the empty-slot sentinel. Rows with any column outside its window
    cannot equal any build key and map to ``empty_key`` (the probe's
    sentinel mask then reports them unmatched); values are clipped before
    folding so even far-out-of-range probes stay overflow-free.
    """
    n = cols[0].shape[0]
    key = jnp.zeros((n,), jnp.int32)
    ok = jnp.ones((n,), bool)
    for c, (lo, span) in zip(cols, pack):
        c = c.astype(jnp.int32)
        ok = ok & (c >= lo) & (c < lo + span)
        key = key * span + jnp.clip(c - lo, 0, span - 1)
    return jnp.where(ok, key, empty_key)


# ---------------------------------------------------------------------------
# sorting
# ---------------------------------------------------------------------------

def lexsort(keys: List[jax.Array], validity: jax.Array,
            descending: Sequence[bool] = None) -> jax.Array:
    """Stable multi-key sort order; invalid rows sort last.

    ``keys[0]`` is the primary key. 2-D (bytes) keys are reduced to their
    per-row bytes interpreted big-endian via iterative column passes.
    """
    n = validity.shape[0]
    descending = descending or [False] * len(keys)
    order = jnp.arange(n, dtype=jnp.int32)

    def _passes(key, desc):
        # yield (column, descending) 1-D sort passes, least significant first
        if key.ndim == 2:   # fixed-width bytes: sort byte columns right-to-left
            cols = [key[:, j].astype(jnp.int32) for j in range(key.shape[1])]
            cols = list(reversed(cols))
        else:
            if jnp.issubdtype(key.dtype, jnp.floating):
                cols = [key]
            else:
                cols = [key.astype(jnp.int32)]
        return [(c, desc) for c in cols]

    # stable multi-pass sort: apply passes least-significant first, so the
    # *last* applied pass is the most significant. keys[0] is primary ->
    # iterate keys in reverse; validity is applied last (most significant:
    # valid rows (0) before invalid (1)).
    all_passes = []
    for key, desc in reversed(list(zip(keys, descending))):
        all_passes.extend(_passes(key, desc))
    all_passes.append(((~validity).astype(jnp.int32), False))

    for k, desc in all_passes:  # least-significant first
        cur = jnp.take(k, order)
        if desc:
            # Stable descending without negating values (negation corrupts
            # INT32_MIN, which overflows back to itself, and loses the
            # -0.0 < 0.0 total-order distinction on floats): stably argsort
            # the reversed array and flip the result, which reverses the
            # comparison while preserving original order among equal keys.
            perm = (cur.shape[0] - 1 - jnp.argsort(cur[::-1], stable=True))[::-1]
        else:
            perm = jnp.argsort(cur, stable=True)
        order = jnp.take(order, perm)
    return order


# ---------------------------------------------------------------------------
# group-by
# ---------------------------------------------------------------------------

class Groups(NamedTuple):
    """Output of ``group_rows``: permutation, dense group ids, count,
    representative row per group, and the group-slot validity mask."""

    order: jax.Array        # row permutation, valid rows first, grouped
    gids: jax.Array         # group id per *sorted* row; invalid -> max_groups
    num_groups: jax.Array   # scalar
    key_rows: jax.Array     # indices (into original rows) of one representative
                            # row per group, for gathering key columns
    group_valid: jax.Array  # bool[max_groups]


def group_rows(key_cols: List[jax.Array], validity: jax.Array,
               max_groups: int) -> Groups:
    """Assign dense group ids via sort + boundary detection.

    This is the sort-based groupby a TPU prefers over cuDF's dynamic hash
    table: lexsort the keys, mark rows where any key changes, prefix-sum the
    boundaries. Exact for arbitrarily many key columns (no hashing).
    """
    order = lexsort(key_cols, validity)
    valid_sorted = jnp.take(validity, order)
    change = jnp.zeros(order.shape, dtype=bool)
    for k in key_cols:
        ks = jnp.take(k, order, axis=0)
        if ks.ndim == 2:
            diff = jnp.any(ks[1:] != ks[:-1], axis=1)
        else:
            diff = ks[1:] != ks[:-1]
        change = change.at[1:].set(change[1:] | diff)
    change = change & valid_sorted
    gids = jnp.cumsum(change.astype(jnp.int32))
    gids = jnp.where(valid_sorted, gids, max_groups)
    num_groups = jnp.sum(change.astype(jnp.int32)) + jnp.any(validity).astype(jnp.int32)

    # representative original-row index per group (first row of each segment)
    first_of_group = valid_sorted & (jnp.concatenate([jnp.ones(1, bool), change[1:]]))
    reps = jnp.zeros(max_groups + 1, dtype=jnp.int32)
    reps = reps.at[jnp.where(first_of_group, gids, max_groups)].set(order)
    group_valid = jnp.arange(max_groups) < num_groups
    return Groups(order, gids, num_groups, reps[:max_groups], group_valid)


def segment_agg(values: jax.Array, gids: jax.Array, order: jax.Array,
                validity: jax.Array, max_groups: int, kind: str) -> jax.Array:
    """Aggregate ``values`` per group id. kind in sum|count|min|max.

    Under the 'pallas' kernel backend every kind dispatches to a
    segmented-agg kernel for 1-D 4-byte values: float sums to the MXU
    scatter-add, integer sums and counts to its int32-accumulator variant
    (exact past 2^24, wrapping at 2^31 like the oracle), min/max to the
    masked-reduction variant. The only remaining fallback is capacity —
    ``max_groups`` past ``PALLAS_AGG_GROUP_LIMIT`` (an inclusive bound:
    exactly ``1 << 16`` groups still dispatches) — plus 8-byte/multi-dim
    values; those run the ``jax.ops.segment_*`` path, which doubles as
    the kernel's oracle.
    """
    v = jnp.take(values, order, axis=0)
    valid_sorted = jnp.take(validity, order)
    seg = jnp.where(valid_sorted, gids, max_groups)

    kernel_kind_ok = (v.ndim == 1 and v.dtype.itemsize <= 4 and (
        kind == "count"
        or (kind in ("sum", "min", "max")
            and (jnp.issubdtype(v.dtype, jnp.floating)
                 or jnp.issubdtype(v.dtype, jnp.integer)))))
    pallas_ok = (kernel_ops.current_backend() == "pallas"
                 and kernel_kind_ok
                 and max_groups <= PALLAS_AGG_GROUP_LIMIT)
    if pallas_ok:
        if kind == "count":
            return kernel_ops.segmented_int_sum(
                seg, valid_sorted.astype(jnp.int32), max_groups)
        if kind == "sum":
            # zero dead rows: their values may be NaN/inf (dead-lane
            # arithmetic) and 0 * NaN would poison the one-hot matmul
            acc = jnp.where(valid_sorted, v, jnp.zeros((), v.dtype))
            if jnp.issubdtype(v.dtype, jnp.integer):
                # int32 accumulator: exact past 2^24, same wrap as oracle
                return kernel_ops.segmented_int_sum(
                    seg, acc, max_groups).astype(v.dtype)
            # float32 accumulation: inexact-by-reduction-order exactly
            # like any matmul reduction
            out = kernel_ops.segmented_sum(seg, acc.astype(jnp.float32),
                                           max_groups)
            return out.astype(v.dtype)
        # min/max: dead rows carry the reduction identity so NaN/inf
        # dead-lane arithmetic can't leak into a group
        acc = jnp.where(valid_sorted, v,
                        _extreme(v.dtype, +1 if kind == "min" else -1))
        return kernel_ops.segmented_minmax(seg, acc, max_groups, kind)

    if kernel_ops.current_backend() == "pallas" and kernel_kind_ok:
        # eligible shape/kind, blocked only by capacity: the static
        # max_groups bound pushed an otherwise kernel-servable
        # aggregation onto the jnp path. Recorded per dispatch so
        # adaptive re-planning can prove it shrank the count. Gated on
        # the pallas backend: a jnp session never "falls back", so its
        # kernel_dispatch stats must stay empty.
        kernel_ops.mark_fallback("agg")

    n = max_groups + 1
    if kind == "count":
        out = jax.ops.segment_sum(valid_sorted.astype(jnp.int32), seg, n,
                                  indices_are_sorted=True)
    elif kind == "sum":
        acc = jnp.where(valid_sorted, v, jnp.zeros((), dtype=v.dtype))
        out = jax.ops.segment_sum(acc, seg, n, indices_are_sorted=True)
    elif kind == "min":
        big = _extreme(v.dtype, +1)
        out = jax.ops.segment_min(jnp.where(valid_sorted, v, big), seg, n,
                                  indices_are_sorted=True)
    elif kind == "max":
        small = _extreme(v.dtype, -1)
        out = jax.ops.segment_max(jnp.where(valid_sorted, v, small), seg, n,
                                  indices_are_sorted=True)
    else:
        raise ValueError(kind)
    return out[:max_groups]


def _extreme(dtype, sign):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(sign * jnp.inf, dtype=dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.max if sign > 0 else info.min, dtype=dtype)


# ---------------------------------------------------------------------------
# joins (sort + searchsorted; the Pallas kernel gives the hash-table variant)
# ---------------------------------------------------------------------------

class BuildTable(NamedTuple):
    """Sorted join build side (keys, permutation, original validity)."""

    sorted_keys: jax.Array   # int32[B], invalid rows pushed to +inf end
    perm: jax.Array          # int32[B] permutation into original build rows
    validity: jax.Array      # original build validity


def join_build(keys: jax.Array, validity: jax.Array) -> BuildTable:
    """Sort build keys (invalid rows to the end) for searchsorted probes."""
    k = jnp.where(validity, keys, INT32_MAX)
    perm = jnp.argsort(k, stable=True).astype(jnp.int32)
    return BuildTable(jnp.take(k, perm), perm, validity)


class ProbeResult(NamedTuple):
    """Expanded probe output: per output row the matched build/probe
    indices + liveness, and per probe row its match count."""

    build_idx: jax.Array     # int32[P*M] original build row per output row
    probe_idx: jax.Array     # int32[P*M] probe row per output row
    valid: jax.Array         # bool[P*M]
    match_count: jax.Array   # int32[P] matches per probe row (pre-expansion)


def join_probe(bt: BuildTable, probe_keys: jax.Array, probe_valid: jax.Array,
               max_matches: int) -> ProbeResult:
    """Expansion probe with static output capacity P * max_matches."""
    p = probe_keys.shape[0]
    start = jnp.searchsorted(bt.sorted_keys, probe_keys, side="left").astype(jnp.int32)
    end = jnp.searchsorted(bt.sorted_keys, probe_keys, side="right").astype(jnp.int32)
    count = jnp.where(probe_valid, end - start, 0)
    m = max_matches
    j = jnp.arange(p * m, dtype=jnp.int32)
    pi = j // m
    k = j % m
    within = k < jnp.take(count, pi)
    b = jnp.clip(jnp.take(start, pi) + k, 0, bt.sorted_keys.shape[0] - 1)
    bidx = jnp.take(bt.perm, b)
    valid = within & jnp.take(probe_valid, pi) & jnp.take(bt.validity, bidx)
    return ProbeResult(bidx, pi, valid, count)


def semi_mask(bt: BuildTable, probe_keys: jax.Array,
              probe_valid: jax.Array) -> jax.Array:
    """probe rows with >=1 match (EXISTS). Anti = probe_valid & ~semi."""
    start = jnp.searchsorted(bt.sorted_keys, probe_keys, side="left")
    end = jnp.searchsorted(bt.sorted_keys, probe_keys, side="right")
    return probe_valid & (end > start)


# ---------------------------------------------------------------------------
# partitioning (exchange support)
# ---------------------------------------------------------------------------

def partition_ids(key_cols: Sequence[jax.Array], validity: jax.Array,
                  num_partitions: int) -> jax.Array:
    """Hash-partition rows for the exchange; invalid rows -> partition 0."""
    h = hash_combine(list(key_cols))
    pid = jnp.remainder(h, num_partitions)
    return jnp.where(validity, pid, 0).astype(jnp.int32)


def partition_layout(pids: jax.Array, validity: jax.Array, num_partitions: int,
                     part_capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Stable scatter layout: row -> slot within [num_partitions, capacity].

    Returns (gather_idx, out_valid): ``gather_idx[p*cap + s]`` is the source
    row for slot s of partition p. Rows past a partition's capacity are
    dropped (callers size capacity from the flow-control governor; the
    Pallas radix_partition kernel mirrors this contract).
    """
    n = pids.shape[0]
    pids = jnp.where(validity, pids, num_partitions)  # invalid -> overflow bin
    order = jnp.argsort(pids, stable=True).astype(jnp.int32)
    sorted_pids = jnp.take(pids, order)
    # rank within partition = position - first position of this partition
    first = jnp.searchsorted(sorted_pids, jnp.arange(num_partitions + 1,
                                                     dtype=jnp.int32), side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - jnp.take(first, sorted_pids)
    in_cap = (rank < part_capacity) & (sorted_pids < num_partitions)
    total = num_partitions * part_capacity
    slot = sorted_pids * part_capacity + jnp.clip(rank, 0, part_capacity - 1)
    slot = jnp.where(in_cap, slot, total)        # rejected rows scatter OOB
    gather = jnp.zeros((total,), dtype=jnp.int32)
    gather = gather.at[slot].set(order, mode="drop")
    out_valid = jnp.zeros((total,), dtype=bool)
    out_valid = out_valid.at[slot].set(True, mode="drop")
    return gather, out_valid
