"""Inter-query batching: stack compatible small queries into one launch.

The paper's cost wins come from keeping the accelerator busy; a serving
workload of thousands of concurrent *small* point-lookup/filter/agg
queries is the regime where fixed per-query dispatch cost dwarfs compute
("Rethinking Analytical Processing in the GPU Era", PAPERS.md). This
module is the engine's answer — the same trick request-batching serving
systems play, applied to whole queries:

* ``extract_shape`` inspects an optimized single-table plan
  (scan → filter/project chain → optional aggregation → trailing
  stages) and, when eligible, lifts it into a shared ``BatchProgram``
  with the filter literals replaced by ``ParamRef`` placeholders. Two
  queries that differ only in those literals produce the *same interned
  program object*, which is what makes the stacked execution compile
  once and the scheduler's compatibility grouping a dict-key check.

* ``run_batch`` executes B member queries as ONE scan: every morsel is
  evaluated once for the shared projections plus a ``[B]``-indexed
  predicate lane per member (one fused Pallas dispatch per morsel under
  the 'pallas' backend, see ``fused.fused_batch_program``), aggregations
  stack into a single segmented-aggregation dispatch via
  ``group_id = query_id * max_groups + local_group`` (see
  ``kernels.segmented_agg.stacked_group_capacity``), and results are
  split per member on the way out.

Correctness contract: a member's batched result is identical to its solo
execution — row sets, row order (morsel order for row queries, ascending
group order for aggregates) and integer values bitwise, float sums up to
reduction order. The scheduler's property tests and the batched DuckDB
oracle sweep (``tests/test_batching.py``) enforce exactly this.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from . import dtypes as dt
from . import fused
from . import plan as P
from . import relational as rel
from .expr import (BinaryOp, BytesMatch, ColumnRef, Expr, IsIn, Literal,
                   PrefixCode, UnaryOp, Year)
from .operators import _OP_CACHES, _table_spec, lower_aggs
from .streaming import ScanStats
from .table import DeviceTable, concat_tables

_AGG_KINDS = ("sum", "count", "min", "max", "avg")

_tls = threading.local()


class Ineligible(Exception):
    """Plan shape the batching layer cannot stack (internal signal)."""


# ---------------------------------------------------------------------------
# parameterized predicates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class ParamRef(Expr):
    """Placeholder for a filter literal in a shared batch program.

    Evaluates to the current member's scalar from the thread-local
    parameter environment that the batched evaluator installs per query
    lane at trace time — so one traced program serves every member (and
    every future batch of the same shape) regardless of literal values.
    """

    idx: int
    dtype: dt.DType

    def evaluate(self, table):
        values = getattr(_tls, "param_values", None)
        if values is None:
            raise RuntimeError(
                "ParamRef evaluated outside a batched program body")
        return jnp.asarray(values[self.idx], dtype=self.dtype.jnp_dtype())

    def out_dtype(self, schema):
        return self.dtype

    def references(self):
        return set()

    def __repr__(self):
        return f"par({self.idx}:{self.dtype.name})"


def _parameterize(e: Expr, dtypes: list, values: list) -> Expr:
    """Copy a filter predicate with every ``Literal`` replaced by a
    ``ParamRef`` (walk order assigns indices, so structurally identical
    predicates parameterize identically). Literal dtypes join the program
    signature: ``x < 5`` (int32) and ``x < 5.5`` (float32) trace different
    programs and must not group."""
    if isinstance(e, Literal):
        idx = len(dtypes)
        dtypes.append(e.dtype)
        values.append(e.value)
        return ParamRef(idx, e.dtype)
    if isinstance(e, ColumnRef):
        return e
    if isinstance(e, BinaryOp):
        return BinaryOp(e.op, _parameterize(e.lhs, dtypes, values),
                        _parameterize(e.rhs, dtypes, values))
    if isinstance(e, UnaryOp):
        return UnaryOp(e.op, _parameterize(e.operand, dtypes, values))
    if isinstance(e, IsIn):
        # membership sets stay literal (they shape the traced program)
        return IsIn(_parameterize(e.operand, dtypes, values), e.values)
    if isinstance(e, BytesMatch):
        return BytesMatch(_parameterize(e.operand, dtypes, values),
                          e.parts, e.mode)
    if isinstance(e, Year):
        return Year(_parameterize(e.operand, dtypes, values))
    if isinstance(e, PrefixCode):
        return PrefixCode(_parameterize(e.operand, dtypes, values), e.n)
    raise Ineligible(f"unsupported expression {type(e).__name__}")


def _sig(e: Expr) -> str:
    """Canonical structural signature of an expression (literal *values*
    included except where a ``ParamRef`` already abstracted them)."""
    if isinstance(e, ParamRef):
        return f"par{e.idx}:{e.dtype.name}"
    if isinstance(e, ColumnRef):
        return f"col({e.name})"
    if isinstance(e, Literal):
        return f"lit({e.value!r}:{e.dtype.name})"
    if isinstance(e, BinaryOp):
        return f"({_sig(e.lhs)} {e.op} {_sig(e.rhs)})"
    if isinstance(e, UnaryOp):
        return f"{e.op}({_sig(e.operand)})"
    if isinstance(e, IsIn):
        return f"isin({_sig(e.operand)},{e.values!r})"
    if isinstance(e, BytesMatch):
        return f"match({_sig(e.operand)},{e.parts!r},{e.mode})"
    if isinstance(e, Year):
        return f"year({_sig(e.operand)})"
    if isinstance(e, PrefixCode):
        return f"pfx({_sig(e.operand)},{e.n})"
    raise Ineligible(f"unsupported expression {type(e).__name__}")


# ---------------------------------------------------------------------------
# shape extraction + program interning
# ---------------------------------------------------------------------------

class BatchProgram:
    """One interned stacked-execution template, shared by every query whose
    optimized plan has the same structural signature. Hashes by identity —
    the interning table guarantees signature-equal queries get the *same*
    object, so jit compile caches keyed on it hit across members, batches,
    and submissions."""

    def __init__(self, sig: str, table: str, columns, pre_stages,
                 param_dtypes, group_keys, user_specs, max_groups,
                 post_stages):
        self.sig = sig
        self.table = table
        self.columns = tuple(columns) if columns is not None else None
        # pre-aggregation stages in ``fused.Stage`` form; filter exprs are
        # parameterized templates, projections are shared verbatim
        self.pre_stages: Tuple[fused.Stage, ...] = tuple(pre_stages)
        self.param_dtypes: Tuple[dt.DType, ...] = tuple(param_dtypes)
        self.group_keys: Tuple[str, ...] = tuple(group_keys)
        self.user_specs = tuple(user_specs)      # as written (avg intact)
        self.lowered_specs = lower_aggs(self.user_specs)  # avg -> sum+cnt
        self.max_groups = int(max_groups)
        self.has_agg = bool(user_specs) or bool(group_keys)
        # stages above the aggregation (final SQL projection, HAVING);
        # applied per member on its [max_groups]-row result slice
        self.post_stages: Tuple[fused.Stage, ...] = tuple(post_stages)

    def __repr__(self):
        return f"BatchProgram({self.table}, {self.sig[:60]}...)"


@dataclasses.dataclass(eq=False)
class BatchShape:
    """One query's membership ticket: the interned program plus the
    member's literal values for the program's parameter slots."""

    program: BatchProgram
    params: Tuple


_PROGRAMS: Dict[str, BatchProgram] = {}
_PROGRAMS_LOCK = threading.Lock()


def clear_programs() -> None:
    """Drop the interned-program table (test isolation)."""
    with _PROGRAMS_LOCK:
        _PROGRAMS.clear()


def extract_shape(plan: P.PlanNode) -> Optional[BatchShape]:
    """Lift an optimized plan into a ``BatchShape``, or None if ineligible.

    Eligible plans are a linear single-table chain::

        TableScan[filter?] -> {Filter|Project}* -> Aggregation?
                           -> {Filter|Project}*   (post-agg stages)

    with at most one Aggregation (mode auto/single, kinds
    sum/count/min/max/avg) and expressions drawn from the core Expr
    algebra. Joins, sorts, limits, distinct, exchanges, and multi-phase
    aggregations stay on the solo path. Only *filter* literals below the
    aggregation are parameterized; projection and post-aggregation
    literals are shared computation and join the signature by value.
    """
    try:
        return _extract(plan)
    except Ineligible:
        return None


def _extract(plan: P.PlanNode) -> BatchShape:
    nodes: List[P.PlanNode] = []
    node = plan
    while not isinstance(node, P.TableScan):
        if isinstance(node, (P.Filter, P.Project, P.Aggregation)):
            nodes.append(node)
            node = node.child
        else:
            raise Ineligible(type(node).__name__)
    scan = node
    nodes.reverse()                       # scan-first order

    aggs = [n for n in nodes if isinstance(n, P.Aggregation)]
    if len(aggs) > 1:
        raise Ineligible("stacked aggregations")
    agg = aggs[0] if aggs else None
    if agg is not None:
        if agg.mode not in ("auto", "single"):
            raise Ineligible(f"aggregation mode {agg.mode}")
        for _out, kind, _col in agg.aggs:
            if kind not in _AGG_KINDS:
                raise Ineligible(f"aggregation kind {kind}")
    split = nodes.index(agg) if agg is not None else len(nodes)
    below = nodes[:split]
    above = nodes[split + 1:] if agg is not None else []

    param_dtypes: list = []
    param_values: list = []
    columns = tuple(scan.columns) if scan.columns is not None else None
    sig_parts = [f"scan({scan.table};{columns})"]
    pre: List[fused.Stage] = []
    # the pushed-down scan filter re-applies as the first parameterized
    # stage: the batched scan streams unfiltered (members' predicates
    # differ, so per-member zone-map skipping is off by construction)
    for filt in ([scan.filter] if scan.filter is not None else []):
        tmpl = _parameterize(filt, param_dtypes, param_values)
        pre.append((tmpl, None))
        sig_parts.append(f"f[{_sig(tmpl)}]")
    for n in below:
        if isinstance(n, P.Filter):
            tmpl = _parameterize(n.predicate, param_dtypes, param_values)
            pre.append((tmpl, None))
            sig_parts.append(f"f[{_sig(tmpl)}]")
        else:
            projs = tuple((name, e) for name, e in n.projections)
            pre.append((None, projs))
            sig_parts.append(
                "p[" + ",".join(f"{nm}={_sig(e)}" for nm, e in projs) + "]")

    group_keys: Tuple[str, ...] = ()
    user_specs: tuple = ()
    max_groups = 1
    if agg is not None:
        group_keys = tuple(agg.group_keys)
        user_specs = tuple((o, k, c) for o, k, c in agg.aggs)
        max_groups = int(agg.max_groups)
        sig_parts.append(
            f"agg[{group_keys};"
            + ",".join(f"{o}:{k}:{c}" for o, k, c in user_specs)
            + f";{max_groups}]")

    post: List[fused.Stage] = []
    for n in above:
        if isinstance(n, P.Filter):
            post.append((n.predicate, None))
            sig_parts.append(f"F[{_sig(n.predicate)}]")
        else:
            projs = tuple((name, e) for name, e in n.projections)
            post.append((None, projs))
            sig_parts.append(
                "P[" + ",".join(f"{nm}={_sig(e)}" for nm, e in projs) + "]")

    sig = "|".join(sig_parts)
    with _PROGRAMS_LOCK:
        program = _PROGRAMS.get(sig)
        if program is None:
            program = BatchProgram(sig, scan.table, columns, pre,
                                   param_dtypes, group_keys, user_specs,
                                   max_groups, post)
            _PROGRAMS[sig] = program
    return BatchShape(program, tuple(param_values))


# ---------------------------------------------------------------------------
# batched per-morsel evaluation
# ---------------------------------------------------------------------------

def apply_batched_stages(table: DeviceTable, stages: Sequence[fused.Stage],
                         params: Tuple, n_members: int):
    """Evaluate the shared stage chain once plus one predicate lane per
    member. Filters AND into per-member masks instead of narrowing the
    shared validity (``DeviceTable.filter`` only touches validity and
    projections are validity-blind, so the shared table stays correct for
    every member); projections run once for all members. Returns
    ``(projected table, bool masks [n_members, capacity])``. Runs both
    under ``jax.eval_shape`` and inside the fused Pallas kernel body."""
    masks = [table.validity] * n_members
    cur = table
    for filter_expr, projections in stages:
        if filter_expr is not None:
            for b in range(n_members):
                _tls.param_values = tuple(p[b] for p in params)
                try:
                    m = filter_expr.evaluate(cur)
                finally:
                    _tls.param_values = None
                masks[b] = masks[b] & m
        if projections is not None:
            cols, schema = {}, {}
            for out_name, e in projections:
                v = e.evaluate(cur)
                if v.ndim == 0:   # literal: broadcast to rows
                    v = jnp.broadcast_to(v, (cur.capacity,))
                cols[out_name] = v
                schema[out_name] = e.out_dtype(cur.schema)
            cur = DeviceTable(cols, cur.validity, schema)
    return cur, jnp.stack(masks)


@functools.lru_cache(maxsize=None)
def _compiled_morsel(program: BatchProgram, n_members: int, spec, backend):
    """One jitted program per (interned program, padded member count,
    morsel spec, backend) — the compile-once property the whole layer is
    built for. Mirrors ``operators.table_op``'s record/replay dispatch
    accounting."""
    del spec

    def body(table, params):
        # morsels arrive worker-stacked [1, cap]: drop the worker axis
        # (batching is W=1 only; the scheduler enforces it at extraction)
        t = DeviceTable({n: a[0] for n, a in table.columns.items()},
                        table.validity[0], dict(table.schema))
        if backend == "pallas":
            return fused.fused_batch_program(
                t, params,
                lambda tb, pr: apply_batched_stages(
                    tb, program.pre_stages, pr, n_members),
                n_members)
        return apply_batched_stages(t, program.pre_stages, params, n_members)

    used: set = set()
    return jax.jit(body), used


_OP_CACHES.append(_compiled_morsel)


def batch_morsel_op(program: BatchProgram, n_members: int,
                    table: DeviceTable, params: Tuple):
    """Run one morsel through the batched stage program (jit + dispatch
    accounting)."""
    jitted, used = _compiled_morsel(program, n_members,
                                    _table_spec((table,) + tuple(params)),
                                    kernel_ops.current_backend())
    with kernel_ops.record_kernels(used):
        out = jitted(table, params)
    for kind in kernel_ops.kernel_snapshot(used):
        kernel_ops.count_dispatch(kind)
    return out


# ---------------------------------------------------------------------------
# stacked aggregation
# ---------------------------------------------------------------------------

def _stacked_segment_agg(vals, member_sorted, gids, max_groups: int,
                         n_members: int, kind: str):
    """All members' segmented aggregation in one dispatch.

    ``vals`` are the shared values in union-sorted row order,
    ``member_sorted`` the per-member validity in the same order, ``gids``
    the shared dense group ids (union-invalid rows carry ``max_groups``).
    Member ``b``'s group ``j`` maps to stacked segment
    ``b * max_groups + j``; rows dead for a member map to the
    ``n_members * max_groups`` sentinel — the only rows whose gid is the
    ``max_groups`` sentinel are union-invalid, hence dead for every
    member, so the per-member remap can never alias a neighbor lane's
    group 0. Unlike ``relational.segment_agg`` the segment ids are NOT
    sorted (a union-valid/member-dead row interrupts the run), so the jnp
    path drops ``indices_are_sorted``. Returns ``[n_members, max_groups]``
    (+ value trailing dims)."""
    total = n_members * max_groups
    n = member_sorted.shape[1]
    lane = max_groups * jnp.arange(n_members, dtype=gids.dtype)[:, None]
    seg = jnp.where(member_sorted, gids[None, :] + lane, total).reshape(-1)
    vflat = jnp.broadcast_to(
        vals[None], (n_members,) + vals.shape).reshape(
            (n_members * n,) + vals.shape[1:])
    mflat = member_sorted.reshape(-1)

    kernel_kind_ok = (vals.ndim == 1 and vals.dtype.itemsize <= 4
                      and (kind == "count"
                           or jnp.issubdtype(vals.dtype, jnp.floating)
                           or jnp.issubdtype(vals.dtype, jnp.integer)))
    pallas_ok = (kernel_ops.current_backend() == "pallas" and kernel_kind_ok
                 and total <= rel.PALLAS_AGG_GROUP_LIMIT)
    if pallas_ok:
        if kind == "count":
            out = kernel_ops.segmented_int_sum(
                seg, mflat.astype(jnp.int32), total)
        elif kind == "sum":
            acc = jnp.where(mflat, vflat, jnp.zeros((), vflat.dtype))
            if jnp.issubdtype(vflat.dtype, jnp.integer):
                out = kernel_ops.segmented_int_sum(
                    seg, acc, total).astype(vflat.dtype)
            else:
                out = kernel_ops.segmented_sum(
                    seg, acc.astype(jnp.float32), total).astype(vflat.dtype)
        else:
            ident = rel._extreme(vflat.dtype, +1 if kind == "min" else -1)
            out = kernel_ops.segmented_minmax(
                seg, jnp.where(mflat, vflat, ident), total, kind)
        return out.reshape((n_members, max_groups) + vals.shape[1:])

    if kernel_ops.current_backend() == "pallas" and kernel_kind_ok:
        # stacked capacity overflow: eligible shape, too many lanes
        kernel_ops.mark_fallback("agg")

    if kind in ("count", "sum") and vals.ndim == 1:
        # Unsorted segment-sum lowers to a serialized scatter on CPU XLA
        # (~25ms per spec at [16 x 30k]); the same reduction phrased as a
        # one-hot contraction is a dense [n_members, n] @ [n, max_groups]
        # matmul (~1ms), and XLA CSEs the shared one-hot across specs in
        # the same jitted body. Sentinel gids (== max_groups) match no
        # one-hot column, so union-invalid rows drop out exactly as they
        # did under the sentinel segment id. Integer inputs contract with
        # an integer accumulator (no float round-trip), so int sums and
        # counts stay exact.
        onehot = (gids[:, None]
                  == jnp.arange(max_groups, dtype=gids.dtype)[None, :])
        if kind == "count":
            acc = member_sorted.astype(jnp.int32)
        else:
            acc = jnp.where(member_sorted, vals[None, :],
                            jnp.zeros((), vals.dtype))
        out = jax.lax.dot_general(
            acc, onehot.astype(acc.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=acc.dtype)
        return out.reshape(n_members, max_groups)

    nseg = total + 1
    mask = mflat.reshape((-1,) + (1,) * (vflat.ndim - 1))
    if kind == "count":
        out = jax.ops.segment_sum(mflat.astype(jnp.int32), seg, nseg)
    elif kind == "sum":
        acc = jnp.where(mask, vflat, jnp.zeros((), vflat.dtype))
        out = jax.ops.segment_sum(acc, seg, nseg)
    elif kind == "min":
        out = jax.ops.segment_min(
            jnp.where(mask, vflat, rel._extreme(vflat.dtype, +1)), seg, nseg)
    elif kind == "max":
        out = jax.ops.segment_max(
            jnp.where(mask, vflat, rel._extreme(vflat.dtype, -1)), seg, nseg)
    else:
        raise ValueError(kind)
    return out[:total].reshape((n_members, max_groups) + vals.shape[1:])


def _stacked_aggregate(table: DeviceTable, masks, program: BatchProgram,
                       n_members: int):
    """All members' aggregation over the materialized batched output.

    Keyed: ONE ``group_rows`` over the union of member masks (members
    share key columns, so their groups are a subsequence of the union's
    ascending group order — matching solo output order), then every spec
    through the stacked segmented aggregation. Global: masked reductions
    per member lane. avg finalizes as sum/max(count,1) exactly like
    ``operators._finalize_avg``. Returns ``(key columns [max_groups],
    agg columns [n_members, max_groups], emission mask)``."""
    G = program.max_groups
    key_vals: Dict[str, jax.Array] = {}
    agg_cols: Dict[str, jax.Array] = {}
    if program.group_keys:
        key_cols = [table.columns[k] for k in program.group_keys]
        union = jnp.any(masks, axis=0)
        g = rel.group_rows(key_cols, union, G)
        member_sorted = jnp.take(masks, g.order, axis=1)
        for k in program.group_keys:
            key_vals[k] = jnp.take(table.columns[k], g.key_rows, axis=0)
        rows = _stacked_segment_agg(
            jnp.zeros((table.capacity,), jnp.int32), member_sorted, g.gids,
            G, n_members, "count")
        emit = g.group_valid[None, :] & (rows > 0)
        for out, kind, col_ in program.lowered_specs:
            vals = (jnp.zeros((table.capacity,), jnp.int32) if col_ is None
                    else table.columns[col_])
            vals_sorted = jnp.take(vals, g.order, axis=0)
            agg_cols[out] = _stacked_segment_agg(
                vals_sorted, member_sorted, g.gids, G, n_members, kind)
    else:
        # global aggregation: one row per member, masked jnp reductions
        # (identities match operators._aggregate's keyless branch)
        emit = jnp.ones((n_members, 1), dtype=bool)
        for out, kind, col_ in program.lowered_specs:
            vals = (jnp.zeros((table.capacity,), jnp.int32) if col_ is None
                    else table.columns[col_])
            axes = tuple(range(1, vals.ndim + 1))
            mask = masks.reshape(masks.shape + (1,) * (vals.ndim - 1))
            if kind == "count":
                agg_cols[out] = jnp.sum(masks.astype(jnp.int32), axis=1,
                                        keepdims=True)
            elif kind == "sum":
                agg_cols[out] = jnp.sum(
                    jnp.where(mask, vals[None], jnp.zeros((), vals.dtype)),
                    axis=axes).reshape(n_members, 1)
            elif kind == "min":
                agg_cols[out] = jnp.min(
                    jnp.where(mask, vals[None], rel._extreme(vals.dtype, +1)),
                    axis=axes).reshape(n_members, 1)
            elif kind == "max":
                agg_cols[out] = jnp.max(
                    jnp.where(mask, vals[None], rel._extreme(vals.dtype, -1)),
                    axis=axes).reshape(n_members, 1)
            else:
                raise ValueError(kind)
    # finalize avg lanes (same arithmetic as operators._finalize_avg)
    for out, kind, _col in program.user_specs:
        if kind == "avg":
            s = agg_cols.pop(f"{out}__sum")
            c = agg_cols.pop(f"{out}__cnt")
            agg_cols[out] = (s.astype(jnp.float32)
                             / jnp.maximum(c, 1).astype(jnp.float32))
    return key_vals, agg_cols, emit


@functools.lru_cache(maxsize=None)
def _compiled_agg(program: BatchProgram, n_members: int, spec, backend):
    del spec, backend   # one entry (and used-set) per specialization

    def body(table, masks):
        return _stacked_aggregate(table, masks, program, n_members)

    used: set = set()
    return jax.jit(body), used


_OP_CACHES.append(_compiled_agg)


@functools.lru_cache(maxsize=None)
def _compiled_post(program: BatchProgram, spec, backend):
    del spec, backend

    def body(table):
        return fused.apply_stages(table, program.post_stages)

    used: set = set()
    return jax.jit(body), used


_OP_CACHES.append(_compiled_post)


def _record_replay(cache_entry, *args):
    jitted, used = cache_entry
    with kernel_ops.record_kernels(used):
        out = jitted(*args)
    for kind in kernel_ops.kernel_snapshot(used):
        kernel_ops.count_dispatch(kind)
    return out


def _agg_schema(program: BatchProgram, in_schema) -> Dict[str, dt.DType]:
    """Host-side output schema of the stacked aggregation (same rules as
    ``operators._aggregate`` + avg finalize)."""
    schema: Dict[str, dt.DType] = {}
    for k in program.group_keys:
        schema[k] = in_schema[k]
    for out, kind, col_ in program.user_specs:
        if kind == "avg":
            schema[out] = dt.FLOAT32
        elif kind == "count":
            schema[out] = dt.INT32
        else:
            schema[out] = in_schema[col_]
    return schema


# ---------------------------------------------------------------------------
# batched execution loop (called from Driver.collect_batch)
# ---------------------------------------------------------------------------

def padded_members(n: int) -> int:
    """Member-lane count rounded up to a power of two: dummy lanes reuse
    member 0's parameters and have their outputs dropped, so one compiled
    program per (program, lane count) serves every batch size beneath it
    — the amortization the >=2x serving throughput win comes from."""
    return 1 << max(0, (n - 1).bit_length())


def run_batch(driver, shapes: Sequence[BatchShape],
              lanes: Optional[int] = None) -> List[Dict[str, np.ndarray]]:
    """Execute ``shapes`` (all sharing one interned program) as a single
    stacked scan; returns one host-numpy result dict per member, in
    order. Caller (``Driver.collect_batch``) provides the kernel scope.
    ``lanes`` pins the stacked lane count (must cover the group); the
    scheduler passes its per-program cap so one compiled executable
    serves every launch of the program.
    """
    program = shapes[0].program
    assert all(s.program is program for s in shapes), \
        "run_batch members must share one interned BatchProgram"
    n = len(shapes)
    lanes = padded_members(max(n, lanes or 0))
    params = tuple(
        jnp.asarray(np.asarray(
            [s.params[i] for s in shapes]
            + [shapes[0].params[i]] * (lanes - n),
            dtype=program.param_dtypes[i].np_dtype()))
        for i in range(len(program.param_dtypes)))

    ctx = driver.ctx
    src = ctx.catalog.get(program.table)
    stats = driver.scan_stats.setdefault(program.table, ScanStats())
    columns = list(program.columns) if program.columns is not None else None
    # the scan streams unfiltered: member predicates differ, so zone-map
    # skipping is off and each pushed-down filter re-applies as that
    # member's first parameterized stage (a superset scan is always safe)
    if ctx.streaming and hasattr(src, "stream"):
        kwargs = {}
        if "host_budget" in inspect.signature(src.stream).parameters:
            kwargs["host_budget"] = ctx.host_budget()
        morsels = src.stream(1, columns, ctx.batch_rows, filter_expr=None,
                             prefetch_depth=ctx.prefetch_depth,
                             sharding=ctx.worker_sharding(), stats=stats,
                             **kwargs)
    else:
        kwargs = {}
        if "stats" in inspect.signature(src.scan).parameters:
            kwargs["stats"] = stats
        morsels = src.scan(1, columns, ctx.batch_rows, filter_expr=None,
                           **kwargs)

    spent = 0.0
    if program.has_agg:
        tables: List[DeviceTable] = []
        mask_parts: List[jax.Array] = []
        for morsel in morsels:
            t0 = time.perf_counter()
            out_table, masks = batch_morsel_op(program, lanes, morsel, params)
            spent += time.perf_counter() - t0
            tables.append(out_table)
            mask_parts.append(masks)
        t0 = time.perf_counter()
        # small-query contract: the projected scan output materializes on
        # device (like any blocking aggregation input) and aggregates once
        table = concat_tables(tables)
        masks = (mask_parts[0] if len(mask_parts) == 1
                 else jnp.concatenate(mask_parts, axis=1))
        key_vals, agg_cols, emit = _record_replay(
            _compiled_agg(program, lanes, _table_spec((table, masks)),
                          kernel_ops.current_backend()),
            table, masks)
        schema = _agg_schema(program, table.schema)
        results: List[Dict[str, np.ndarray]] = []
        for b in range(n):
            cols = {k: key_vals[k] for k in program.group_keys}
            for out, _kind, _col in program.user_specs:
                cols[out] = agg_cols[out][b]
            member = DeviceTable(cols, emit[b], dict(schema))
            if program.post_stages:
                member = _record_replay(
                    _compiled_post(program, _table_spec((member,)),
                                   kernel_ops.current_backend()),
                    member)
            results.append(member.to_numpy())
        spent += time.perf_counter() - t0
        driver.op_seconds["BatchedPipeline"] = (
            driver.op_seconds.get("BatchedPipeline", 0.0) + spent)
        return results

    # row queries: per-morsel host scatter in morsel order — identical row
    # order to the solo path's flat[validity] collection
    acc: List[Dict[str, List[np.ndarray]]] = [
        {} for _ in range(n)]
    out_names: List[str] = []
    for morsel in morsels:
        t0 = time.perf_counter()
        out_table, masks = batch_morsel_op(program, lanes, morsel, params)
        spent += time.perf_counter() - t0
        out_names = list(out_table.column_names)
        masks_np = np.asarray(masks)
        cols_np = {c: np.asarray(out_table.columns[c]) for c in out_names}
        for b in range(n):
            sel = masks_np[b]
            for c in out_names:
                acc[b].setdefault(c, []).append(cols_np[c][sel])
    driver.op_seconds["BatchedPipeline"] = (
        driver.op_seconds.get("BatchedPipeline", 0.0) + spent)
    return [
        {c: np.concatenate(parts[c]) for c in out_names}
        for parts in acc]
