"""Rule-based logical optimizer: the paper's "driver adaptation" planner.

Presto's coordinator adapts logical plans for device execution (paper §3.1):
it chooses join distributions, prunes and pushes work into connectors, and
sizes operators from catalog statistics. This module reproduces that step as
a pass pipeline over ``PlanNode`` trees:

* ``push_filters``      -- merge Filter nodes into ``TableScan.filter`` (and
                           through pure-rename Projects), so predicates run
                           fused inside the scan and data skipping can use
                           chunk min/max stats.
* ``prune_columns``     -- scan only columns referenced downstream.
* ``choose_join_distribution``
                        -- broadcast vs partitioned per join, from catalog
                           row counts (replaces hand-set ``distribution=``).
* ``derive_capacities`` -- static-shape capacity hints (``max_groups``,
                           ``max_matches``) from catalog stats + key
                           uniqueness, replacing the ad-hoc ``Sizes``
                           threading the queries used to do by hand.
* ``place_exchanges``   -- lower the logical plan to a *distributed
                           fragment plan*: the join-distribution hint and
                           the Aggregation/Distinct auto modes become
                           explicit ``Repartition``/``Broadcast`` exchange
                           nodes (the paper's plan fragments separated by
                           exchanges), placed only where the planner can
                           prove the input is still worker-partitioned.
                           Runs only when ``config.num_workers > 1``.

``optimize(plan, catalog)`` runs the default pipeline; ``explain(plan)``
pretty-prints a plan tree (with row bounds when a catalog is given).

Capacity hints are *sound upper bounds*: a too-small ``max_groups`` or
``max_matches`` silently drops rows, so every derivation here bounds the
true cardinality from above (table row counts, dictionary domain sizes,
provable build-key uniqueness).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, List, Optional, Sequence, Set

from . import dtypes as dt
from . import plan as P
from .expr import BinaryOp, ColumnRef, Expr

# max_groups/max_matches are static array capacities; when the provable
# bound exceeds this budget the optimizer leaves the hand-set hint alone
# instead of deriving something absurd (or silently unsound).
MAX_CAPACITY = 1 << 24


def _pow2(n: int) -> int:
    return max(int(2 ** math.ceil(math.log2(max(n, 2)))), 2)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Knobs for the stats-driven decisions."""

    # build sides estimated above this many rows are exchanged (partitioned
    # join) instead of replicated to every worker (broadcast join)
    broadcast_row_limit: int = 1 << 16
    # slack added before rounding group capacities to a power of two
    group_slack: int = 8
    # planned worker count: >1 makes ``place_exchanges`` lower distribution
    # hints into explicit Repartition/Broadcast exchange nodes
    num_workers: int = 1
    # runtime-feedback store (core.feedback.FeedbackStore). Set, observed
    # cardinalities from prior executions override the static catalog row
    # bounds: join distribution and orientation follow observed sizes, and
    # ``derive_capacities`` tightens max_groups/build_rows/max_matches so
    # more operators stay on the pallas kernels (ROADMAP "Adaptive
    # execution"). None = plan statically (the cold path).
    feedback: Optional[object] = None
    # multiplicative headroom on observed group counts before re-rounding
    # to a power of two (drift tolerance between runs)
    feedback_slack: float = 1.25


DEFAULT_CONFIG = OptimizerConfig()


# ---------------------------------------------------------------------------
# runtime-feedback lookups
# ---------------------------------------------------------------------------

def observed_rows(node: P.PlanNode, catalog,
                  config: OptimizerConfig) -> Optional[int]:
    """Observed output cardinality of ``node`` from a prior execution, or
    None when no feedback store is configured / nothing was recorded for
    this plan shape (worker count and table versions must match — see
    ``FeedbackStore.key_for``)."""
    fb = config.feedback
    if fb is None:
        return None
    return fb.rows(fb.key_for(node, catalog, config.num_workers))


def estimated_rows(node: P.PlanNode, catalog,
                   config: OptimizerConfig = DEFAULT_CONFIG) -> int:
    """The row estimate the planner believes: observed cardinality when the
    feedback store has one, the static ``row_bound`` otherwise."""
    obs = observed_rows(node, catalog, config)
    return int(obs) if obs is not None else int(row_bound(node, catalog))


def feedback_estimates(plan: P.PlanNode, catalog,
                       config: OptimizerConfig) -> Dict[str, int]:
    """Per-node planner estimates for an optimized plan, keyed by feedback
    store key — the "producing estimates" a plan-cache entry is filed
    under. After execution the scheduler compares them against the fresh
    observations: a q-error past its threshold invalidates the cached
    plan, so the next submission re-plans from the better numbers."""
    fb = config.feedback
    if fb is None:
        return {}
    out: Dict[str, int] = {}

    def visit(node: P.PlanNode) -> None:
        for c in node.children():
            visit(c)
        if isinstance(node, (P.Repartition, P.Broadcast, P.Exchange)):
            return                       # keyed through to their child
        try:
            est = row_bound(node, catalog)
        except TypeError:
            return
        key = fb.key_for(node, catalog, config.num_workers)
        entry = fb.get(key)
        out[key] = int(entry.rows) if entry is not None else int(est)

    visit(plan)
    return out


# ---------------------------------------------------------------------------
# tree plumbing
# ---------------------------------------------------------------------------

def replace_children(node: P.PlanNode,
                     new_children: Sequence[P.PlanNode]) -> P.PlanNode:
    """Rebuild ``node`` with ``new_children`` (in ``node.children()`` order)."""
    kids = iter(new_children)
    updates = {}
    for f in dataclasses.fields(node):
        if isinstance(getattr(node, f.name), P.PlanNode):
            updates[f.name] = next(kids)
    return dataclasses.replace(node, **updates) if updates else node


def rewrite_refs(e: Expr, rename: Dict[str, str]) -> Expr:
    """Rebuild an expression with column references renamed."""
    if isinstance(e, ColumnRef):
        return ColumnRef(rename.get(e.name, e.name))
    if dataclasses.is_dataclass(e):
        updates = {f.name: rewrite_refs(getattr(e, f.name), rename)
                   for f in dataclasses.fields(e)
                   if isinstance(getattr(e, f.name), Expr)}
        if updates:
            return dataclasses.replace(e, **updates)
    return e


# ---------------------------------------------------------------------------
# schema inference
# ---------------------------------------------------------------------------

def infer_schema(node: P.PlanNode, catalog) -> Dict[str, dt.DType]:
    """Output schema (ordered name -> DType) of a plan node."""
    if isinstance(node, P.TableScan):
        src = catalog.get(node.table).schema
        cols = list(node.columns) if node.columns is not None else list(src)
        return {c: src[c] for c in cols}
    if isinstance(node, P.InMemorySource):
        return dict(node.schema)
    if isinstance(node, (P.Filter, P.Limit, P.OrderBy, P.Exchange,
                         P.Repartition, P.Broadcast)):
        return infer_schema(node.child, catalog)
    if isinstance(node, P.Project):
        child = infer_schema(node.child, catalog)
        return {name: e.out_dtype(child) for name, e in node.projections}
    if isinstance(node, P.Aggregation):
        child = infer_schema(node.child, catalog)
        out = {k: child[k] for k in node.group_keys}
        for name, kind, col_ in node.aggs:
            if kind == "count":
                out[name] = dt.INT32
            elif kind == "avg":
                if node.mode == "partial":
                    # partial phase emits mergeable sum+count state
                    out[f"{name}__sum"] = child[col_]
                    out[f"{name}__cnt"] = dt.INT32
                else:
                    out[name] = dt.FLOAT32
            elif node.mode == "final" and col_ not in child:
                # final phase consumes partial state named by the output
                out[name] = child[name]
            else:
                out[name] = child[col_]
        return out
    if isinstance(node, P.Distinct):
        child = infer_schema(node.child, catalog)
        return {k: child[k] for k in node.keys}
    if isinstance(node, P.Join):
        probe = infer_schema(node.probe, catalog)
        if node.join_type in ("left_semi", "left_anti"):
            return probe
        build = infer_schema(node.build, catalog)
        out = dict(probe)
        for name in node.build_payload:
            out[name] = build[name]
        if node.join_type == "left_outer":
            out["__matched"] = dt.BOOL
        return out
    if isinstance(node, P.ScalarBroadcast):
        out = dict(infer_schema(node.child, catalog))
        scalar = infer_schema(node.scalar, catalog)
        for name in node.columns:
            out[name] = scalar[name]
        return out
    raise TypeError(f"cannot infer schema for {type(node).__name__}")


# ---------------------------------------------------------------------------
# cardinality bounds
# ---------------------------------------------------------------------------

def row_bound(node: P.PlanNode, catalog) -> int:
    """Upper bound on the number of valid output rows."""
    if isinstance(node, P.TableScan):
        return int(catalog.get(node.table).num_rows())
    if isinstance(node, P.InMemorySource):
        vals = list(node.data.values())
        return len(vals[0]) if vals else 0
    if isinstance(node, (P.Filter, P.Project, P.ScalarBroadcast, P.Exchange,
                         P.Repartition)):
        return row_bound(node.children()[0], catalog)
    if isinstance(node, P.Broadcast):
        # every worker holds a replica: W copies of each valid row
        return row_bound(node.child, catalog) * max(node.num_workers, 1)
    if isinstance(node, (P.Aggregation, P.Distinct)):
        keys = node.group_keys if isinstance(node, P.Aggregation) else node.keys
        if not keys:
            return 1
        child_bound = row_bound(node.child, catalog)
        dom = _domain_bound(keys, infer_schema(node.child, catalog))
        return min(child_bound, dom) if dom is not None else child_bound
    if isinstance(node, P.OrderBy):
        b = row_bound(node.child, catalog)
        return min(b, node.limit) if node.limit is not None else b
    if isinstance(node, P.Limit):
        return min(row_bound(node.child, catalog), node.n)
    if isinstance(node, P.Join):
        probe = row_bound(node.probe, catalog)
        if node.join_type in ("left_semi", "left_anti"):
            return probe
        if _build_side_unique(node, catalog):
            # every probe row matches at most one build row (left_outer keeps
            # each probe row exactly once: matched or padded)
            return probe
        out = probe * max(node.max_matches, 1)
        return out + probe if node.join_type == "left_outer" else out
    raise TypeError(f"cannot bound rows for {type(node).__name__}")


def _domain_bound(keys: Sequence[str],
                  schema: Dict[str, dt.DType]) -> Optional[int]:
    """Product of key-domain sizes, when every key has a finite domain."""
    prod = 1
    for k in keys:
        d = schema[k]
        if d.name == "dict32" and d.dictionary is not None:
            prod *= max(len(d.dictionary), 1)
        elif d.name == "bool":
            prod *= 2
        else:
            return None
    return prod


def unique_sets(node: P.PlanNode, catalog) -> List[FrozenSet[str]]:
    """Column sets proven to uniquely identify output rows (key inference).

    Sources declare primary keys via ``TableSource.unique_keys``; grouping
    and distinct make their keys unique; joins against a unique build side
    preserve probe-side uniqueness.
    """
    if isinstance(node, P.TableScan):
        src = catalog.get(node.table)
        cols = set(node.columns) if node.columns is not None else set(src.schema)
        return [frozenset(u) for u in getattr(src, "unique_keys", ())
                if set(u) <= cols]
    if isinstance(node, (P.Filter, P.Limit, P.OrderBy, P.Exchange,
                         P.ScalarBroadcast, P.Repartition, P.Broadcast)):
        # Repartition permutes rows; Broadcast replicates *across* workers
        # but each worker's slice stays duplicate-free, which is what the
        # per-worker join build uniqueness (max_matches) relies on.
        return unique_sets(node.children()[0], catalog)
    if isinstance(node, P.Project):
        # translate through pure column renames
        out_names: Dict[str, List[str]] = {}
        for name, e in node.projections:
            if isinstance(e, ColumnRef):
                out_names.setdefault(e.name, []).append(name)
        translated = []
        for u in unique_sets(node.child, catalog):
            if all(c in out_names for c in u):
                translated.append(frozenset(out_names[c][0] for c in u))
        return translated
    if isinstance(node, P.Aggregation):
        return [frozenset(node.group_keys)] if node.group_keys else []
    if isinstance(node, P.Distinct):
        return [frozenset(node.keys)]
    if isinstance(node, P.Join):
        if node.join_type in ("left_semi", "left_anti"):
            return unique_sets(node.probe, catalog)
        if _build_side_unique(node, catalog):
            return unique_sets(node.probe, catalog)
        return []
    return []


def _build_side_unique(node: P.Join, catalog) -> bool:
    """True when the build keys provably identify at most one build row."""
    bk = set(node.build_keys)
    return any(u <= bk for u in unique_sets(node.build, catalog))


def _exact_key(node: P.Join, catalog) -> bool:
    """Mirror of HashJoin's exact-key rule: single int-like key column."""
    if len(node.build_keys) != 1:
        return False
    build = infer_schema(node.build, catalog)
    return build[node.build_keys[0]].name in ("int32", "date32", "dict32")


# ---------------------------------------------------------------------------
# rule 1: predicate pushdown
# ---------------------------------------------------------------------------

def push_filters(node: P.PlanNode, catalog,
                 config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Merge Filter nodes into TableScan.filter, through pure renames."""
    if isinstance(node, P.Filter):
        child = push_filters(node.child, catalog, config)
        if isinstance(child, P.Filter):
            merged = P.Filter(child.child,
                              BinaryOp("and", child.predicate, node.predicate),
                              compact=node.compact or child.compact)
            return push_filters(merged, catalog, config)
        if isinstance(child, P.TableScan):
            pred = (node.predicate if child.filter is None
                    else BinaryOp("and", child.filter, node.predicate))
            return dataclasses.replace(child, filter=pred)
        if isinstance(child, P.Project):
            rename = {name: e.name for name, e in child.projections
                      if isinstance(e, ColumnRef)}
            if node.predicate.references() <= set(rename):
                pushed = push_filters(
                    P.Filter(child.child,
                             rewrite_refs(node.predicate, rename),
                             compact=node.compact),
                    catalog, config)
            else:
                return dataclasses.replace(
                    node, child=dataclasses.replace(
                        child, child=push_filters(child.child, catalog, config)))
            return dataclasses.replace(child, child=pushed)
        return dataclasses.replace(node, child=child)
    return replace_children(
        node, [push_filters(c, catalog, config) for c in node.children()])


# ---------------------------------------------------------------------------
# rule 2: projection pruning
# ---------------------------------------------------------------------------

def prune_columns(node: P.PlanNode, catalog,
                  config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Restrict every TableScan to the columns referenced downstream."""
    return _prune(node, set(infer_schema(node, catalog)), catalog)


def _prune(node: P.PlanNode, required: Set[str], catalog) -> P.PlanNode:
    if isinstance(node, P.TableScan):
        src = catalog.get(node.table).schema
        need = set(required)
        if node.filter is not None:
            need |= node.filter.references()
        cols = [c for c in src if c in need]
        if not cols:                     # keep one column to carry row count
            cols = [next(iter(src))]
        return dataclasses.replace(node, columns=cols)
    if isinstance(node, P.InMemorySource):
        return node
    if isinstance(node, P.Filter):
        return dataclasses.replace(
            node, child=_prune(node.child,
                               required | node.predicate.references(), catalog))
    if isinstance(node, P.Project):
        keep = [(n, e) for n, e in node.projections if n in required]
        if not keep:
            keep = list(node.projections)[:1]
        need: Set[str] = set()
        for _, e in keep:
            need |= e.references()
        return P.Project(_prune(node.child, need, catalog), keep)
    if isinstance(node, P.Aggregation):
        need = set(node.group_keys) | {c for _, _, c in node.aggs
                                       if c is not None}
        return dataclasses.replace(node,
                                   child=_prune(node.child, need, catalog))
    if isinstance(node, P.Distinct):
        return dataclasses.replace(
            node, child=_prune(node.child, set(node.keys), catalog))
    if isinstance(node, P.Join):
        probe_out = set(infer_schema(node.probe, catalog))
        if node.join_type in ("left_semi", "left_anti"):
            probe_req = (required & probe_out) | set(node.probe_keys)
            build_req = set(node.build_keys)
        else:
            probe_req = ((required - set(node.build_payload) - {"__matched"})
                         & probe_out) | set(node.probe_keys)
            build_req = set(node.build_keys) | set(node.build_payload)
        return dataclasses.replace(
            node,
            probe=_prune(node.probe, probe_req, catalog),
            build=_prune(node.build, build_req, catalog))
    if isinstance(node, P.OrderBy):
        return dataclasses.replace(
            node, child=_prune(node.child, required | set(node.keys), catalog))
    if isinstance(node, P.Limit):
        return dataclasses.replace(node,
                                   child=_prune(node.child, required, catalog))
    if isinstance(node, (P.Exchange, P.Repartition)):
        return dataclasses.replace(
            node, child=_prune(node.child, required | set(node.keys), catalog))
    if isinstance(node, P.Broadcast):
        return dataclasses.replace(
            node, child=_prune(node.child, required, catalog))
    if isinstance(node, P.ScalarBroadcast):
        return dataclasses.replace(
            node,
            child=_prune(node.child, required - set(node.columns), catalog),
            scalar=_prune(node.scalar, set(node.columns), catalog))
    raise TypeError(f"cannot prune {type(node).__name__}")


# ---------------------------------------------------------------------------
# rule 3a: feedback-driven join orientation (build-side selection)
# ---------------------------------------------------------------------------

def reorder_joins(node: P.PlanNode, catalog,
                  config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Swap a join's build/probe orientation when observation says the
    probe side is the (much) smaller one — build-side selection from
    observed rather than declared sizes.

    A swap is taken only when it is provably safe: inner join, no hand-set
    'local' co-partitioning, disjoint column names across the sides, and
    the swapped orientation's build keys (the old probe keys) cover a
    declared unique set — the engine's ``max_matches`` contract silently
    truncates many-to-many overflow, so an unprovable orientation is never
    produced. The swapped join carries the old probe's columns as payload
    and is wrapped in a schema-restoring Project, so downstream operators
    (and the plan's output) are unchanged. No-op without a feedback store.
    """
    if config.feedback is None:
        return node
    new = replace_children(
        node, [reorder_joins(c, catalog, config) for c in node.children()])
    if (not isinstance(new, P.Join) or new.join_type != "inner"
            or new.distribution == "local"):
        return new
    obs_build = observed_rows(new.build, catalog, config)
    obs_probe = observed_rows(new.probe, catalog, config)
    if obs_build is None or obs_probe is None or 2 * obs_probe >= obs_build:
        return new
    probe_schema = infer_schema(new.probe, catalog)
    build_schema = infer_schema(new.build, catalog)
    if set(probe_schema) & set(build_schema):
        return new       # colliding names: payload would shadow columns
    swapped = P.Join(
        probe=new.build, build=new.probe,
        probe_keys=list(new.build_keys), build_keys=list(new.probe_keys),
        build_payload=list(probe_schema), join_type="inner")
    if not _build_side_unique(swapped, catalog):
        return new       # cannot prove the old probe side joins uniquely
    out_schema = infer_schema(new, catalog)
    return P.Project(swapped,
                     [(name, ColumnRef(name)) for name in out_schema])


# ---------------------------------------------------------------------------
# rule 3: join distribution selection
# ---------------------------------------------------------------------------

def choose_join_distribution(node: P.PlanNode, catalog,
                             config: OptimizerConfig = DEFAULT_CONFIG
                             ) -> P.PlanNode:
    """Broadcast small build sides, exchange (partition) large ones.

    Mirrors Presto's stats-based join-distribution decision: replicating a
    small build side avoids exchanging the (large) probe side; once the
    build side outgrows ``broadcast_row_limit`` rows, replicating it to all
    workers costs more than hash-exchanging both sides on the join keys.
    Hand-set ``'local'`` (already co-partitioned) is preserved. With a
    feedback store, the observed build cardinality from a prior run
    replaces the static bound — a build side whose declared bound forced a
    partitioned join can come back as a broadcast join once observation
    shows it small.
    """
    new = replace_children(
        node, [choose_join_distribution(c, catalog, config)
               for c in node.children()])
    if isinstance(new, P.Join) and new.distribution != "local":
        obs = observed_rows(new.build, catalog, config)
        build_rows = obs if obs is not None else row_bound(new.build, catalog)
        dist = ("partitioned" if build_rows > config.broadcast_row_limit
                else "broadcast")
        new = dataclasses.replace(new, distribution=dist)
    return new


# ---------------------------------------------------------------------------
# rule 4: capacity hints (max_groups / max_matches) from catalog stats
# ---------------------------------------------------------------------------

def derive_capacities(node: P.PlanNode, catalog,
                      config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Size static-capacity operators from sound cardinality upper bounds.

    * Aggregation/Distinct ``max_groups``: min(input row bound, product of
      finite key domains), with slack, rounded up to a power of two.
    * Join ``max_matches``: 1 when a single exact key provably hits a unique
      build key; a small collision-headroom constant when the (unique) key
      is hashed/composite; otherwise the hand-set value is kept -- the
      optimizer never *lowers* a capacity it cannot prove.

    With a feedback store, observed cardinalities tighten these further
    (only ever downward, and only under the table versions they were
    measured on):

    * ``max_groups`` from the aggregate's *own* observed output (that IS
      the group count), with ``feedback_slack`` headroom — often the
      difference between an in-budget pallas ``segmented_sum`` dispatch
      and the jnp fallback;
    * ``build_rows`` from the observed build cardinality — an undersized
      bound degrades to the jnp probe (the occupancy check fails), never
      to wrong results, so the exact observation is safe;
    * ``max_matches`` from the observed build-key multiplicity, but only
      for single exact int-like keys where equality has no hash
      collisions (the driver records nothing otherwise).
    """
    new = replace_children(
        node, [derive_capacities(c, catalog, config) for c in node.children()])

    if isinstance(new, (P.Aggregation, P.Distinct)):
        keys = new.group_keys if isinstance(new, P.Aggregation) else new.keys
        if not keys:
            return dataclasses.replace(new, max_groups=1)
        bound = row_bound(new.child, catalog)
        dom = _domain_bound(keys, infer_schema(new.child, catalog))
        if dom is not None:
            bound = min(bound, dom)
        candidates = []
        mg = _pow2(bound + config.group_slack)
        if mg <= MAX_CAPACITY:
            candidates.append(mg)
        obs = observed_rows(new, catalog, config)
        if obs is not None:
            # the aggregate's own observed output is its group count (a
            # W-fold over-count at worst for distributed partials — still
            # an upper bound on true groups)
            warm = _pow2(int(math.ceil(obs * config.feedback_slack))
                         + config.group_slack)
            if warm <= MAX_CAPACITY:
                candidates.append(warm)
        if not candidates:
            # no in-budget bound provable: never lower a hand-set capacity
            return new
        return dataclasses.replace(new, max_groups=min(candidates))

    if isinstance(new, P.Join):
        obs_build = observed_rows(new.build, catalog, config)
        if obs_build is not None and (new.build_rows is None
                                      or obs_build < new.build_rows):
            # tightening is sound: a bound smaller than the actual build
            # fails the pallas occupancy check and falls back to jnp
            new = dataclasses.replace(new, build_rows=max(int(obs_build), 1))
        elif new.build_rows is None:
            # build-side row bound: sizes the kernel backend's
            # open-addressing probe table (2x slots for load factor 1/2).
            # Hand-set hints are kept -- the planner never overrides a
            # bound the caller asserted.
            try:
                br = row_bound(new.build, catalog)
            except TypeError:
                br = None
            if br is not None and br <= MAX_CAPACITY:
                new = dataclasses.replace(new, build_rows=br)
        if new.join_type in ("left_semi", "left_anti"):
            return new
        try:
            br_static = row_bound(new.build, catalog)
        except TypeError:
            br_static = None       # exchange-wrapped subtree

        def clamp(mm: int) -> int:
            # a probe row cannot match more rows than the build side can
            # hold on any probe path (hash collisions included — only that
            # many rows exist), so the *static* build bound caps the
            # expansion capacity. Never clamp by the feedback-tightened
            # build_rows: its safety net (the occupancy-check fallback)
            # protects table sizing, not match capacity.
            if br_static is not None and mm > br_static:
                return max(int(br_static), 1)
            return mm

        if _build_side_unique(new, catalog):
            # exact unique key: exactly one candidate row per probe row.
            # hashed (composite/multi-column) unique key: matches beyond the
            # first are hash collisions, filtered by the verify pass -- a
            # small constant of headroom suffices.
            mm = 1 if _exact_key(new, catalog) else clamp(4)
            return dataclasses.replace(new, max_matches=mm)
        if config.feedback is not None and _exact_key(new, catalog):
            # uniqueness unprovable statically, but the driver measured the
            # exact-key build multiplicity (collision-free equality): it
            # bounds matches per probe row for the recorded table versions
            mm_obs = config.feedback.max_matches(
                config.feedback.key_for(new, catalog, config.num_workers))
            if mm_obs is not None and mm_obs < new.max_matches:
                return dataclasses.replace(new, max_matches=max(mm_obs, 1))
        if clamp(new.max_matches) != new.max_matches:
            return dataclasses.replace(new,
                                       max_matches=clamp(new.max_matches))
        # uniqueness unprovable: keep the hand-set capacity

    return new


# ---------------------------------------------------------------------------
# rule 5: physical exchange placement (distributed fragment plans)
# ---------------------------------------------------------------------------

def infer_distribution(node: P.PlanNode) -> str:
    """Planner-visible distribution of a node's output across workers.

    Mirrors the driver's runtime stream tracking: ``'partitioned'`` (each
    worker holds a disjoint row slice) or ``'replicated'`` (every worker
    holds all rows). Blocking global operators (OrderBy/Limit) and explicit
    Broadcast nodes replicate; sources and hash exchanges partition.
    """
    if isinstance(node, P.OrderBy) and node.local:
        return infer_distribution(node.child)
    if isinstance(node, (P.OrderBy, P.Limit, P.Broadcast)):
        return "replicated"
    if isinstance(node, (P.TableScan, P.InMemorySource, P.Exchange,
                         P.Repartition)):
        return "partitioned"
    if isinstance(node, P.Join):
        return infer_distribution(node.probe)
    kids = node.children()
    return infer_distribution(kids[0]) if kids else "partitioned"


def _shuffle_key_position(keys: Sequence[str],
                          schema: Dict[str, dt.DType]) -> Optional[int]:
    """Position of a single stand-in shuffle key, or None to keep all keys.

    Hash-partitioning on any non-empty key subset keeps equal full keys on
    one worker, so when the key list drags byte-matrix columns through the
    hash, a single int/date column can stand in for all of them. The
    subset is taken only when it actually removes byte hashing: without
    per-column cardinality stats a lone low-cardinality int key could skew
    the shuffle, so key lists that are already cheap to hash (ints, dicts)
    are kept whole — the full composite hash spreads at least as well.
    """
    if not any(schema[k].name == "bytes" for k in keys):
        return None
    return next((i for i, k in enumerate(keys)
                 if schema[k].name in ("int32", "date32")), None)


def _shuffle_keys(keys: Sequence[str],
                  schema: Dict[str, dt.DType]) -> List[str]:
    """Minimal co-location-preserving shuffle key subset (see
    ``_shuffle_key_position``)."""
    pos = _shuffle_key_position(keys, schema)
    return [keys[pos]] if pos is not None else list(keys)


def place_exchanges(node: P.PlanNode, catalog,
                    config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Lower distribution hints to explicit exchange nodes (physical plan).

    With ``config.num_workers > 1`` the stats-driven join-distribution
    decision stops being a hint the driver interprets and becomes plan
    structure: a 'partitioned' join gets ``Repartition`` nodes on both
    sides (hash-exchange on the join keys), a 'broadcast' join gets a
    ``Broadcast`` around its build side, auto Aggregations lower to
    partial -> Repartition/Broadcast -> final fragments, Distinct lowers to
    partial-dedup -> Repartition -> final-dedup, and the inputs of global
    operators (OrderBy/Limit, scalar subqueries) are broadcast. Exchanges
    are placed only where the child is provably still worker-partitioned
    (``infer_distribution``) — exchanging an already-replicated input would
    duplicate rows. The rule is idempotent: lowered joins are 'local',
    lowered aggregations carry explicit partial/final modes, and replicated
    inputs are never re-wrapped.
    """
    w = config.num_workers
    if w <= 1:
        return node
    new = replace_children(
        node, [place_exchanges(c, catalog, config) for c in node.children()])

    if isinstance(new, P.Join) and new.distribution != "local":
        probe_dist = infer_distribution(new.probe)
        if new.distribution == "broadcast" or probe_dist == "replicated":
            # replicate the build side; a replicated probe forces this shape
            # (repartitioning replicas would multiply rows W-fold)
            if infer_distribution(new.build) == "partitioned":
                return dataclasses.replace(
                    new, build=P.Broadcast(new.build, w), distribution="local")
            return dataclasses.replace(new, distribution="local")
        # both sides must shuffle on the same key positions; a single
        # cheap position stands in for byte-heavy composite keys (see
        # _shuffle_key_position for the skew rationale)
        pos = _shuffle_key_position(new.build_keys,
                                    infer_schema(new.build, catalog))
        probe_keys = ([new.probe_keys[pos]] if pos is not None
                      else list(new.probe_keys))
        build_keys = ([new.build_keys[pos]] if pos is not None
                      else list(new.build_keys))
        build = new.build
        if infer_distribution(build) == "partitioned":
            build = P.Repartition(build, build_keys)
        return dataclasses.replace(
            new, build=build,
            probe=P.Repartition(new.probe, probe_keys),
            distribution="local")

    if (isinstance(new, P.Aggregation) and new.mode == "auto"
            and infer_distribution(new.child) == "partitioned"):
        partial = dataclasses.replace(new, mode="partial")
        if new.group_keys:
            keys = _shuffle_keys(new.group_keys,
                                 infer_schema(new.child, catalog))
            shuffle = P.Repartition(partial, keys)
        else:
            shuffle = P.Broadcast(partial, w)
        return dataclasses.replace(new, child=shuffle, mode="final")

    if (isinstance(new, P.Distinct) and new.mode == "auto"
            and infer_distribution(new.child) == "partitioned"):
        partial = dataclasses.replace(new, mode="partial")
        keys = _shuffle_keys(new.keys, infer_schema(new.child, catalog))
        return dataclasses.replace(
            new, child=P.Repartition(partial, keys), mode="final")

    if isinstance(new, P.OrderBy) and not new.local:
        if infer_distribution(new.child) == "partitioned":
            child = new.child
            if new.limit is not None:
                # distributed top-N: per-worker local top-limit first, so
                # the gather moves W*limit candidate rows, not everything
                child = dataclasses.replace(new, local=True)
            return dataclasses.replace(new, child=P.Broadcast(child, w))
    elif isinstance(new, P.Limit):
        if infer_distribution(new.child) == "partitioned":
            return dataclasses.replace(new, child=P.Broadcast(new.child, w))

    if isinstance(new, P.ScalarBroadcast):
        if infer_distribution(new.scalar) == "partitioned":
            return dataclasses.replace(new, scalar=P.Broadcast(new.scalar, w))

    return new


# ---------------------------------------------------------------------------
# device-memory footprint estimation (admission control input)
# ---------------------------------------------------------------------------

def row_width(schema: Dict[str, dt.DType]) -> int:
    """Bytes per row of a schema (+1 byte/row for the validity mask)."""
    width = 1
    for d in schema.values():
        itemsize = int(d.np_dtype().itemsize)
        width += itemsize * d.width if d.name == "bytes" else itemsize
    return width


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    """Per-operator device-memory footprint breakdown for one plan.

    ``per_node`` lists ``(label, bytes)`` in plan-walk order; ``total`` is
    their sum (identical to ``estimate_memory``'s return value). The
    breakdown travels with admission decisions so a ``QueryRejected`` or an
    admit-with-spill slowdown is explainable from the message alone.
    """

    total: int
    per_node: tuple    # ((label, bytes), ...)

    def spill_cost(self, device_budget: int,
                   host_budget: int = 1 << 31) -> Dict[str, object]:
        """Bytes expected to cross each memory tier when this plan runs
        under ``device_budget``, plus a coarse slowdown multiplier.

        The excess over the device budget lands in pinned host buffers
        first and overflows to paged disk files past ``host_budget``.
        The slowdown model prices each spilled byte at the extra
        transfers it implies (device<->host ~2x the in-memory touch,
        disk ~8x) -- deliberately pessimistic, like the footprint model.
        """
        excess = max(0, self.total - max(device_budget, 1))
        host_bytes = min(excess, max(host_budget, 0))
        disk_bytes = excess - host_bytes
        denom = max(self.total, 1)
        slowdown = 1.0 + 2.0 * host_bytes / denom + 8.0 * disk_bytes / denom
        return {"excess_bytes": excess, "host_tier_bytes": host_bytes,
                "disk_tier_bytes": disk_bytes,
                "est_slowdown": round(slowdown, 2)}

    def describe(self, device_budget: Optional[int] = None,
                 host_budget: int = 1 << 31) -> str:
        """Human-readable footprint breakdown (one line per operator),
        optionally followed by the spill-cost estimate for a budget."""
        lines = [f"estimated footprint: {self.total} B"]
        for label, nbytes in self.per_node:
            lines.append(f"  {label}: {nbytes} B")
        if device_budget is not None:
            cost = self.spill_cost(device_budget, host_budget)
            lines.append(
                f"  spill cost @ budget {device_budget} B: "
                f"{cost['host_tier_bytes']} B host tier, "
                f"{cost['disk_tier_bytes']} B disk tier, "
                f"~{cost['est_slowdown']}x est. slowdown")
        return "\n".join(lines)


def estimate_memory(plan: P.PlanNode, catalog, num_workers: int = 1,
                    batch_rows: int = 8192, prefetch_depth: int = 2,
                    feedback=None) -> int:
    """Estimated peak device-memory footprint of executing ``plan``, in bytes.

    The scheduler admits queries against a device-memory budget using this
    estimate (the paper's coordinator multiplexes queries under the GPU
    memory budget). The model sums the device-resident state each node pins:

    * ``TableScan``     -- ``prefetch_depth + 1`` in-flight worker-stacked
                           morsels (the bounded prefetch queue plus the one
                           computing), capped at the table's total size.
    * ``Aggregation`` / ``Distinct``
                        -- ``max_groups`` static hash-table slots per worker
                           (doubled when the two-phase lowering materializes
                           partials for the exchange).
    * ``Join``          -- the materialized build side (replicated to every
                           worker under a broadcast distribution) plus one
                           ``max_matches``-expanded probe output batch.
    * ``OrderBy`` / ``Limit`` / ``Exchange``
                        -- the child materialized (these are blocking).

    Like the capacity hints, this is an upper-bound-flavored estimate: it
    never prices real work at zero, so admission errs toward queueing
    rather than oversubscribing device memory.

    With ``feedback`` (a ``core.feedback.FeedbackStore``), warm entries are
    priced from *observed* footprints: recorded cardinalities replace the
    declared row bounds for materialized intermediates, and zone-map skip
    fractions discount scans — so a warm query admits at what it actually
    pins, raising admission throughput.
    """
    return estimate_memory_breakdown(plan, catalog, num_workers, batch_rows,
                                     prefetch_depth, feedback).total


def estimate_memory_breakdown(plan: P.PlanNode, catalog,
                              num_workers: int = 1, batch_rows: int = 8192,
                              prefetch_depth: int = 2,
                              feedback=None) -> MemoryEstimate:
    """``estimate_memory`` with the per-operator breakdown retained
    (admission control attaches it to rejections and spill decisions)."""
    parts: List = []
    w = max(num_workers, 1)

    def observed(node: P.PlanNode) -> Optional[int]:
        if feedback is None:
            return None
        return feedback.rows(feedback.key_for(node, catalog, w))

    def bounded_rows(node: P.PlanNode) -> int:
        obs = observed(node)
        if obs is not None:
            return max(int(obs), 1)
        try:
            return min(row_bound(node, catalog), 1 << 40)
        except TypeError:
            return 1 << 20

    def visit(node: P.PlanNode) -> None:
        if isinstance(node, P.TableScan):
            width = row_width(infer_schema(node, catalog))
            in_flight = batch_rows * w * (prefetch_depth + 1)
            try:
                total_rows = min(row_bound(node, catalog), 1 << 40)
            except TypeError:
                total_rows = 1 << 20
            if feedback is not None:
                # the recorded zone-map skip fraction discounts chunks the
                # scan prunes before they ever reach device memory (the
                # observed *row* count is post-filter and would under-price
                # the in-flight morsels, so only the skip rate is used)
                sf = feedback.skip_fraction(
                    feedback.key_for(node, catalog, w))
                if sf:
                    total_rows = max(int(total_rows * (1.0 - sf)), 1)
            parts.append((f"TableScan({node.table})",
                          width * min(in_flight,
                                      max(total_rows, batch_rows))))
        elif isinstance(node, P.InMemorySource):
            width = row_width(infer_schema(node, catalog))
            parts.append(("InMemorySource", width * bounded_rows(node)))
        elif isinstance(node, (P.Aggregation, P.Distinct)):
            width = row_width(infer_schema(node, catalog))
            phases = 2 if (isinstance(node, P.Aggregation)
                           and node.mode in ("auto", "two_phase")
                           and w > 1) else 1
            key_cols = (node.group_keys if isinstance(node, P.Aggregation)
                        else node.keys)
            keys = ",".join(key_cols) if key_cols else "<global>"
            parts.append((f"{type(node).__name__}({keys})",
                          width * node.max_groups * w * phases))
        elif isinstance(node, P.Join):
            build_width = row_width(infer_schema(node.build, catalog))
            build_rows = bounded_rows(node.build)
            repl = w if node.distribution == "broadcast" else 1
            out_width = row_width(infer_schema(node, catalog))
            keys = ",".join(node.build_keys)
            parts.append((f"Join({keys}) build", build_width * build_rows
                          * repl))
            parts.append((f"Join({keys}) probe-out",
                          out_width * batch_rows
                          * max(node.max_matches, 1) * w))
        elif isinstance(node, (P.OrderBy, P.Limit, P.Exchange)):
            width = row_width(infer_schema(node.children()[0], catalog))
            parts.append((type(node).__name__,
                          width * bounded_rows(node.children()[0])))
        elif isinstance(node, P.Repartition):
            # blocking: child materialized into [W, W, cap] send layout,
            # then received into same-sized worker-stacked buffers
            width = row_width(infer_schema(node.child, catalog))
            parts.append(("Repartition",
                          2 * width * bounded_rows(node.child)))
        elif isinstance(node, P.Broadcast):
            # W-stacked replicas: every worker pins a copy of all rows,
            # plus the materialized input being replicated
            width = row_width(infer_schema(node.child, catalog))
            repl = max(node.num_workers, w)
            parts.append(("Broadcast",
                          width * bounded_rows(node.child) * (repl + 1)))
        for c in node.children():
            visit(c)

    visit(plan)
    return MemoryEstimate(total=sum(n for _, n in parts),
                          per_node=tuple(parts))


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------

DEFAULT_RULES = (push_filters, prune_columns, reorder_joins,
                 choose_join_distribution, derive_capacities, place_exchanges)


def optimize(plan: P.PlanNode, catalog, rules=DEFAULT_RULES,
             config: OptimizerConfig = DEFAULT_CONFIG) -> P.PlanNode:
    """Run the rule pipeline; the input tree is never mutated."""
    for rule in rules:
        plan = rule(plan, catalog, config)
    return plan


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------

def explain(plan: P.PlanNode, catalog=None) -> str:
    """Pretty-print a plan tree; adds row bounds when a catalog is given."""
    lines: List[str] = []
    _explain_into(plan, catalog, 0, lines)
    return "\n".join(lines)


def explain_before_after(plan: P.PlanNode, catalog,
                         config: OptimizerConfig = DEFAULT_CONFIG) -> str:
    """Plan tree before and after the optimizer pipeline."""
    return (f"== logical plan ==\n{explain(plan, catalog)}\n"
            f"== optimized plan ==\n"
            f"{explain(optimize(plan, catalog, config=config), catalog)}")


def _explain_into(node: P.PlanNode, catalog, depth: int,
                  lines: List[str]) -> None:
    suffix = ""
    if catalog is not None:
        try:
            suffix = f"  [<= {row_bound(node, catalog)} rows]"
        except TypeError:
            pass
        if isinstance(node, P.TableScan):
            suffix += _scan_storage_note(node, catalog)
    lines.append("  " * depth + _describe(node) + suffix)
    for c in node.children():
        _explain_into(c, catalog, depth + 1, lines)


def _scan_storage_note(node: P.TableScan, catalog) -> str:
    """Storage-side annotation: chunk count and whether the pushed-down
    predicate is eligible for zone-map data skipping on this source."""
    src = catalog.get(node.table)
    chunks = getattr(src, "num_chunks", None)
    if chunks is None:
        return ""
    note = f"  [chunks={chunks}"
    if getattr(src, "skip_with_stats", False) and node.filter is not None:
        note += ", zone-map skip"
    return note + "]"


def _describe(node: P.PlanNode) -> str:
    if isinstance(node, P.TableScan):
        cols = "*" if node.columns is None else ", ".join(node.columns)
        f = f", filter={node.filter}" if node.filter is not None else ""
        return f"TableScan({node.table}: {cols}{f})"
    if isinstance(node, P.InMemorySource):
        return f"InMemorySource({node.name}: {', '.join(node.schema)})"
    if isinstance(node, P.Filter):
        return f"Filter({node.predicate})"
    if isinstance(node, P.Project):
        parts = [name if isinstance(e, ColumnRef) and e.name == name
                 else f"{name}={e}" for name, e in node.projections]
        return f"Project({', '.join(parts)})"
    if isinstance(node, P.Aggregation):
        aggs = ", ".join(f"{n}={k}({c})" if c else f"{n}={k}()"
                         for n, k, c in node.aggs)
        keys = ", ".join(node.group_keys)
        return (f"Aggregation(keys=[{keys}], aggs=[{aggs}], "
                f"max_groups={node.max_groups}, mode={node.mode})")
    if isinstance(node, P.Distinct):
        return (f"Distinct(keys=[{', '.join(node.keys)}], "
                f"max_groups={node.max_groups}, mode={node.mode})")
    if isinstance(node, P.Join):
        pay = (f", payload=[{', '.join(node.build_payload)}]"
               if node.build_payload else "")
        return (f"Join({node.join_type}, {list(node.probe_keys)} = "
                f"{list(node.build_keys)}{pay}, "
                f"distribution={node.distribution}, "
                f"max_matches={node.max_matches})")
    if isinstance(node, P.OrderBy):
        desc = node.descending or [False] * len(node.keys)
        keys = ", ".join(k + (" desc" if d else "")
                         for k, d in zip(node.keys, desc))
        lim = f", limit={node.limit}" if node.limit is not None else ""
        loc = ", local" if node.local else ""
        return f"OrderBy(keys=[{keys}]{lim}{loc})"
    if isinstance(node, P.Limit):
        return f"Limit({node.n})"
    if isinstance(node, P.ScalarBroadcast):
        return f"ScalarBroadcast(columns=[{', '.join(node.columns)}])"
    if isinstance(node, P.Exchange):
        return f"Exchange(keys=[{', '.join(node.keys)}])"
    if isinstance(node, P.Repartition):
        return f"Repartition(keys=[{', '.join(node.keys)}])"
    if isinstance(node, P.Broadcast):
        return f"Broadcast(num_workers={node.num_workers})"
    return type(node).__name__
