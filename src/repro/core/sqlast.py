"""SQL tokenizer, recursive-descent parser and AST for the SQL frontend.

This is the engine's *bundled* parser: a dependency-free implementation of
the ANSI-ish SELECT subset the lowering layer (``core.sql``) can execute —
SELECT [DISTINCT] / FROM (comma and explicit INNER JOIN) / WHERE / GROUP BY
/ HAVING / ORDER BY / LIMIT, WITH-CTEs, derived tables, scalar & IN/EXISTS
subqueries, CASE, EXTRACT, SUBSTRING, LIKE, BETWEEN, IN, date + interval
literals. When the optional ``sqlglot`` dependency is installed (the
``[sql]`` extra), ``core.sql`` first normalizes other dialects down to this
subset; the bundled parser is always the one producing the AST.

Two error types, both loud:

* ``SqlParseError`` — the text is not valid SQL for this grammar (carries
  the offending token and position).
* ``SqlUnsupportedError`` — the construct parsed fine but the engine cannot
  execute it (names the construct, e.g. ``UNION``, ``LEFT OUTER JOIN``,
  window functions). Raised here for syntax-level constructs and by
  ``core.sql`` for semantic ones.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


class SqlParseError(ValueError):
    """The SQL text does not parse under the supported grammar."""


class SqlUnsupportedError(ValueError):
    """Valid SQL, but a construct the engine cannot lower/execute.

    The message always names the offending construct so failures are
    diagnosable from the exception alone (never silently wrong results).
    """


# ---------------------------------------------------------------------------
# tokens
# ---------------------------------------------------------------------------

_KEYWORDS = {
    "SELECT", "DISTINCT", "FROM", "WHERE", "GROUP", "BY", "HAVING", "ORDER",
    "LIMIT", "AS", "AND", "OR", "NOT", "IN", "EXISTS", "BETWEEN", "LIKE",
    "IS", "NULL", "CASE", "WHEN", "THEN", "ELSE", "END", "JOIN", "INNER",
    "LEFT", "RIGHT", "FULL", "OUTER", "CROSS", "ON", "ASC", "DESC", "DATE",
    "INTERVAL", "EXTRACT", "SUBSTRING", "FOR", "WITH", "UNION", "EXCEPT",
    "INTERSECT", "ALL", "ANY", "SOME", "OVER", "CAST", "TRUE", "FALSE",
    "OFFSET", "USING", "NATURAL", "VALUES",
}

_TWO_CHAR_OPS = ("<=", ">=", "<>", "!=", "||")
_ONE_CHAR_OPS = "+-*/(),.;=<>"


@dataclasses.dataclass
class Token:
    """One lexed token (kind, text, source offset)."""
    kind: str          # kw | ident | int | float | str | op | end
    value: str
    pos: int           # character offset (error messages)


def tokenize(sql: str) -> List[Token]:
    """Lex SQL text into tokens; raises ``SqlParseError`` on bad input."""
    out: List[Token] = []
    i, n = 0, len(sql)
    while i < n:
        c = sql[i]
        if c.isspace():
            i += 1
            continue
        if sql.startswith("--", i):                      # line comment
            j = sql.find("\n", i)
            i = n if j < 0 else j + 1
            continue
        if c == "'":                                     # string ('' escapes)
            j, buf = i + 1, []
            while True:
                if j >= n:
                    raise SqlParseError(
                        f"unterminated string literal at position {i}")
                if sql[j] == "'":
                    if j + 1 < n and sql[j + 1] == "'":
                        buf.append("'")
                        j += 2
                        continue
                    break
                buf.append(sql[j])
                j += 1
            out.append(Token("str", "".join(buf), i))
            i = j + 1
            continue
        if c.isdigit() or (c == "." and i + 1 < n and sql[i + 1].isdigit()):
            j = i
            seen_dot = False
            while j < n and (sql[j].isdigit() or (sql[j] == "." and not seen_dot)):
                seen_dot = seen_dot or sql[j] == "."
                j += 1
            text = sql[i:j]
            out.append(Token("float" if "." in text else "int", text, i))
            i = j
            continue
        if c.isalpha() or c == "_":
            j = i
            while j < n and (sql[j].isalnum() or sql[j] == "_"):
                j += 1
            word = sql[i:j]
            upper = word.upper()
            if upper in _KEYWORDS:
                out.append(Token("kw", upper, i))
            else:
                out.append(Token("ident", word.lower(), i))
            i = j
            continue
        two = sql[i:i + 2]
        if two in _TWO_CHAR_OPS:
            out.append(Token("op", two, i))
            i += 2
            continue
        if c in _ONE_CHAR_OPS:
            out.append(Token("op", c, i))
            i += 1
            continue
        raise SqlParseError(f"unexpected character {c!r} at position {i}")
    out.append(Token("end", "", n))
    return out


# ---------------------------------------------------------------------------
# expression AST
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SqlExpr:
    """Base class for parsed SQL expressions."""


@dataclasses.dataclass
class SCol(SqlExpr):
    """Column reference, optionally qualified: ``n1.n_name``."""
    qualifier: Optional[str]
    name: str


@dataclasses.dataclass
class SLit(SqlExpr):
    """Literal; ``kind`` in int | float | str | date | bool."""
    value: object
    kind: str


@dataclasses.dataclass
class SInterval(SqlExpr):
    """``INTERVAL 'n' unit`` — only valid added to / subtracted from dates."""
    n: int
    unit: str          # year | month | day


@dataclasses.dataclass
class SBin(SqlExpr):
    """Binary operator; op in and/or/add/sub/mul/div/eq/ne/lt/le/gt/ge."""
    op: str
    lhs: SqlExpr
    rhs: SqlExpr


@dataclasses.dataclass
class SNot(SqlExpr):
    """Logical negation: ``NOT expr``."""
    operand: SqlExpr


@dataclasses.dataclass
class SNeg(SqlExpr):
    """Arithmetic negation: ``-expr``."""
    operand: SqlExpr


@dataclasses.dataclass
class SFunc(SqlExpr):
    """Function call (aggregates and scalar functions)."""
    name: str                      # lowercased
    args: List[SqlExpr]
    distinct: bool = False
    star: bool = False             # count(*)


@dataclasses.dataclass
class SExtract(SqlExpr):
    """``EXTRACT(field FROM expr)``."""
    field: str                     # lowercased, e.g. 'year'
    operand: SqlExpr


@dataclasses.dataclass
class SSubstr(SqlExpr):
    """``SUBSTRING(x FROM a FOR b)`` / ``SUBSTRING(x, a, b)``."""
    operand: SqlExpr
    start: int
    length: int


@dataclasses.dataclass
class SCase(SqlExpr):
    """Searched CASE: ``CASE WHEN c THEN v ... [ELSE d] END``."""
    whens: List[Tuple[SqlExpr, SqlExpr]]
    default: Optional[SqlExpr]


@dataclasses.dataclass
class SIn(SqlExpr):
    """``x IN (literal, ...)``."""
    operand: SqlExpr
    values: List[SLit]
    negated: bool = False


@dataclasses.dataclass
class SInSelect(SqlExpr):
    """``x [NOT] IN (SELECT ...)``."""
    operand: SqlExpr
    select: "Select"
    negated: bool = False


@dataclasses.dataclass
class SExists(SqlExpr):
    """``[NOT] EXISTS (SELECT ...)``."""
    select: "Select"
    negated: bool = False


@dataclasses.dataclass
class SBetween(SqlExpr):
    """``expr BETWEEN lo AND hi`` (inclusive bounds)."""
    operand: SqlExpr
    lo: SqlExpr
    hi: SqlExpr


@dataclasses.dataclass
class SLike(SqlExpr):
    """``expr [NOT] LIKE 'pattern'`` (``%`` wildcards only)."""
    operand: SqlExpr
    pattern: str
    negated: bool = False


@dataclasses.dataclass
class SScalar(SqlExpr):
    """Scalar subquery: ``(SELECT agg(...) ...)`` used as a value."""
    select: "Select"


@dataclasses.dataclass
class SStar(SqlExpr):
    """``*`` / ``alias.*`` in a select list."""
    qualifier: Optional[str] = None


# ---------------------------------------------------------------------------
# statement AST
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SelectItem:
    """One SELECT-list entry: expression plus optional ``AS`` alias."""
    expr: SqlExpr
    alias: Optional[str]


@dataclasses.dataclass
class TableRef:
    """Base-table (or CTE) reference in FROM."""
    name: str
    alias: str                     # defaults to the table name


@dataclasses.dataclass
class SubqueryRef:
    """Derived table: ``( SELECT ... ) alias``."""
    select: "Select"
    alias: str


@dataclasses.dataclass
class Select:
    """One parsed SELECT statement (plus its WITH-bound CTEs)."""
    items: List[SelectItem]
    from_items: List[object]                 # TableRef | SubqueryRef
    distinct: bool = False
    # ON-conjuncts from explicit JOIN syntax; merged with WHERE by lowering
    join_conditions: List[SqlExpr] = dataclasses.field(default_factory=list)
    where: Optional[SqlExpr] = None
    group_by: List[SqlExpr] = dataclasses.field(default_factory=list)
    having: Optional[SqlExpr] = None
    order_by: List[Tuple[SqlExpr, bool]] = dataclasses.field(
        default_factory=list)               # (expr, descending)
    limit: Optional[int] = None
    ctes: List[Tuple[str, "Select"]] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

_AGG_FUNCS = {"sum", "avg", "min", "max", "count"}
_CMP_OPS = {"=": "eq", "<>": "ne", "!=": "ne", "<": "lt", "<=": "le",
            ">": "gt", ">=": "ge"}


class _Parser:
    def __init__(self, tokens: List[Token], sql: str):
        self.toks = tokens
        self.sql = sql
        self.i = 0

    # -- token helpers ------------------------------------------------------
    def peek(self, ahead: int = 0) -> Token:
        return self.toks[min(self.i + ahead, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.i]
        if t.kind != "end":
            self.i += 1
        return t

    def at_kw(self, *kws: str) -> bool:
        t = self.peek()
        return t.kind == "kw" and t.value in kws

    def at_op(self, *ops: str) -> bool:
        t = self.peek()
        return t.kind == "op" and t.value in ops

    def accept_kw(self, *kws: str) -> bool:
        if self.at_kw(*kws):
            self.next()
            return True
        return False

    def accept_op(self, *ops: str) -> bool:
        if self.at_op(*ops):
            self.next()
            return True
        return False

    def expect_kw(self, kw: str) -> None:
        t = self.next()
        if t.kind != "kw" or t.value != kw:
            raise SqlParseError(
                f"expected {kw} at position {t.pos}, got {t.value!r}")

    def expect_op(self, op: str) -> None:
        t = self.next()
        if t.kind != "op" or t.value != op:
            raise SqlParseError(
                f"expected {op!r} at position {t.pos}, got {t.value!r}")

    def expect_ident(self, what: str) -> str:
        t = self.next()
        if t.kind == "ident":
            return t.value
        raise SqlParseError(
            f"expected {what} at position {t.pos}, got {t.value!r}")

    # -- statement ----------------------------------------------------------
    def parse_statement(self) -> Select:
        ctes: List[Tuple[str, Select]] = []
        if self.accept_kw("WITH"):
            while True:
                name = self.expect_ident("CTE name")
                self.expect_kw("AS")
                self.expect_op("(")
                ctes.append((name, self.parse_select()))
                self.expect_op(")")
                if not self.accept_op(","):
                    break
        sel = self.parse_select()
        sel.ctes = ctes + sel.ctes
        self.accept_op(";")
        t = self.peek()
        if t.kind != "end":
            raise SqlParseError(
                f"trailing input at position {t.pos}: {t.value!r}")
        return sel

    def parse_select(self) -> Select:
        self.expect_kw("SELECT")
        distinct = bool(self.accept_kw("DISTINCT"))
        self.accept_kw("ALL")
        items = [self.parse_select_item()]
        while self.accept_op(","):
            items.append(self.parse_select_item())
        sel = Select(items=items, from_items=[], distinct=distinct)
        if self.accept_kw("FROM"):
            self.parse_from(sel)
        if self.accept_kw("WHERE"):
            sel.where = self.parse_expr()
        if self.accept_kw("GROUP"):
            self.expect_kw("BY")
            sel.group_by.append(self.parse_expr())
            while self.accept_op(","):
                sel.group_by.append(self.parse_expr())
        if self.accept_kw("HAVING"):
            sel.having = self.parse_expr()
        if self.at_kw("UNION", "EXCEPT", "INTERSECT"):
            raise SqlUnsupportedError(
                f"set operation {self.peek().value} is not supported")
        if self.accept_kw("ORDER"):
            self.expect_kw("BY")
            while True:
                e = self.parse_expr()
                desc = False
                if self.accept_kw("DESC"):
                    desc = True
                else:
                    self.accept_kw("ASC")
                sel.order_by.append((e, desc))
                if not self.accept_op(","):
                    break
        if self.accept_kw("LIMIT"):
            t = self.next()
            if t.kind != "int":
                raise SqlParseError(
                    f"LIMIT expects an integer at position {t.pos}")
            sel.limit = int(t.value)
        if self.at_kw("OFFSET"):
            raise SqlUnsupportedError("OFFSET is not supported")
        return sel

    def parse_from(self, sel: Select) -> None:
        sel.from_items.append(self.parse_from_item())
        while True:
            if self.accept_op(","):
                sel.from_items.append(self.parse_from_item())
                continue
            if self.at_kw("LEFT", "RIGHT", "FULL", "CROSS", "NATURAL"):
                raise SqlUnsupportedError(
                    f"{self.peek().value} JOIN is not supported "
                    f"(only INNER equi-joins)")
            if self.at_kw("JOIN", "INNER"):
                self.accept_kw("INNER")
                self.expect_kw("JOIN")
                sel.from_items.append(self.parse_from_item())
                if self.at_kw("USING"):
                    raise SqlUnsupportedError(
                        "JOIN ... USING is not supported (use ON)")
                self.expect_kw("ON")
                sel.join_conditions.append(self.parse_expr())
                continue
            break

    def parse_from_item(self):
        if self.accept_op("("):
            sub = self.parse_select()
            self.expect_op(")")
            self.accept_kw("AS")
            alias = self.expect_ident("derived-table alias")
            return SubqueryRef(sub, alias)
        name = self.expect_ident("table name")
        alias = name
        if self.accept_kw("AS"):
            alias = self.expect_ident("table alias")
        elif self.peek().kind == "ident":
            alias = self.next().value
        return TableRef(name, alias)

    def parse_select_item(self) -> SelectItem:
        if self.at_op("*"):
            self.next()
            return SelectItem(SStar(), None)
        if (self.peek().kind == "ident" and self.peek(1).kind == "op"
                and self.peek(1).value == "."
                and self.peek(2).kind == "op" and self.peek(2).value == "*"):
            qual = self.next().value
            self.next()
            self.next()
            return SelectItem(SStar(qual), None)
        e = self.parse_expr()
        alias = None
        if self.accept_kw("AS"):
            alias = self.expect_ident("column alias")
        elif self.peek().kind == "ident":
            alias = self.next().value
        return SelectItem(e, alias)

    # -- expressions --------------------------------------------------------
    def parse_expr(self) -> SqlExpr:
        return self.parse_or()

    def parse_or(self) -> SqlExpr:
        e = self.parse_and()
        while self.accept_kw("OR"):
            e = SBin("or", e, self.parse_and())
        return e

    def parse_and(self) -> SqlExpr:
        e = self.parse_not()
        while self.accept_kw("AND"):
            e = SBin("and", e, self.parse_not())
        return e

    def parse_not(self) -> SqlExpr:
        if self.at_kw("NOT") and not (
                self.peek(1).kind == "kw" and self.peek(1).value == "EXISTS"):
            self.next()
            return SNot(self.parse_not())
        return self.parse_comparison()

    def parse_comparison(self) -> SqlExpr:
        if self.at_kw("EXISTS") or (
                self.at_kw("NOT") and self.peek(1).kind == "kw"
                and self.peek(1).value == "EXISTS"):
            negated = self.accept_kw("NOT")
            self.expect_kw("EXISTS")
            self.expect_op("(")
            sub = self.parse_select()
            self.expect_op(")")
            return SExists(sub, negated)
        e = self.parse_additive()
        # postfix predicates: IN / BETWEEN / LIKE / IS [NOT] NULL
        negated = False
        if self.at_kw("NOT") and self.peek(1).kind == "kw" \
                and self.peek(1).value in ("IN", "BETWEEN", "LIKE"):
            self.next()
            negated = True
        if self.accept_kw("IN"):
            self.expect_op("(")
            if self.at_kw("SELECT", "WITH"):
                sub = self.parse_select()
                self.expect_op(")")
                return SInSelect(e, sub, negated)
            values = [self.parse_literal("IN list")]
            while self.accept_op(","):
                values.append(self.parse_literal("IN list"))
            self.expect_op(")")
            out: SqlExpr = SIn(e, values)
            return SNot(out) if negated else out
        if self.accept_kw("BETWEEN"):
            lo = self.parse_additive()
            self.expect_kw("AND")
            hi = self.parse_additive()
            out = SBetween(e, lo, hi)
            return SNot(out) if negated else out
        if self.accept_kw("LIKE"):
            t = self.next()
            if t.kind != "str":
                raise SqlParseError(
                    f"LIKE expects a string pattern at position {t.pos}")
            return SLike(e, t.value, negated)
        if self.accept_kw("IS"):
            raise SqlUnsupportedError(
                "IS [NOT] NULL is not supported (the engine has no NULLs)")
        for op_text, op in _CMP_OPS.items():
            if self.at_op(op_text):
                self.next()
                if self.at_kw("ANY", "SOME", "ALL"):
                    raise SqlUnsupportedError(
                        f"quantified comparison {self.peek().value} "
                        f"is not supported")
                return SBin(op, e, self.parse_additive())
        return e

    def parse_additive(self) -> SqlExpr:
        e = self.parse_multiplicative()
        while True:
            if self.accept_op("+"):
                e = SBin("add", e, self.parse_multiplicative())
            elif self.accept_op("-"):
                e = SBin("sub", e, self.parse_multiplicative())
            elif self.at_op("||"):
                raise SqlUnsupportedError(
                    "string concatenation || is not supported")
            else:
                return e

    def parse_multiplicative(self) -> SqlExpr:
        e = self.parse_unary()
        while True:
            if self.accept_op("*"):
                e = SBin("mul", e, self.parse_unary())
            elif self.accept_op("/"):
                e = SBin("div", e, self.parse_unary())
            else:
                return e

    def parse_unary(self) -> SqlExpr:
        if self.accept_op("-"):
            e = self.parse_unary()
            if isinstance(e, SLit) and e.kind in ("int", "float"):
                return SLit(-e.value, e.kind)
            return SNeg(e)
        self.accept_op("+")
        return self.parse_primary()

    def parse_literal(self, ctx: str) -> SLit:
        t = self.peek()
        if t.kind == "int":
            self.next()
            return SLit(int(t.value), "int")
        if t.kind == "float":
            self.next()
            return SLit(float(t.value), "float")
        if t.kind == "str":
            self.next()
            return SLit(t.value, "str")
        if self.accept_kw("DATE"):
            s = self.next()
            if s.kind != "str":
                raise SqlParseError(
                    f"DATE expects a 'YYYY-MM-DD' string at position {s.pos}")
            return SLit(s.value, "date")
        if self.accept_op("-"):
            lit = self.parse_literal(ctx)
            if lit.kind not in ("int", "float"):
                raise SqlParseError(f"cannot negate {lit.kind} in {ctx}")
            return SLit(-lit.value, lit.kind)
        raise SqlParseError(
            f"{ctx}: expected a literal at position {t.pos}, got {t.value!r}")

    def parse_primary(self) -> SqlExpr:
        t = self.peek()
        if t.kind in ("int", "float", "str"):
            return self.parse_literal("expression")
        if self.accept_kw("TRUE"):
            return SLit(True, "bool")
        if self.accept_kw("FALSE"):
            return SLit(False, "bool")
        if self.at_kw("NULL"):
            raise SqlUnsupportedError(
                "NULL literal is not supported (the engine has no NULLs)")
        if self.at_kw("DATE"):
            return self.parse_literal("expression")
        if self.accept_kw("INTERVAL"):
            s = self.next()
            if s.kind != "str":
                raise SqlParseError(
                    f"INTERVAL expects a quoted count at position {s.pos}")
            unit = self.expect_ident("interval unit").lower().rstrip("s")
            if unit not in ("year", "month", "day"):
                raise SqlUnsupportedError(
                    f"INTERVAL unit '{unit}' is not supported")
            return SInterval(int(s.value), unit)
        if self.accept_kw("CASE"):
            if not self.at_kw("WHEN"):
                raise SqlUnsupportedError(
                    "simple CASE <expr> WHEN is not supported "
                    "(use searched CASE WHEN <cond>)")
            whens = []
            while self.accept_kw("WHEN"):
                cond = self.parse_expr()
                self.expect_kw("THEN")
                whens.append((cond, self.parse_expr()))
            default = self.parse_expr() if self.accept_kw("ELSE") else None
            self.expect_kw("END")
            return SCase(whens, default)
        if self.accept_kw("EXTRACT"):
            self.expect_op("(")
            field = self.expect_ident("EXTRACT field").lower()
            self.expect_kw("FROM")
            operand = self.parse_expr()
            self.expect_op(")")
            return SExtract(field, operand)
        if self.accept_kw("SUBSTRING"):
            self.expect_op("(")
            operand = self.parse_expr()
            if not self.accept_kw("FROM"):
                self.expect_op(",")
            start = self._int_arg("SUBSTRING start")
            if not self.accept_kw("FOR"):
                self.expect_op(",")
            length = self._int_arg("SUBSTRING length")
            self.expect_op(")")
            return SSubstr(operand, start, length)
        if self.at_kw("CAST"):
            raise SqlUnsupportedError("CAST is not supported")
        if self.accept_op("("):
            if self.at_kw("SELECT", "WITH"):
                sub = self.parse_select()
                self.expect_op(")")
                return SScalar(sub)
            e = self.parse_expr()
            self.expect_op(")")
            return e
        if t.kind == "ident":
            # function call?
            if self.peek(1).kind == "op" and self.peek(1).value == "(":
                name = self.next().value
                self.next()                               # '('
                distinct = bool(self.accept_kw("DISTINCT"))
                star = False
                args: List[SqlExpr] = []
                if self.accept_op("*"):
                    star = True
                elif not self.at_op(")"):
                    args.append(self.parse_expr())
                    while self.accept_op(","):
                        args.append(self.parse_expr())
                self.expect_op(")")
                if self.at_kw("OVER"):
                    raise SqlUnsupportedError(
                        f"window function {name}() OVER is not supported")
                return SFunc(name, args, distinct=distinct, star=star)
            name = self.next().value
            if self.accept_op("."):
                col = self.next()
                if col.kind == "op" and col.value == "*":
                    return SStar(name)
                if col.kind not in ("ident", "kw"):
                    raise SqlParseError(
                        f"expected column after '{name}.' at position "
                        f"{col.pos}")
                return SCol(name, col.value.lower())
            return SCol(None, name)
        raise SqlParseError(
            f"unexpected token {t.value!r} at position {t.pos}")

    def _int_arg(self, ctx: str) -> int:
        t = self.next()
        if t.kind != "int":
            raise SqlParseError(
                f"{ctx} expects an integer at position {t.pos}")
        return int(t.value)


def parse(sql: str) -> Select:
    """Parse one SELECT statement into the AST.

    Raises ``SqlParseError`` for invalid syntax and ``SqlUnsupportedError``
    for recognized-but-unsupported constructs (set operations, outer joins,
    window functions, ...)::

        >>> sel = parse("SELECT a, sum(b) AS s FROM t GROUP BY a")
        >>> [i.alias for i in sel.items]
        [None, 's']
    """
    return _Parser(tokenize(sql), sql).parse_statement()


# ---------------------------------------------------------------------------
# AST walking helpers (used by the lowering layer)
# ---------------------------------------------------------------------------

def children(e: SqlExpr) -> Sequence[SqlExpr]:
    """Direct subexpressions of ``e`` (subquery bodies are NOT descended)."""
    if isinstance(e, SBin):
        return (e.lhs, e.rhs)
    if isinstance(e, (SNot, SNeg)):
        return (e.operand,)
    if isinstance(e, SFunc):
        return tuple(e.args)
    if isinstance(e, (SExtract, SSubstr)):
        return (e.operand,)
    if isinstance(e, SCase):
        out = []
        for c, v in e.whens:
            out.extend((c, v))
        if e.default is not None:
            out.append(e.default)
        return tuple(out)
    if isinstance(e, SIn):
        return (e.operand,)
    if isinstance(e, SInSelect):
        return (e.operand,)
    if isinstance(e, SBetween):
        return (e.operand, e.lo, e.hi)
    if isinstance(e, SLike):
        return (e.operand,)
    return ()


def walk(e: SqlExpr):
    """Yield ``e`` and every descendant (subquery bodies not descended)."""
    yield e
    for c in children(e):
        yield from walk(c)


def conjuncts(e: Optional[SqlExpr]) -> List[SqlExpr]:
    """Split a predicate on top-level ANDs."""
    if e is None:
        return []
    if isinstance(e, SBin) and e.op == "and":
        return conjuncts(e.lhs) + conjuncts(e.rhs)
    return [e]


def contains_aggregate(e: SqlExpr) -> bool:
    """True if ``e`` contains an aggregate function call (not in subqueries)."""
    return any(isinstance(x, SFunc) and x.name in _AGG_FUNCS
               for x in walk(e))


def contains_subquery(e: SqlExpr) -> bool:
    """True if ``e`` contains an IN/EXISTS/scalar subquery node."""
    return any(isinstance(x, (SInSelect, SExists, SScalar))
               for x in walk(e))
