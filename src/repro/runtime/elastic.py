"""Elastic scaling: reshard a training state across different mesh shapes.

Checkpoints carry global host arrays (see checkpoint/ckpt.py), so scaling
from N to M chips is: build the new mesh, derive the new sharding tree from
the same policy, restore. This module packages that as one call and also
supports in-memory resharding (no disk) for planned rescales.
"""

from __future__ import annotations

import jax

from ..launch.mesh import axes_of
from ..models import sharding as shp


def reshard_state(state, new_mesh):
    """Re-place every leaf of ``state`` for ``new_mesh`` (in-memory path)."""
    axes = axes_of(new_mesh)
    shardings = shp.params_shardings(state, axes, new_mesh)
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    return jax.tree.map(lambda arr, sh: jax.device_put(arr, sh),
                        host, shardings)


def restore_for_mesh(ckpt_dir: str, template, new_mesh):
    """Disk path: newest checkpoint restored directly onto ``new_mesh``."""
    from ..checkpoint.ckpt import restore_latest

    axes = axes_of(new_mesh)
    shardings = shp.params_shardings(template, axes, new_mesh)
    return restore_latest(ckpt_dir, template, shardings)
