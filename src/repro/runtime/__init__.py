from .fault import (FailureInjector, StragglerMonitor, TrainLoop,  # noqa: F401
                    WorkerFailure)
