"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler detection with data reassignment.

On a real 1000+-chip fleet, failures arrive as ICI/RPC errors from the
coordinator; here ``FailureInjector`` raises them deterministically so the
recovery path (restore latest checkpoint -> rebuild pipeline at the exact
step -> continue) is tested end to end. Recovery is bitwise deterministic
because both the data pipeline position and the optimizer state are pure
functions of the checkpointed step.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..checkpoint.ckpt import CheckpointManager, restore_latest


class WorkerFailure(RuntimeError):
    """A (simulated) worker/chip failure surfaced during a step."""


class FailureInjector:
    """Raises WorkerFailure at the given global steps, once each."""

    def __init__(self, fail_at_steps: List[int] = ()):
        self.remaining = set(fail_at_steps)

    def check(self, step: int):
        if step in self.remaining:
            self.remaining.discard(step)
            raise WorkerFailure(f"injected failure at step {step}")


class StragglerMonitor:
    """Flags workers whose step time exceeds ``factor`` x the fleet median.

    Mitigation at this layer is *data reassignment*: a flagged worker's
    input shard is redistributed to healthy workers (the pipeline's window
    order makes this a pure re-partitioning). The monitor records events so
    the benchmark/report layer can show detection latency.
    """

    def __init__(self, num_workers: int, factor: float = 3.0, window: int = 8):
        self.num_workers = num_workers
        self.factor = factor
        self.window = window
        self.history: Dict[int, List[float]] = {w: [] for w in range(num_workers)}
        self.flagged: List[int] = []

    def record(self, worker: int, seconds: float):
        h = self.history[worker]
        h.append(seconds)
        if len(h) > self.window:
            h.pop(0)

    def detect(self) -> List[int]:
        med = np.median([np.mean(h) for h in self.history.values() if h])
        out = []
        for w, h in self.history.items():
            if h and np.mean(h) > self.factor * med and w not in self.flagged:
                out.append(w)
                self.flagged.append(w)
        return out

    def healthy_workers(self) -> List[int]:
        return [w for w in range(self.num_workers) if w not in self.flagged]


class TrainLoop:
    """Checkpoint-and-restart training driver.

    run() executes ``num_steps`` steps; WorkerFailure triggers restore from
    the newest checkpoint and a clean continue. Any step not covered by a
    checkpoint is recomputed — standard restart semantics.
    """

    def __init__(self, train_step: Callable, init_state, pipeline_factory,
                 ckpt_dir: str, ckpt_every: int = 10,
                 injector: Optional[FailureInjector] = None,
                 max_restarts: int = 10, state_shardings=None):
        self.train_step = train_step
        self.init_state = init_state
        self.pipeline_factory = pipeline_factory   # (start_step) -> iterator
        self.ckpt = CheckpointManager(ckpt_dir, keep=2)
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector()
        self.max_restarts = max_restarts
        self.state_shardings = state_shardings
        self.restarts = 0
        self.metrics: List[dict] = []

    def _bootstrap(self):
        step, state, extra = restore_latest(self.ckpt.dir, self.init_state,
                                            self.state_shardings)
        if state is None:
            return 0, self.init_state
        return extra["next_step"], state

    def run(self, num_steps: int):
        while True:
            start_step, state = self._bootstrap()
            pipe = self.pipeline_factory(start_step)
            try:
                for step in range(start_step, num_steps):
                    batch = next(pipe)
                    self.injector.check(step)
                    t0 = time.perf_counter()
                    state, m = self.train_step(state, batch)
                    self.metrics.append(
                        {"step": step, "loss": float(m["loss"]),
                         "seconds": time.perf_counter() - t0})
                    if (step + 1) % self.ckpt_every == 0:
                        self.ckpt.save(step, state,
                                       {"next_step": step + 1})
                self.ckpt.wait()
                return state
            except WorkerFailure:
                self.restarts += 1
                self.ckpt.wait()           # never restore a half-written save
                if self.restarts > self.max_restarts:
                    raise
                continue
