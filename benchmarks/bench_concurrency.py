"""Concurrent-serving throughput/latency vs the serial baseline.

The paper's coordinator admits many concurrent queries and multiplexes GPU
workers under a device-memory budget. This suite measures what that serving
layer buys: N concurrent clients each submit a fixed dashboard of TPC-H
queries through ``Session.submit`` (admission control + plan/result caches
+ in-flight coalescing + interleaved morsel pipelines), against a serial
baseline that executes the identical workload one query at a time with no
scheduler. Reported per client count: wall time, query throughput, p50/p95
latency, and the speedup over serial; every scheduled result is validated
against the numpy oracle. A "cold" scheduler row disables the result cache
and coalescing (every query executes for real; the plan cache stays on),
separating pipeline-overlap + plan-cache gains from result-reuse gains.

A second, **open-loop** mode measures latency under load the way serving
systems are actually characterized: a dispatcher submits queries at a
fixed arrival rate regardless of completions (no closed-loop
self-throttling), and the suite reports p50/p99 latency per offered rate
-- queueing delay shows up in the tail as the rate approaches the
scheduler's capacity.

A third, **small-queries** mode (``--small-queries``) measures inter-query
batching (``SchedulerConfig.batching``, core/batch.py): N concurrent
clients each issue distinct-literal point lookups, filtered global
aggregates, and low-cardinality group-bys — the high-QPS serving regime
where fixed per-query dispatch cost dwarfs compute — once through the
plain scheduler and once with batching on (compatible queries coalesce
into stacked kernel launches). Reported: throughput both ways, the
batched:unbatched speedup, stacked-launch counters, and open-loop p50/p99
at a fixed arrival rate; every batched result is verified row-count- and
checksum-identical against scheduler-less serial execution.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import Session, SchedulerConfig
from repro.core.builder import QueryBuilder, col
from repro.tpch import dbgen, oracle, queries

from .common import emit

DASHBOARD = (1, 6, 14, 3)           # quick, shape-diverse queries
CLIENT_COUNTS = (1, 4, 16)


def _assert_oracle(engine: dict, orc: dict, qnum: int) -> None:
    """Order-insensitive engine-vs-oracle row match (numeric columns)."""
    cols = [c for c in orc if c in engine]
    assert cols, f"q{qnum}: no common columns"
    n = np.atleast_1d(np.asarray(orc[cols[0]])).shape[0]
    eng = np.stack([np.asarray(engine[c], dtype=np.float64).reshape(n)
                    for c in cols])
    orc_ = np.stack([np.asarray(orc[c], dtype=np.float64).reshape(n)
                     for c in cols])
    eo = np.lexsort(np.round(eng, 2)[::-1])
    oo = np.lexsort(np.round(orc_, 2)[::-1])
    np.testing.assert_allclose(eng[:, eo], orc_[:, oo], rtol=2e-3, atol=1e-2,
                               err_msg=f"q{qnum} mismatch vs oracle")


def _serial(catalog, n_clients: int) -> float:
    """Baseline: the same workload, one query at a time, no scheduler."""
    session = Session(catalog, num_workers=1, batch_rows=16384)
    t0 = time.perf_counter()
    for _ in range(n_clients):
        for qnum in DASHBOARD:
            session.execute(queries.build_query(qnum, catalog))
    return time.perf_counter() - t0


def _scheduled(catalog, n_clients: int, oracles=None,
               cache_results: bool = True):
    """N client threads submitting through the scheduler; returns
    (wall_seconds, sorted per-query latencies, scheduler stats)."""
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = SchedulerConfig(
        memory_budget=512 << 20, max_concurrency=8,
        max_queue=max(64, n_clients * len(DASHBOARD)),
        cache_results=cache_results)
    latencies: list = []
    errors: list = []

    def client():
        try:
            handles = [session.submit(
                queries.build_query(q, catalog, optimized=False))
                for q in DASHBOARD]
            for qnum, h in zip(DASHBOARD, handles):
                res = h.result()
                latencies.append(h.latency)
                if oracles is not None:
                    _assert_oracle(res, oracles[qnum], qnum)
        except Exception as exc:  # noqa: BLE001 -- fail the suite below
            errors.append(exc)

    threads = [threading.Thread(target=client) for _ in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    latencies.sort()
    return wall, latencies, session.scheduler().stats()


def _open_loop(catalog, rate_qps: float, n_queries: int,
               cache_results: bool = False):
    """Open-loop arrivals: submit one dashboard query every ``1/rate``
    seconds from a dispatcher thread, never waiting for completions.
    Returns (sorted latencies, offered seconds, scheduler stats)."""
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = SchedulerConfig(
        memory_budget=512 << 20, max_concurrency=8,
        max_queue=max(64, n_queries), cache_results=cache_results)
    handles = []
    interval = 1.0 / rate_qps
    t0 = time.perf_counter()
    for i in range(n_queries):
        target = t0 + i * interval
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)       # fixed schedule: no self-throttling
        qnum = DASHBOARD[i % len(DASHBOARD)]
        handles.append(session.submit(
            queries.build_query(qnum, catalog, optimized=False)))
    offered = time.perf_counter() - t0
    session.gather(*handles)
    lats = sorted(h.latency for h in handles)
    return lats, offered, session.scheduler().stats()


def run(sf: float = 0.005) -> None:
    catalog = dbgen.load_catalog(sf=sf)
    data = dbgen.generate(sf=sf)
    oracles = {q: oracle.ORACLES[q](data) for q in DASHBOARD}

    # warm jit caches once so neither path pays first-compile inside timing
    warm = Session(catalog, num_workers=1, batch_rows=16384)
    for qnum in DASHBOARD:
        warm.execute(queries.build_query(qnum, catalog))

    for n in CLIENT_COUNTS:
        n_queries = n * len(DASHBOARD)
        serial_s = _serial(catalog, n)
        wall, lats, stats = _scheduled(catalog, n, oracles=oracles)
        cold_wall, _, _ = _scheduled(catalog, n, cache_results=False)
        speedup = serial_s / wall
        cold_speedup = serial_s / cold_wall
        p50 = lats[len(lats) // 2]
        p95 = lats[min(len(lats) - 1, int(len(lats) * 0.95))]
        emit(f"concurrency_c{n}", wall,
             derived=f"{speedup:.2f}x_vs_serial",
             detail={
                 "clients": n,
                 "queries": n_queries,
                 "serial_seconds": serial_s,
                 "scheduled_seconds": wall,
                 "cold_scheduled_seconds": cold_wall,
                 "speedup_vs_serial": speedup,
                 "cold_speedup_vs_serial": cold_speedup,
                 "throughput_qps": n_queries / wall,
                 "serial_throughput_qps": n_queries / serial_s,
                 "latency_p50_s": p50,
                 "latency_p95_s": p95,
                 "scheduler": stats,
             })
        print(f"# clients={n:2d}: serial {serial_s:.2f}s | scheduled "
              f"{wall:.2f}s ({speedup:.2f}x) | cold {cold_wall:.2f}s "
              f"({cold_speedup:.2f}x) | p50 {p50 * 1e3:.0f}ms "
              f"p95 {p95 * 1e3:.0f}ms | coalesced={stats['coalesced']} "
              f"cache_hits={stats['result_cache_hits']}", flush=True)

    # open-loop latency percentiles under offered load (cold: every
    # arrival is a real execution, so queueing is not hidden by the
    # result cache)
    for rate in (2.0, 8.0):
        n_queries = max(int(rate) * 4, len(DASHBOARD))
        lats, offered, stats = _open_loop(catalog, rate, n_queries)
        p50 = lats[len(lats) // 2]
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
        emit(f"concurrency_openloop_r{rate:g}", p99,
             derived=f"p50_{p50 * 1e3:.0f}ms",
             detail={
                 "offered_rate_qps": rate,
                 "queries": n_queries,
                 "offered_seconds": offered,
                 "latency_p50_s": p50,
                 "latency_p99_s": p99,
                 "latency_max_s": lats[-1],
                 "scheduler": stats,
             })
        print(f"# open-loop {rate:g} q/s: p50 {p50 * 1e3:.0f}ms "
              f"p99 {p99 * 1e3:.0f}ms over {n_queries} arrivals", flush=True)


# --- small-queries mode: inter-query batching vs plain dispatch ------------

SMALL_PER_CLIENT = 3    # one query of each shape per client


def _small_queries(catalog, order_keys, n: int):
    """``n`` distinct-literal small queries cycling three compatible
    shapes (point lookup / filtered global agg / low-card group-by), so
    the batching scheduler forms one stacked launch group per shape."""
    out = []
    for i in range(n):
        kind = i % 3
        if kind == 0:
            key = int(order_keys[(i * 37) % len(order_keys)])
            out.append(QueryBuilder.scan(catalog, "orders")
                       .filter(col("o_orderkey") == key)
                       .project("o_orderkey", "o_totalprice"))
        elif kind == 1:
            out.append(QueryBuilder.scan(catalog, "lineitem")
                       .filter(col("l_quantity") < float(2 + (i % 47)))
                       .project(rev=col("l_extendedprice")
                                * col("l_discount"))
                       .agg(total=("sum", "rev"), n=("count", None)))
        else:
            out.append(QueryBuilder.scan(catalog, "lineitem")
                       .filter(col("l_quantity") < float(3 + (i % 43)))
                       .group_by("l_returnflag")
                       .agg(total=("sum", "l_extendedprice"),
                            n=("count", None)))
    return out


def _assert_checksums(ref: dict, got: dict, label: str) -> None:
    """Row-count + per-column checksum identity (floats to reduction
    order; ints/keys exact)."""
    assert set(ref) == set(got), f"{label}: column sets differ"
    for c in ref:
        r, g = np.asarray(ref[c]), np.asarray(got[c])
        assert r.shape == g.shape, f"{label}.{c}: {r.shape} != {g.shape}"
        if np.issubdtype(r.dtype, np.floating):
            np.testing.assert_allclose(
                np.sum(g, dtype=np.float64), np.sum(r, dtype=np.float64),
                rtol=2e-3, atol=1e-2, err_msg=f"{label}.{c} checksum")
        else:
            np.testing.assert_array_equal(g, r,
                                          err_msg=f"{label}.{c} rows")


def _scheduled_small(catalog, builders, n_clients: int, batching: bool):
    """N client threads, ``SMALL_PER_CLIENT`` queries each; returns
    (wall_seconds, results in builder order, sorted latencies, stats)."""
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = SchedulerConfig(
        memory_budget=512 << 20, max_concurrency=8,
        max_queue=max(64, len(builders)), cache_results=False,
        batching=batching, batch_window_ms=10.0, max_batch=32)
    results: list = [None] * len(builders)
    latencies: list = []
    errors: list = []

    def client(c: int):
        try:
            idx = range(c * SMALL_PER_CLIENT, (c + 1) * SMALL_PER_CLIENT)
            handles = [(i, session.submit(builders[i])) for i in idx]
            for i, h in handles:
                results[i] = h.result()
                latencies.append(h.latency)
        except Exception as exc:  # noqa: BLE001 -- fail the suite below
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    latencies.sort()
    return wall, results, latencies, session.scheduler().stats()


def _open_loop_small(catalog, builders, rate_qps: float, batching: bool):
    """Open-loop arrivals of the small-query workload; returns sorted
    latencies (queue-to-result, so the batch window shows up in p50)."""
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = SchedulerConfig(
        memory_budget=512 << 20, max_concurrency=8,
        max_queue=max(64, len(builders)), cache_results=False,
        batching=batching, batch_window_ms=10.0, max_batch=32)
    handles = []
    interval = 1.0 / rate_qps
    t0 = time.perf_counter()
    for i, b in enumerate(builders):
        target = t0 + i * interval
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        handles.append(session.submit(b))
    session.gather(*handles)
    return sorted(h.latency for h in handles)


def run_small_queries(sf: float = 0.005, clients: int = 16) -> None:
    """Batched vs unbatched dispatch for concurrent small queries."""
    catalog = dbgen.load_catalog(sf=sf)
    data = dbgen.generate(sf=sf)
    order_keys = np.asarray(data["orders"]["o_orderkey"])
    n_queries = clients * SMALL_PER_CLIENT
    builders = _small_queries(catalog, order_keys, n_queries)

    # scheduler-less serial reference: timing baseline AND the oracle the
    # batched results must be row/checksum-identical to. Every mode runs
    # the workload once untimed first — jit/XLA compiles amortize across
    # a serving lifetime (the batched path compiles one stacked program
    # per shape and lane count), so the timed pass is steady-state
    # dispatch, the thing batching exists to amortize.
    serial_session = Session(catalog, num_workers=1, batch_rows=16384)
    plans = [b.optimized() for b in builders]
    refs = [serial_session.execute(p) for p in plans]
    t0 = time.perf_counter()
    refs = [serial_session.execute(p) for p in plans]
    serial_s = time.perf_counter() - t0

    _scheduled_small(catalog, builders, clients, batching=False)
    plain_wall, plain_res, plain_lats, plain_stats = _scheduled_small(
        catalog, builders, clients, batching=False)
    _scheduled_small(catalog, builders, clients, batching=True)
    bat_wall, bat_res, bat_lats, bat_stats = _scheduled_small(
        catalog, builders, clients, batching=True)
    for i, (r, p, b) in enumerate(zip(refs, plain_res, bat_res)):
        _assert_checksums(r, p, f"plain q{i}")
        _assert_checksums(r, b, f"batched q{i}")

    speedup = plain_wall / bat_wall
    p50 = bat_lats[len(bat_lats) // 2]
    p99 = bat_lats[min(len(bat_lats) - 1, int(len(bat_lats) * 0.99))]
    emit(f"concurrency_small_c{clients}", bat_wall,
         derived=f"{speedup:.2f}x_batched_vs_unbatched",
         detail={
             "clients": clients,
             "queries": n_queries,
             "serial_seconds": serial_s,
             "unbatched_seconds": plain_wall,
             "batched_seconds": bat_wall,
             "batched_speedup": speedup,
             "unbatched_throughput_qps": n_queries / plain_wall,
             "batched_throughput_qps": n_queries / bat_wall,
             "batched_latency_p50_s": p50,
             "batched_latency_p99_s": p99,
             "stacked_launches": bat_stats["batches"],
             "batched_queries": bat_stats["batched_queries"],
             "unbatched_scheduler": plain_stats,
             "batched_scheduler": bat_stats,
         })
    print(f"# small-queries clients={clients}: serial {serial_s:.2f}s | "
          f"unbatched {plain_wall:.2f}s "
          f"({n_queries / plain_wall:.1f} q/s) | batched {bat_wall:.2f}s "
          f"({n_queries / bat_wall:.1f} q/s, {speedup:.2f}x) | "
          f"{bat_stats['batched_queries']}/{n_queries} queries in "
          f"{bat_stats['batches']} stacked launches | "
          f"p50 {p50 * 1e3:.0f}ms p99 {p99 * 1e3:.0f}ms", flush=True)

    # open-loop: does the batch window hurt latency at moderate load?
    rate = max(8.0, clients / 2)
    for batching in (False, True):
        lats = _open_loop_small(catalog, builders, rate, batching)
        p50 = lats[len(lats) // 2]
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
        mode = "batched" if batching else "unbatched"
        emit(f"concurrency_small_openloop_{mode}", p99,
             derived=f"p50_{p50 * 1e3:.0f}ms",
             detail={"offered_rate_qps": rate, "queries": n_queries,
                     "latency_p50_s": p50, "latency_p99_s": p99,
                     "latency_max_s": lats[-1], "batching": batching})
        print(f"# small-queries open-loop {rate:g} q/s [{mode}]: "
              f"p50 {p50 * 1e3:.0f}ms p99 {p99 * 1e3:.0f}ms", flush=True)


def main(argv=None) -> None:
    import argparse
    parser = argparse.ArgumentParser(
        description="Concurrent-serving benchmarks")
    parser.add_argument("--sf", type=float, default=0.005,
                        help="TPC-H scale factor")
    parser.add_argument("--small-queries", action="store_true",
                        help="run the inter-query batching mode instead "
                             "of the dashboard suite")
    parser.add_argument("--clients", type=int, default=16,
                        help="concurrent clients (small-queries mode)")
    args = parser.parse_args(argv)
    if args.small_queries:
        run_small_queries(sf=args.sf, clients=args.clients)
    else:
        run(sf=args.sf)


if __name__ == "__main__":
    main()
