"""Shared benchmark plumbing. Prints ``name,us_per_call,derived`` CSV rows
(harness contract) and writes JSON details to results/bench/."""

from __future__ import annotations

import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def timeit(fn, *, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def emit(name: str, seconds: float, derived: str = "", detail: dict = None):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    if detail is not None:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
            json.dump({"name": name, "seconds": seconds,
                       "derived": derived, **detail}, f, indent=1)
