"""Paper §2.2: column-chunk format vs paged (Parquet-shaped) baseline.

Measures time to read the lineitem table into device memory with (a) the
minimal column-chunk format (memmap -> device, no interpretation) and (b)
the paged format (footer/row-group/page metadata walk + delta decode).
The paper observed a 10x gap on GPU hardware; the mechanism (metadata
interpretation + interleaved decode serializes the read path) reproduces
at any scale.
"""

from __future__ import annotations

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.storage import PagedTable, write_paged_table
from repro.tpch import dbgen
from repro.tpch import schema as S

from .common import emit, timeit


def run(sf: float = 0.01):
    data = dbgen.generate(sf=sf)
    li = data["lineitem"]
    with tempfile.TemporaryDirectory() as root:
        from repro.storage import write_table
        from repro.storage.colchunk import read_column_chunk
        write_table(root, "lineitem", li, S.LINEITEM, chunks=8)
        write_paged_table(root, "lineitem", li, S.LINEITEM, row_groups=8)

        cols = list(S.LINEITEM)

        # the paper's experiment is the raw storage->device read rate:
        # column-chunk = memmap -> device transfer, zero interpretation;
        # paged = footer/row-group/page metadata walk + delta decode.
        def read_colchunk():
            for c in cols:
                for k in range(8):
                    arr = read_column_chunk(root, "lineitem", c, k)
                    jnp.asarray(arr).block_until_ready()

        def read_paged():
            r = PagedTable(root, "lineitem")
            for c in cols:
                jnp.asarray(r.read_column(c)).block_until_ready()

        t_cc = timeit(read_colchunk, warmup=1, iters=3)
        t_pg = timeit(read_paged, warmup=1, iters=3)
        nbytes = sum(np.asarray(v).nbytes for v in li.values())
        emit("storage_colchunk_read", t_cc,
             f"GBps={nbytes / t_cc / 1e9:.2f}",
             {"bytes": int(nbytes), "rows": len(li["l_orderkey"])})
        emit("storage_paged_read", t_pg,
             f"GBps={nbytes / t_pg / 1e9:.2f};gap={t_pg / t_cc:.1f}x",
             {"bytes": int(nbytes)})


if __name__ == "__main__":
    run()
