"""Paper Figure 9 / §3.6: cost-performance (cost x time product).

Reproduces the paper's *methodology* with its own published prices: measured
suite time per configuration x public on-demand $/hr for the instance
class. We use the paper's AWS figures (g7e GPU vs r6i/m7a CPU families) and
scale by our measured relative throughputs between the accelerated
(device-resident, ICI exchange) and host-staged configurations, which is
the quantity our system controls."""

from __future__ import annotations

from repro.core import HostExchange, ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

# public on-demand rates used by the paper's Figure 9 (USD/hr)
PRICE = {"gpu_g7e.12xlarge_x4": 4 * 4.83, "cpu_r6i.16xlarge_x4": 4 * 4.03}
QS = (1, 3, 5, 6, 9, 13)


def run(sf: float = 0.002):
    catalog = dbgen.load_catalog(sf=sf)
    times = {}
    for name, ex_factory in (("accelerated", ICIExchange),
                             ("host_staged", HostExchange)):
        total = 0.0
        for q in QS:
            session = Session(catalog, num_workers=4, exchange=ex_factory(),
                              batch_rows=16384)
            plan = queries.build_query(q, catalog)
            total += timeit(lambda: session.execute(plan), warmup=1, iters=2)
        times[name] = total
    # cost x time product (lower is better), paper's metric
    gpu_cost_time = (times["accelerated"] / 60) * PRICE["gpu_g7e.12xlarge_x4"]
    cpu_cost_time = (times["host_staged"] / 60) * PRICE["cpu_r6i.16xlarge_x4"]
    emit("fig9_accelerated", times["accelerated"],
         f"cost_time={gpu_cost_time:.4f}")
    emit("fig9_host_staged", times["host_staged"],
         f"cost_time={cpu_cost_time:.4f};"
         f"advantage={cpu_cost_time / gpu_cost_time:.2f}x",
         {"times": times, "prices": PRICE})


if __name__ == "__main__":
    run()
