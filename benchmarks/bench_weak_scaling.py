"""Paper Figure 7: weak scaling — data size and worker count grow together
(SF=100/1gpu .. SF=1000/8gpu in the paper; scaled SFs here). Reports total
suite time per (sf, workers) plus the per-query join-heavy outliers (the
paper calls out Q9/Q21)."""

from __future__ import annotations

from repro.core import ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

# queries representative of Figure 7b (join-heavy ones deviate most)
QS = (1, 5, 6, 9, 13, 18, 21)


def run():
    base = 0.001
    for mult, workers in ((1, 1), (2, 2), (4, 4)):
        sf = base * mult
        catalog = dbgen.load_catalog(sf=sf)
        total = 0.0
        per_q = {}
        for q in QS:
            session = Session(catalog, num_workers=workers,
                              exchange=ICIExchange(), batch_rows=16384)
            plan = queries.build_query(q, catalog)
            t = timeit(lambda: session.execute(plan), warmup=1, iters=2)
            per_q[q] = t
            total += t
        emit(f"fig7_sf{mult}x_w{workers}", total,
             f"q9={per_q[9] * 1e3:.1f}ms;q21={per_q[21] * 1e3:.1f}ms",
             {"per_query": {str(k): v for k, v in per_q.items()}})


if __name__ == "__main__":
    run()
