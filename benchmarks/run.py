# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; per-benchmark JSON details land in results/bench/.

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (bench_barebones, bench_cold_hot, bench_cost_perf,
                   bench_exchange, bench_q5_scaling, bench_scaleup,
                   bench_storage_format, bench_weak_scaling)

    suites = [
        ("storage_format(§2.2)", bench_storage_format.run),
        ("barebones(Table1)", bench_barebones.run),
        ("exchange(Fig5,§3.4)", bench_exchange.run),
        ("q5_scaling(Fig6)", bench_q5_scaling.run),
        ("weak_scaling(Fig7)", bench_weak_scaling.run),
        ("scaleup(Fig8)", bench_scaleup.run),
        ("cold_hot(Table3)", bench_cold_hot.run),
        ("cost_perf(Fig9)", bench_cost_perf.run),
    ]
    failures = 0
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:   # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"# FAILED {name}", flush=True)
            traceback.print_exc()
        print(f"# --- {name} done in {time.time() - t0:.0f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
