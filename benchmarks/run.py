# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; per-benchmark JSON details land in results/bench/.

from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description="Run benchmark suites")
    parser.add_argument("--sf", type=float, default=None,
                        help="TPC-H scale factor override for suites that "
                             "take one (CI smoke runs use a tiny value)")
    parser.add_argument("--only", default=None,
                        help="substring filter on suite names")
    args = parser.parse_args(argv)

    from . import (bench_adaptive, bench_barebones, bench_cold_hot,
                   bench_concurrency, bench_cost_perf, bench_exchange,
                   bench_kernels, bench_outofcore, bench_q5_scaling,
                   bench_scaleup, bench_scan_pipeline, bench_sql,
                   bench_storage_format, bench_weak_scaling)

    suites = [
        ("storage_format(§2.2)", bench_storage_format.run),
        ("scan_pipeline(§2.2)", bench_scan_pipeline.run),
        ("sql(frontend)", bench_sql.run),
        ("kernels(§3.2)", bench_kernels.run),
        ("concurrency(serving)", bench_concurrency.run),
        ("concurrency_small(batching)", bench_concurrency.run_small_queries),
        ("barebones(Table1)", bench_barebones.run),
        ("exchange(Fig5,§3.4)", bench_exchange.run),
        ("exchange_planned(§3.3)", bench_exchange.run_planned),
        ("q5_scaling(Fig6)", bench_q5_scaling.run),
        ("weak_scaling(Fig7)", bench_weak_scaling.run),
        ("scaleup(Fig8)", bench_scaleup.run),
        ("cold_hot(Table3)", bench_cold_hot.run),
        ("cost_perf(Fig9)", bench_cost_perf.run),
        ("outofcore(spill)", bench_outofcore.run),
        ("adaptive(feedback)", bench_adaptive.run),
    ]
    if args.only:
        suites = [(n, fn) for n, fn in suites if args.only in n]

    results = []   # (name, ok, seconds)
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        kwargs = {}
        if args.sf is not None and "sf" in inspect.signature(fn).parameters:
            kwargs["sf"] = args.sf
        t0 = time.time()
        ok = True
        try:
            fn(**kwargs)
        except Exception:   # noqa: BLE001 — keep the harness running
            ok = False
            print(f"# FAILED {name}", flush=True)
            traceback.print_exc()
        dt = time.time() - t0
        results.append((name, ok, dt))
        print(f"# --- {name} done in {dt:.0f}s", flush=True)

    # scannable per-suite summary for CI logs
    print("# === summary ===", flush=True)
    for name, ok, dt in results:
        print(f"# {'PASS' if ok else 'FAIL'} {name} ({dt:.0f}s)", flush=True)
    failures = sum(1 for _, ok, _ in results if not ok)
    print(f"# {len(results) - failures}/{len(results)} suites passed",
          flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
