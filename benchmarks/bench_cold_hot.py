"""Paper Table 3: cold (from storage) vs hot (cached DeviceTables) runs.

The paper's AsyncDataCache analogue here is an in-memory catalog holding
already-device-resident tables; cold runs read the column-chunk files per
query. Paper ratio: 1.77x."""

from __future__ import annotations

import tempfile

from repro.core import Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

QS = (1, 5, 6, 13)


def run(sf: float = 0.004):
    with tempfile.TemporaryDirectory() as root:
        data = dbgen.write_dataset(root, sf=sf, chunks=4)
        cold_cat = dbgen.storage_catalog(root)          # reads files per scan
        hot_cat = dbgen.load_catalog(sf=sf)             # tables resident

        t_cold = t_hot = 0.0
        for q in QS:
            s_cold = Session(cold_cat, num_workers=2, batch_rows=16384)
            s_hot = Session(hot_cat, num_workers=2, batch_rows=16384)
            t_cold += timeit(lambda: s_cold.execute(
                queries.build_query(q, cold_cat)), warmup=0, iters=2)
            t_hot += timeit(lambda: s_hot.execute(
                queries.build_query(q, hot_cat)), warmup=1, iters=2)
        emit("table3_cold", t_cold, "")
        emit("table3_hot", t_hot, f"ratio={t_cold / t_hot:.2f}x")
        del data


if __name__ == "__main__":
    run()
