"""Paper Figure 8 (scale-up) + §3.5.1: worker-count sweep at fixed SF.

The paper scales up by adding GPUs with more memory; here we sweep worker
count on the suite subset and report strong-scaling efficiency (paper: 1->8
B200s gave 3.2x on SF=1K)."""

from __future__ import annotations

from repro.core import ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

QS = (1, 3, 5, 6, 12, 14)


def run(sf: float = 0.004):
    catalog = dbgen.load_catalog(sf=sf)
    base = None
    for workers in (1, 2, 4, 8):
        total = 0.0
        for q in QS:
            session = Session(catalog, num_workers=workers,
                              exchange=ICIExchange(), batch_rows=16384)
            plan = queries.build_query(q, catalog)
            total += timeit(lambda: session.execute(plan), warmup=1, iters=2)
        if base is None:
            base = total
        emit(f"fig8_workers{workers}", total,
             f"speedup={base / total:.2f}x")


if __name__ == "__main__":
    run()
