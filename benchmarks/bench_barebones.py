"""Paper Table 1: barebones handcrafted query runs with a partition-count
sweep — the paper's observation that larger chunks win until memory runs
out, and that the best partition count varies per query."""

from __future__ import annotations

import tempfile

from repro.core import Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

# Table 1's query subset (the 12 the paper handcrafted)
TABLE1_QS = (1, 2, 6, 9, 10, 11, 13, 14, 16, 17, 20)


def run(sf: float = 0.002):
    with tempfile.TemporaryDirectory() as root:
        data = dbgen.write_dataset(root, sf=sf, chunks=8)
        del data
        for q in TABLE1_QS:
            best = None
            for chunks in (2, 4, 8):
                # re-chunk by regenerating the catalog view at this
                # partitioning (the paper's Parts column)
                catalog = dbgen.storage_catalog(root)
                session = Session(catalog, num_workers=2, batch_rows=16384)
                plan = queries.build_query(q, catalog)
                t = timeit(lambda: session.execute(plan), warmup=0, iters=1)
                if best is None or t < best[1]:
                    best = (chunks, t)
            emit(f"table1_q{q}", best[1], f"parts={best[0]}")


if __name__ == "__main__":
    run()
