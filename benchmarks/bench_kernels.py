"""Kernel-backend comparison: pallas kernels vs their jnp oracle paths.

Per-primitive micro-benchmarks of the four hot-spot kernels the engine
dispatches through ``kernels.ops`` — segmented aggregation (MXU
scatter-add vs ``jax.ops.segment_sum``), exchange histogram (radix vs
one-hot sum), stream-compaction addresses (two-level scan vs stable
argsort), and hash-table build + probe (open addressing vs
sort + searchsorted) — plus per-kernel achieved roofline fractions
(``launch.roofline.measure_program``), a Q6-shaped fused-vs-per-primitive
morsel scan, and a Q1/Q3 end-to-end run of both Session backends with
their ``kernel_dispatch`` counts.

Off-TPU the pallas numbers are *interpret mode* (the kernel body executed
as plain XLA ops): they validate the dispatch boundary and give a shape of
the work, not a speedup — on a TPU backend the same wrappers run the
compiled kernels. The emitted JSON (``results/bench/kernels.json``) is the
artifact the kernel-backend CI job uploads.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Session
from repro.core import relational as rel
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.tpch import dbgen, queries

from .common import RESULTS, emit, timeit

N_ROWS = 65536
N_GROUPS = 4096
N_PARTS = 8
N_BUILD = 8192
TABLE = 4 * N_BUILD


def _block(fn):
    return lambda: jax.block_until_ready(fn())


def bench_primitives(detail: dict) -> None:
    """Per-primitive jnp-oracle vs pallas-kernel wall times."""
    rng = np.random.default_rng(0)
    gids = jnp.asarray(rng.integers(0, N_GROUPS, N_ROWS), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, N_ROWS), jnp.float32)
    pids = jnp.asarray(rng.integers(0, N_PARTS, N_ROWS), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, N_ROWS).astype(bool))
    keys = jnp.asarray(rng.choice(10**7, N_BUILD, replace=False), jnp.int32)
    rows = jnp.arange(N_BUILD, dtype=jnp.int32)
    probes = jnp.asarray(rng.integers(0, 10**7, N_ROWS), jnp.int32)

    jit_ref_seg = jax.jit(
        lambda g, v: ref.segmented_agg(g, v, N_GROUPS, "sum"))
    jit_ref_hist = jax.jit(lambda p: ref.radix_histogram(p, N_PARTS))
    jit_ref_bps = jax.jit(ref.block_prefix_sum)
    jit_ref_probe = jax.jit(
        lambda bt, pk: rel.join_probe(bt, pk, jnp.ones_like(pk, bool), 1))

    pairs = [
        ("segmented_sum",
         _block(lambda: jit_ref_seg(gids, vals)),
         _block(lambda: kernel_ops.segmented_sum(gids, vals, N_GROUPS))),
        ("radix_histogram",
         _block(lambda: jit_ref_hist(pids)),
         _block(lambda: kernel_ops.radix_histogram(pids, N_PARTS))),
        ("block_prefix_sum",
         _block(lambda: jit_ref_bps(mask)),
         _block(lambda: kernel_ops.block_prefix_sum(mask))),
    ]
    for name, jnp_fn, pallas_fn in pairs:
        t_jnp = timeit(jnp_fn)
        t_pal = timeit(pallas_fn)
        emit(f"kernels_{name}_jnp", t_jnp)
        emit(f"kernels_{name}_pallas", t_pal,
             derived=f"x{t_pal / max(t_jnp, 1e-9):.1f}_vs_jnp")
        detail[name] = {"jnp_s": t_jnp, "pallas_s": t_pal}

    # join build + probe: sorted-searchsorted vs open-addressing table
    valid = jnp.ones((N_BUILD,), bool)
    t_jnp_build = timeit(_block(lambda: rel.join_build(keys, valid)))
    t_pal_build = timeit(
        _block(lambda: kernel_ops.build_table(keys, rows, TABLE)))
    bt = rel.join_build(keys, valid)
    tk, tv = kernel_ops.build_table(keys, rows, TABLE)
    t_jnp_probe = timeit(_block(lambda: jit_ref_probe(bt, probes)))
    t_pal_probe = timeit(
        _block(lambda: kernel_ops.hash_probe(tk, tv, probes,
                                             max_probes=64)))
    emit("kernels_join_build_jnp", t_jnp_build)
    emit("kernels_join_build_pallas", t_pal_build,
         derived=f"x{t_pal_build / max(t_jnp_build, 1e-9):.1f}_vs_jnp")
    emit("kernels_hash_probe_jnp", t_jnp_probe)
    emit("kernels_hash_probe_pallas", t_pal_probe,
         derived=f"x{t_pal_probe / max(t_jnp_probe, 1e-9):.1f}_vs_jnp")
    detail["join_build"] = {"jnp_s": t_jnp_build, "pallas_s": t_pal_build}
    detail["hash_probe"] = {"jnp_s": t_jnp_probe, "pallas_s": t_pal_probe}


def bench_roofline(detail: dict) -> None:
    """Achieved roofline fraction per kernel (``launch.roofline``).

    Each wrapper is lowered at the bench shape; FLOPs/bytes come from the
    compiled cost analysis, the bound from the TPU v5e peak terms. Off-TPU
    the absolute fractions are interpret-mode noise — the artifact exists
    so the TPU run of the same job shows each kernel's distance from the
    §3.2 ceiling, and the CPU run keeps the plumbing tested."""
    from repro.launch import roofline

    rng = np.random.default_rng(0)
    gids = jnp.asarray(rng.integers(0, N_GROUPS, N_ROWS), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, N_ROWS), jnp.float32)
    ivals = jnp.asarray(rng.integers(0, 100, N_ROWS), jnp.int32)
    pids = jnp.asarray(rng.integers(0, N_PARTS, N_ROWS), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, N_ROWS).astype(bool))
    keys = jnp.asarray(rng.choice(10**7, N_BUILD, replace=False), jnp.int32)
    rows = jnp.arange(N_BUILD, dtype=jnp.int32)
    probes = jnp.asarray(rng.integers(0, 10**7, N_ROWS), jnp.int32)
    tk, tv = kernel_ops.build_table(keys, rows, TABLE)

    programs = {
        "segmented_sum": (
            lambda g, v: kernel_ops.segmented_sum(g, v, N_GROUPS),
            (gids, vals)),
        "segmented_int_sum": (
            lambda g, v: kernel_ops.segmented_int_sum(g, v, N_GROUPS),
            (gids, ivals)),
        "segmented_minmax": (
            lambda g, v: kernel_ops.segmented_minmax(g, v, N_GROUPS, "max"),
            (gids, vals)),
        "radix_histogram": (
            lambda p: kernel_ops.radix_histogram(p, N_PARTS), (pids,)),
        "block_prefix_sum": (kernel_ops.block_prefix_sum, (mask,)),
        "build_table": (
            lambda k, r: kernel_ops.build_table(k, r, TABLE), (keys, rows)),
        "hash_probe": (
            lambda a, b, p: kernel_ops.hash_probe(a, b, p, max_probes=64),
            (tk, tv, probes)),
        "hash_probe_multi": (
            lambda a, b, p: kernel_ops.hash_probe_multi(a, b, p, 4,
                                                        max_probes=64),
            (tk, tv, probes)),
    }
    reports = {}
    for name, (fn, args) in programs.items():
        rep = roofline.measure_program(fn, *args)
        reports[name] = rep
        emit(f"kernels_roofline_{name}", rep["measured_s"],
             derived=(f"roofline={rep['achieved_fraction']:.4f}"
                      f"_{rep['dominant']}"))
    detail["roofline"] = reports


def bench_fused_scan(detail: dict) -> None:
    """Q6-shaped scan morsel: filter (shipdate window, discount window,
    quantity cap) then project revenue. 'fused' runs the whole chain as
    one per-morsel pallas kernel (``core.fused``); 'per_primitive' is the
    dispatch baseline — the same stages launched as one kernel each, the
    way the unfused pipeline executes the morsel. The delta is the launch
    + HBM-round-trip overhead the fused path exists to remove."""
    from repro.core import dtypes as dt
    from repro.core import fused
    from repro.core.expr import col
    from repro.core.table import DeviceTable
    from repro.launch import roofline

    rng = np.random.default_rng(1)
    n = N_ROWS
    table = DeviceTable.from_numpy(
        {"l_shipdate": rng.integers(8700, 9200, n).astype(np.int32),
         "l_discount": rng.uniform(0.0, 0.1, n).astype(np.float32),
         "l_quantity": rng.uniform(1.0, 50.0, n).astype(np.float32),
         "l_extendedprice": rng.uniform(1.0, 1e5, n).astype(np.float32)},
        {"l_shipdate": dt.INT32, "l_discount": dt.FLOAT32,
         "l_quantity": dt.FLOAT32, "l_extendedprice": dt.FLOAT32})
    f = (col("l_shipdate").between(8800, 9100)
         & col("l_discount").between(0.05, 0.07)
         & (col("l_quantity") < 24.0))
    proj = (("v", col("l_extendedprice") * col("l_discount")),)
    stages = ((f, None), (None, proj))

    def fused_fn(t):
        out, _, _ = fused.fused_morsel_program(t, stages)
        return out

    def per_primitive_fn(t):
        for stage in stages:
            t, _, _ = fused.fused_morsel_program(t, (stage,))
        return t

    t_fused = timeit(_block(lambda: jax.jit(fused_fn)(table)))
    t_prim = timeit(_block(lambda: jax.jit(per_primitive_fn)(table)))
    rep = roofline.measure_program(fused_fn, table)
    emit("kernels_fused_q6_scan_per_primitive", t_prim)
    emit("kernels_fused_q6_scan_fused", t_fused,
         derived=(f"x{t_prim / max(t_fused, 1e-9):.2f}_vs_per_primitive_"
                  f"roofline={rep['achieved_fraction']:.4f}"))
    detail["fused_q6_scan"] = {
        "fused_s": t_fused, "per_primitive_s": t_prim,
        "speedup": t_prim / max(t_fused, 1e-9), "roofline": rep}


def bench_end_to_end(detail: dict, sf: float) -> None:
    """Q1 + Q3 through both Session backends, with dispatch counts."""
    catalog = dbgen.load_catalog(sf=sf)
    for qnum in (1, 3):
        plan = queries.build_query(qnum, catalog)
        row = {}
        for backend in kernel_ops.BACKENDS:
            session = Session(catalog, num_workers=1,
                              kernel_backend=backend)
            session.execute(plan)             # compile warmup
            t = timeit(lambda s=session: s.execute(plan), iters=2)
            stats = session.executor_stats()
            emit(f"kernels_q{qnum}_{backend}", t)
            row[backend] = {"seconds": t,
                            "kernel_dispatch": stats["kernel_dispatch"]}
        detail[f"q{qnum}"] = row


def run(sf: float = 0.002) -> None:
    """Entry point for benchmarks.run: primitives + end-to-end backends."""
    detail: dict = {"on_tpu": kernel_ops.on_tpu(), "rows": N_ROWS}
    bench_primitives(detail)
    bench_roofline(detail)
    bench_fused_scan(detail)
    bench_end_to_end(detail, sf)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "kernels.json"), "w") as f:
        json.dump(detail, f, indent=1)


if __name__ == "__main__":
    run()
