"""Paper Figure 5 + §3.4 headline: all 22 TPC-H queries with the
device-native ICIExchange vs the host-staged HostExchange (HttpExchange
analogue), 4 workers.

Reports per-query wall time for both protocols, the total-suite ratio
(paper: 828s -> 93s, >8x), and the *mechanism* numbers that transfer across
hardware: bytes staged through host memory (HostExchange) vs zero
(ICIExchange), and exchange rounds. Also q9-style exchange-heavy vs
q1-style exchange-light contrast (paper: >20x vs ~1x).
"""

from __future__ import annotations

from repro.core import HostExchange, ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

SF = 0.002
WORKERS = 4


def run(sf: float = SF):
    catalog = dbgen.load_catalog(sf=sf)
    totals = {}
    staged = {}
    for proto_name, make in (("ici", lambda: ICIExchange()),
                             ("host", lambda: HostExchange())):
        total = 0.0
        staged_bytes = 0
        for q in sorted(queries.QUERIES):
            ex = make()
            session = Session(catalog, num_workers=WORKERS, exchange=ex,
                              batch_rows=16384)
            plan = queries.build_query(q, catalog)
            t = timeit(lambda: session.execute(plan), warmup=1, iters=2)
            total += t
            staged_bytes += ex.stats.host_staged_bytes
            emit(f"fig5_q{q}_{proto_name}", t,
                 f"rounds={ex.stats.rounds};moved_B={ex.stats.bytes_moved};"
                 f"staged_B={ex.stats.host_staged_bytes}")
        totals[proto_name] = total
        staged[proto_name] = staged_bytes
    emit("fig5_total_ici", totals["ici"], f"staged_B={staged['ici']}")
    emit("fig5_total_host", totals["host"],
         f"staged_B={staged['host']};"
         f"suite_ratio={totals['host'] / totals['ici']:.2f}x",
         {"totals": totals, "staged": staged})


if __name__ == "__main__":
    run()
