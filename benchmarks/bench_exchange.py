"""Paper Figure 5 + §3.4 headline: TPC-H with the device-native ICIExchange
vs the host-staged HostExchange (HttpExchange analogue).

Two modes:

* ``run`` (Fig 5) — all 22 queries, 4 workers, driver-inserted exchanges.
  Reports per-query wall time for both protocols, the total-suite ratio
  (paper: 828s -> 93s, >8x), and the *mechanism* numbers that transfer
  across hardware: bytes staged through host memory (HostExchange) vs zero
  (ICIExchange), and exchange rounds. Also q9-style exchange-heavy vs
  q1-style exchange-light contrast (paper: >20x vs ~1x).

* ``run_planned`` (§3.3 over fragment plans) — Q3/Q5/Q10 *planned by the
  optimizer with physical exchange placement* (explicit Repartition/
  Broadcast nodes via ``build_query(..., num_workers=W)``) and executed
  distributed at W∈{1,2,4}. Reports ICI-vs-host wall time per (query, W),
  the exchange-round/byte counters, and asserts the device-native path
  stages zero bytes through host memory.
"""

from __future__ import annotations

import dataclasses

from repro.core import HostExchange, ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit

SF = 0.002
WORKERS = 4

PLANNED_QUERIES = (3, 5, 10)
PLANNED_WORKERS = (1, 2, 4)


def run(sf: float = SF):
    catalog = dbgen.load_catalog(sf=sf)
    totals = {}
    staged = {}
    for proto_name, make in (("ici", lambda: ICIExchange()),
                             ("host", lambda: HostExchange())):
        total = 0.0
        staged_bytes = 0
        for q in sorted(queries.QUERIES):
            ex = make()
            session = Session(catalog, num_workers=WORKERS, exchange=ex,
                              batch_rows=16384)
            plan = queries.build_query(q, catalog)
            t = timeit(lambda: session.execute(plan), warmup=1, iters=2)
            total += t
            staged_bytes += ex.stats.host_staged_bytes
            emit(f"fig5_q{q}_{proto_name}", t,
                 f"rounds={ex.stats.rounds};moved_B={ex.stats.bytes_moved};"
                 f"staged_B={ex.stats.host_staged_bytes}")
        totals[proto_name] = total
        staged[proto_name] = staged_bytes
    emit("fig5_total_ici", totals["ici"], f"staged_B={staged['ici']}")
    emit("fig5_total_host", totals["host"],
         f"staged_B={staged['host']};"
         f"suite_ratio={totals['host'] / totals['ici']:.2f}x",
         {"totals": totals, "staged": staged})


def run_planned(sf: float = SF):
    """Optimizer-planned distributed Q3/Q5/Q10: ICI vs host-staged at
    W∈{1,2,4} over fragment plans with explicit exchange nodes."""
    catalog = dbgen.load_catalog(sf=sf)
    detail = {"sf": sf, "runs": []}
    for q in PLANNED_QUERIES:
        for w in PLANNED_WORKERS:
            plan = queries.build_query(q, catalog, num_workers=w)
            seconds = {}
            for proto_name, make in (("ici", ICIExchange),
                                     ("host", HostExchange)):
                ex = make()
                session = Session(catalog, num_workers=w, exchange=ex,
                                  batch_rows=16384)
                session.execute(plan)           # warmup (compile caches)
                session.execute(plan)
                ex.stats.reset()
                session.execute(plan)           # one run's exchange counters
                stats = dataclasses.replace(ex.stats)
                if proto_name == "ici" and stats.host_staged_bytes:
                    raise AssertionError(
                        f"planned q{q} W={w}: device-native exchange staged "
                        f"{stats.host_staged_bytes} B through host")
                # best-of-3 short batches: robust to scheduler noise on
                # shared CI runners at these millisecond scales
                t = min(timeit(lambda: session.execute(plan),
                               warmup=0, iters=3) for _ in range(3))
                seconds[proto_name] = t
                emit(f"planned_q{q}_w{w}_{proto_name}", t,
                     f"rounds={stats.rounds};"
                     f"moved_B={stats.bytes_moved};"
                     f"staged_B={stats.host_staged_bytes}")
                detail["runs"].append(
                    {"query": q, "workers": w, "protocol": proto_name,
                     "seconds": t, "rounds": stats.rounds,
                     "rows_moved": stats.rows_moved,
                     "bytes_moved": stats.bytes_moved,
                     "host_staged_bytes": stats.host_staged_bytes})
            if w > 1:
                emit(f"planned_q{q}_w{w}_ratio", seconds["host"],
                     f"host_over_ici={seconds['host'] / seconds['ici']:.2f}x")
    dist = [r for r in detail["runs"] if r["workers"] > 1]
    ici = sum(r["seconds"] for r in dist if r["protocol"] == "ici")
    host = sum(r["seconds"] for r in dist if r["protocol"] == "host")
    emit("planned_total", ici,
         f"host_total={host:.4f};suite_ratio={host / ici:.2f}x;ici_staged_B=0",
         detail)


if __name__ == "__main__":
    import sys
    if "--planned" in sys.argv:
        run_planned()
    elif "--all" in sys.argv:
        run()
        run_planned()
    else:
        run()
