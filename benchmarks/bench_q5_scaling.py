"""Paper Figure 6: Q5 (join-heavy) across scale factors, ICI vs host
exchange — the protocol ratio must hold as data grows."""

from __future__ import annotations

from repro.core import HostExchange, ICIExchange, Session
from repro.tpch import dbgen, queries

from .common import emit, timeit


def run():
    for sf in (0.001, 0.002, 0.004):
        catalog = dbgen.load_catalog(sf=sf)
        plan = queries.build_query(5, catalog)
        times = {}
        for name, ex in (("ici", ICIExchange()), ("host", HostExchange())):
            session = Session(catalog, num_workers=4, exchange=ex,
                              batch_rows=16384)
            times[name] = timeit(lambda: session.execute(plan),
                                 warmup=1, iters=2)
            emit(f"fig6_q5_sf{sf}_{name}", times[name],
                 f"staged_B={ex.stats.host_staged_bytes}")
        emit(f"fig6_q5_sf{sf}_ratio", times["host"],
             f"ratio={times['host'] / times['ici']:.2f}x")


if __name__ == "__main__":
    run()
