"""Out-of-core TPC-H: slowdown vs device-memory budget.

Theseus-style claim (PAPERS.md): a tiered memory hierarchy lets a query
whose working set is several times device memory complete with *bounded*
slowdown instead of failing admission. This suite shrinks the device
budget to 1/2, 1/4, and 1/8 of each query's *observed* device-reservation
peak (measured once under an unbounded spill manager, so the fractions
bind at any scale factor) and runs
a join/aggregation-heavy TPC-H subset through the spill subsystem
(``core.spill``): grace-partitioned joins, flushing aggregations, staged
exchanges. Every run is validated against the numpy oracle, and the
reported curve includes the per-tier spilled bytes -- a row with zero
spilled bytes at a fractional budget would mean the budget never bound.
"""

from __future__ import annotations

import time

from repro.core import Session
from repro.core.optimizer import estimate_memory
from repro.tpch import dbgen, oracle, queries

from .common import emit
from .bench_concurrency import _assert_oracle

# join-heavy (3, 18), high-cardinality agg (13), multi-join (5)
QUERY_SET = (3, 5, 13, 18)
# None = unbounded (in-memory baseline); k = device budget = peak // k
BUDGET_DIVISORS = (None, 2, 4, 8)


def _estimate(session: Session, plan) -> int:
    return estimate_memory(session.optimize(plan), session.catalog,
                           num_workers=session.num_workers,
                           batch_rows=session.batch_rows,
                           prefetch_depth=session.prefetch_depth)


def _observed_peak(catalog, plan) -> int:
    """Run once under an unbounded spill manager and report the true
    high-water mark of operator device reservations -- the static
    ``estimate_memory`` figure is deliberately conservative (prefetch
    windows, capacity bounds), so fractions of it may never bind."""
    session = Session(catalog, num_workers=1, batch_rows=8192,
                      device_budget=1 << 40)
    session.execute(plan)
    spill = (session.executor_stats() or {}).get("spill", {})
    return max(int(spill.get("reserved_peak", 0)), 1)


def run(sf: float = 0.01) -> None:
    catalog = dbgen.load_catalog(sf=sf)
    data = dbgen.generate(sf=sf)
    oracles = {q: oracle.ORACLES[q](data) for q in QUERY_SET}
    plans = {q: queries.build_query(q, catalog) for q in QUERY_SET}
    probe = Session(catalog, num_workers=1, batch_rows=8192)
    estimates = {q: _estimate(probe, plans[q]) for q in QUERY_SET}
    footprints = {q: _observed_peak(catalog, plans[q]) for q in QUERY_SET}

    # warm jit caches: every (query, budget) pair compiles its own
    # programs (grace partition shapes depend on the budget), so warm
    # each divisor's exact budget once before timing
    for q in QUERY_SET:
        Session(catalog, num_workers=1, batch_rows=8192).execute(plans[q])
        for divisor in BUDGET_DIVISORS:
            if divisor is None:
                continue
            Session(catalog, num_workers=1, batch_rows=8192,
                    device_budget=max(footprints[q] // divisor, 1024)
                    ).execute(plans[q])

    baseline_s: dict = {}
    for divisor in BUDGET_DIVISORS:
        total_s = 0.0
        total_spilled = 0
        total_disk = 0
        per_query: dict = {}
        for q in QUERY_SET:
            budget = (None if divisor is None
                      else max(footprints[q] // divisor, 1024))
            session = Session(catalog, num_workers=1, batch_rows=8192,
                              device_budget=budget)
            t0 = time.perf_counter()
            res = session.execute(plans[q])
            dt = time.perf_counter() - t0
            _assert_oracle(res, oracles[q], q)
            spill = (session.executor_stats() or {}).get("spill", {})
            spilled = spill.get("spilled_bytes", 0)
            disk = spill.get("disk", {}).get("spilled_bytes", 0)
            total_s += dt
            total_spilled += spilled
            total_disk += disk
            per_query[f"q{q}"] = {
                "seconds": dt, "device_budget": budget,
                "observed_peak": footprints[q],
                "estimated_footprint": estimates[q],
                "spilled_bytes": spilled,
                "disk_spilled_bytes": disk,
                "slowdown": (dt / baseline_s[q] if divisor is not None
                             else 1.0),
            }
            if divisor is None:
                baseline_s[q] = dt
        label = "inf" if divisor is None else f"1of{divisor}"
        slowdown = (1.0 if divisor is None
                    else total_s / sum(baseline_s.values()))
        if divisor is not None and divisor >= 4:
            assert total_spilled > 0, \
                f"budget footprint/{divisor} never bound -- nothing spilled"
        emit(f"outofcore_budget_{label}", total_s,
             derived=f"{slowdown:.2f}x_slowdown",
             detail={
                 "sf": sf,
                 "budget_divisor": divisor,
                 "total_seconds": total_s,
                 "slowdown_vs_unbounded": slowdown,
                 "spilled_bytes": total_spilled,
                 "disk_spilled_bytes": total_disk,
                 "queries": per_query,
             })
        print(f"# budget={label:>5}: {total_s:.2f}s "
              f"({slowdown:.2f}x vs in-memory) | spilled "
              f"{total_spilled / 1e6:.1f} MB (disk {total_disk / 1e6:.1f} MB)",
              flush=True)


if __name__ == "__main__":
    run()
