"""Adaptive execution: cold-vs-warm replan latency + kernel-residency.

Each query runs on a pallas-backend session with a shared feedback store:
the cold run plans from static catalog bounds (oversized capacities push
the hot aggregations/joins onto the jnp fallback), the warm run re-plans
from the cold run's observed cardinalities. Reported per query:

* cold and warm wall time (the warm figure includes the re-optimize, so
  the speedup is end-to-end, not just kernel time);
* jnp-fallback dispatch counts cold vs warm — the number adaptive
  re-planning exists to drive down;
* for the warm run's segmented-sum shape, the achieved fraction of the
  roofline bound (``launch.roofline``): the kernel's FLOPs/bytes from the
  compiled program's cost analysis against the TPU v5e peak terms. On CPU
  containers (interpret mode) the fraction is tiny; on a real TPU it
  tracks how close the warm dispatch runs to the §3.2 ceiling.

The scale factor matters: at the default 0.02 the static lineitem-side
aggregation bounds exceed the pallas group-capacity limit, so cold runs
genuinely fall back and the warm delta is visible. ``--sf`` overrides
(the CI smoke run shrinks it; fallback deltas then fade to zero).
"""

from __future__ import annotations

from .common import emit, timeit

# queries whose static bounds overflow pallas capacities at sf=0.02 (the
# warm replan brings every one of them back onto the kernels)
QUERIES = (3, 9, 10, 18)


def _fallbacks(stats) -> int:
    kd = stats.get("kernel_dispatch") or {}
    return sum(v for k, v in kd.items() if k.startswith("fallback"))


def _roofline_fraction(num_rows: int, num_groups: int) -> dict:
    """Achieved roofline fraction for the warm-shape segmented sum,
    via the shared ``launch.roofline.measure_program`` report."""
    import jax.numpy as jnp

    from repro.kernels import ops as kernel_ops
    from repro.launch import roofline

    gids = jnp.arange(num_rows, dtype=jnp.int32) % max(num_groups, 1)
    vals = jnp.ones((num_rows,), dtype=jnp.float32)
    report = roofline.measure_program(
        lambda g, v: kernel_ops.segmented_sum(g, v, num_groups),
        gids, vals)
    return {"rows": num_rows, "groups": num_groups, **report}


def run(sf: float = 0.02) -> None:
    from repro.core import Session
    from repro.core import plan as P
    from repro.tpch import dbgen, queries

    catalog = dbgen.load_catalog(sf=sf)
    for qnum in QUERIES:
        session = Session(catalog, feedback=True, kernel_backend="pallas")
        q = queries.build_query(qnum, catalog)

        cold_s = timeit(lambda: session.execute(session.optimize(q)),
                        warmup=0, iters=1)
        cold_fb = _fallbacks(session.executor_stats())
        # the store is seeded now: every further run is warm
        warm_s = timeit(lambda: session.execute(session.optimize(q)),
                        warmup=1, iters=3)
        warm_fb = _fallbacks(session.executor_stats())

        warm_plan = session.optimize(q)
        groups = [n.max_groups for n in _walk(warm_plan, P)
                  if isinstance(n, (P.Aggregation, P.Distinct))]
        roof = _roofline_fraction(
            num_rows=catalog.get("lineitem").num_rows(),
            num_groups=max(groups) if groups else 1)

        emit(f"adaptive_q{qnum}_cold_sf{sf}", cold_s,
             derived=f"fallbacks={cold_fb}")
        emit(f"adaptive_q{qnum}_warm_sf{sf}", warm_s,
             derived=(f"fallbacks={warm_fb} "
                      f"speedup={cold_s / warm_s:.2f}x "
                      f"roofline={roof['achieved_fraction']:.3f}"),
             detail={
                 "sf": sf,
                 "cold_seconds": cold_s,
                 "warm_seconds": warm_s,
                 "cold_fallbacks": cold_fb,
                 "warm_fallbacks": warm_fb,
                 "feedback": session.executor_stats()["feedback"],
                 "roofline": roof,
             })


def _walk(node, P):
    yield node
    for c in node.children():
        yield from _walk(c, P)


if __name__ == "__main__":
    run()
