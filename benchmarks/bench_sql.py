"""SQL frontend overhead: parse + lower + optimize vs the execution cost.

The frontend's promise is "SQL at near-zero marginal cost": ``Session.sql``
must add only microseconds of parse/lower work on top of the identical
plan the fluent builder produces. This bench measures, per TPC-H text:

* ``sql_parse``      -- SQL text -> AST (bundled recursive-descent parser)
* ``sql_lower``      -- text -> QueryBuilder (parse + schema-checked
                        lowering onto the builder)
* ``sql_optimize``   -- text -> optimized physical plan (lower + the full
                        rule pipeline; what a plan-cache miss costs)

and once overall the end-to-end ``sql_e2e_q6`` execution so the overhead
can be read as a fraction of runtime. Amortization across repeats is the
scheduler's plan/result cache (keyed by the SQL text, see
``core/scheduler.py``), measured in bench_concurrency.
"""

from __future__ import annotations

from .common import emit, timeit


def run(sf: float = 0.01) -> None:
    from repro.core import Session
    from repro.core.sqlast import parse as parse_sql
    from repro.tpch import dbgen, sqltext

    catalog = dbgen.load_catalog(sf=sf)
    session = Session(catalog)

    texts = {q: sqltext.sql_text(q, catalog)
             for q in (1, 3, 6, 18)}          # agg / join / scan / heavy

    for qnum, text in texts.items():
        t_parse = timeit(lambda: parse_sql(text), warmup=2, iters=20)
        t_lower = timeit(lambda: session.sql(text), warmup=2, iters=20)
        t_opt = timeit(lambda: session.optimize(session.sql(text).plan),
                       warmup=2, iters=10)
        emit(f"sql_parse_q{qnum}", t_parse)
        emit(f"sql_lower_q{qnum}", t_lower)
        emit(f"sql_optimize_q{qnum}", t_opt,
             detail={"sf": sf, "parse_s": t_parse, "lower_s": t_lower,
                     "optimize_s": t_opt, "chars": len(text)})

    t_exec = timeit(lambda: session.sql(texts[6]).collect(),
                    warmup=1, iters=3)
    t_lower6 = timeit(lambda: session.sql(texts[6]), warmup=2, iters=20)
    frac = t_lower6 / t_exec if t_exec else 0.0
    emit("sql_e2e_q6", t_exec, derived=f"lower_frac={frac:.4f}",
         detail={"sf": sf, "lower_s": t_lower6, "exec_s": t_exec,
                 "lower_fraction_of_runtime": frac})


if __name__ == "__main__":
    run()
