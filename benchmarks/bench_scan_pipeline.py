"""Morsel-driven streaming scan vs materialize-then-run (paper §2.2).

Two pipelines over a chunked ``ColumnChunkTable`` at several chunk counts
(the partition-count knob of paper Table 1):

* a Q1-shaped scan -> project -> group-aggregate pipeline (compute on par
  with I/O, the case streaming targets), executed three ways:
    - ``materialized``  drain the whole scan, concatenate every batch, then
                        run the operators once (I/O, transfer and compute
                        fully serialized; the seed driver's behavior)
    - ``streamed``      per-morsel operator execution, synchronous reads
    - ``prefetched``    per-morsel execution with the async double-buffered
                        storage->device prefetcher: the read + transfer of
                        morsel N+1 overlaps compute on morsel N
* a Q6-shaped selective scan measuring zone-map data skipping end-to-end:
  with the fact table clustered on ship date, chunks refuted by the pushed
  predicate are never read and never transferred.

Emits seconds per run plus prefetch-overlap fraction and chunks skipped
from the executor's ScanStats.
"""

from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro.core.expr import col, lit
from repro.core.operators import FilterProject, HashAggregation, Pipeline
from repro.core.streaming import ScanStats
from repro.core.table import concat_tables
from repro.storage import ColumnChunkTable, write_table
from repro.tpch import dbgen
from repro.tpch import schema as S

from .common import emit, timeit

Q1_COLS = ["l_shipdate", "l_returnflag", "l_linestatus", "l_quantity",
           "l_extendedprice", "l_discount", "l_tax"]
Q6_COLS = ["l_shipdate", "l_discount", "l_quantity", "l_extendedprice"]

# Expr objects hash by identity (their statics key the op jit cache), so
# predicates and pipelines are built once and reused: operators reset their
# state in open(), and re-building them per run would recompile every call.
Q1_PRED = col("l_shipdate") <= lit(10471)
_DISC = lit(1.0) - col("l_discount")
Q1_PIPE = Pipeline([
    FilterProject(Q1_PRED, [
        ("l_returnflag", col("l_returnflag")),
        ("l_linestatus", col("l_linestatus")),
        ("l_quantity", col("l_quantity")),
        ("l_extendedprice", col("l_extendedprice")),
        ("disc_price", col("l_extendedprice") * _DISC),
        ("charge", col("l_extendedprice") * _DISC * (lit(1.0) + col("l_tax"))),
        ("l_discount", col("l_discount")),
    ]),
    HashAggregation(["l_returnflag", "l_linestatus"],
                    [("sum_qty", "sum", "l_quantity"),
                     ("sum_base", "sum", "l_extendedprice"),
                     ("sum_disc_price", "sum", "disc_price"),
                     ("sum_charge", "sum", "charge"),
                     ("avg_disc", "avg", "l_discount"),
                     ("count_order", "count", None)], "single", 16),
])

Q6_PRED = ((col("l_shipdate") >= lit(8766)) & (col("l_shipdate") <= lit(9130))
           & (col("l_discount").between(lit(0.05), lit(0.07)))
           & (col("l_quantity") < lit(24.0)))
Q6_PIPE = Pipeline([
    FilterProject(Q6_PRED,
                  [("rev", col("l_extendedprice") * col("l_discount"))]),
    HashAggregation([], [("revenue", "sum", "rev")], "single", 1),
])


def _drain(pipe, batches, out_col):
    pipe.open()
    outs = []
    for b in batches:
        outs.extend(pipe.add_input(b))
    outs.extend(pipe.finish())
    jax.block_until_ready([t.columns[out_col] for t in outs])
    return outs


def run_materialized(src, cols, pred, pipe, out_col):
    batches = list(src.scan(1, cols, 1 << 20, filter_expr=pred))
    table = batches[0] if len(batches) == 1 else concat_tables(batches)
    return _drain(pipe, [table], out_col)


def run_streamed(src, cols, pred, pipe, out_col):
    return _drain(pipe, src.scan(1, cols, 1 << 20, filter_expr=pred), out_col)


def run_prefetched(src, cols, pred, pipe, out_col, stats: ScanStats):
    morsels = src.stream(1, cols, 1 << 20, filter_expr=pred,
                         prefetch_depth=2, stats=stats)
    return _drain(pipe, morsels, out_col)


def run(sf: float = 0.05, chunk_counts=(2, 8, 32), iters: int = 5):
    li = dbgen.generate(sf=sf)["lineitem"]
    order = np.argsort(li["l_shipdate"], kind="stable")
    li = {c: v[order] for c, v in li.items()}   # clustered layout (zone map)

    for chunks in chunk_counts:
        with tempfile.TemporaryDirectory() as root:
            write_table(root, "lineitem", li, S.LINEITEM, chunks=chunks)
            # streaming comparison with skipping off: every mode reads all
            # chunks, the difference is purely how I/O, transfer and
            # compute are scheduled
            src = ColumnChunkTable(root, "lineitem", skip_with_stats=False)

            t_mat = timeit(lambda: run_materialized(
                src, Q1_COLS, Q1_PRED, Q1_PIPE, "sum_qty"),
                warmup=1, iters=iters)
            t_str = timeit(lambda: run_streamed(
                src, Q1_COLS, Q1_PRED, Q1_PIPE, "sum_qty"),
                warmup=1, iters=iters)
            holder = {"stats": ScanStats()}

            def prefetched(source=src, cols=Q1_COLS, pred=Q1_PRED,
                           pipe=Q1_PIPE, out="sum_qty"):
                holder["stats"] = ScanStats()   # fresh stats per run
                run_prefetched(source, cols, pred, pipe, out,
                               holder["stats"])

            t_pre = timeit(prefetched, warmup=1, iters=iters)
            stats = holder["stats"]

            emit(f"scan_pipeline_materialized_c{chunks}", t_mat,
                 f"chunks={chunks}",
                 {"chunks": chunks, "rows": len(li["l_shipdate"])})
            emit(f"scan_pipeline_streamed_c{chunks}", t_str,
                 f"speedup={t_mat / t_str:.2f}x", {"chunks": chunks})
            emit(f"scan_pipeline_prefetched_c{chunks}", t_pre,
                 f"speedup={t_mat / t_pre:.2f}x;"
                 f"overlap={stats.prefetch_overlap:.2f}",
                 {"chunks": chunks, "stats": stats.summary()})

            # zone-map skipping end-to-end (selective Q6 predicate over the
            # clustered table): refuted chunks are never read, never moved
            skip_src = ColumnChunkTable(root, "lineitem")
            t_mat6 = timeit(lambda: run_materialized(
                ColumnChunkTable(root, "lineitem", skip_with_stats=False),
                Q6_COLS, Q6_PRED, Q6_PIPE, "revenue"),
                warmup=1, iters=iters)
            t_skip = timeit(lambda: prefetched(
                skip_src, Q6_COLS, Q6_PRED, Q6_PIPE, "revenue"),
                warmup=1, iters=iters)
            s = holder["stats"]
            emit(f"scan_pipeline_q6_materialized_c{chunks}", t_mat6,
                 f"chunks={chunks}", {"chunks": chunks})
            emit(f"scan_pipeline_q6_prefetch_skip_c{chunks}", t_skip,
                 f"speedup={t_mat6 / t_skip:.2f}x;"
                 f"chunks_skipped={s.chunks_skipped}/{s.chunks_total};"
                 f"bytes_read={s.bytes_read}",
                 {"chunks": chunks, "stats": s.summary()})


if __name__ == "__main__":
    run()
