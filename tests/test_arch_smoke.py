"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of the same family, run one forward + one train step + a
prefill/decode step on CPU, assert output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ShapeSpec, get_config
from repro.models import build_model
from repro.models.model import synthetic_batch

SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=64, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=64, global_batch=2,
                          kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=64, global_batch=2,
                         kind="decode")


@pytest.fixture(scope="module")
def models():
    return {a: build_model(get_config(a, smoke=True)) for a in ARCH_IDS}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, models):
    model = models[arch]
    batch = synthetic_batch(model, SMOKE_TRAIN)
    params = model.init(jax.random.key(0))
    logits, aux = jax.jit(model.forward)(params, batch)
    b = SMOKE_TRAIN.global_batch
    s_out = batch["labels"].shape[1]
    assert logits.shape == (b, s_out, model.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_and_finite_grads(arch, models):
    model = models[arch]
    batch = synthetic_batch(model, SMOKE_TRAIN)
    params = model.init(jax.random.key(1))

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(model.loss)(p, batch)
        # plain SGD nudge: loss on the same batch must drop
        p2 = jax.tree.map(lambda w, g: w - 0.3 * g.astype(w.dtype), p, grads)
        return loss, p2, grads

    loss0, params2, grads = step(params)
    assert bool(jnp.isfinite(loss0)), arch
    finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
    assert all(jax.tree.leaves(finite)), arch
    loss1 = jax.jit(model.loss)(params2, batch)
    assert float(loss1) < float(loss0), (arch, float(loss0), float(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistent_with_forward(arch, models):
    """Greedy decode logits from the cached path must match the
    full-sequence forward at the same position."""
    model = models[arch]
    cfg = model.cfg
    params = model.init(jax.random.key(2))
    b, s = 2, 32
    rng = np.random.default_rng(0)

    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)),
                             dtype=jnp.bfloat16)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, 4)), jnp.int32)
        full_logits, _ = jax.jit(model.forward)(
            params, {"frames": frames, "tokens": tokens})
        caches = model.prefill(params, {"frames": frames})
        x = tokens[:, :1]
        logits = None
        for pos in range(tokens.shape[1]):
            logits, caches = jax.jit(model.decode_step)(
                params, tokens[:, pos: pos + 1], caches, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, -1], np.float32), rtol=0.15, atol=0.15)
        return

    if cfg.embed_frontend_stub:
        pytest.skip("vlm backbone decode exercised via token path in dryrun")

    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full_logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})

    # incremental decode from an empty cache must reproduce the forward
    caches = model.init_caches(b, s)
    logits = None
    step = jax.jit(model.decode_step)
    for pos in range(s):
        logits, caches = step(params, tokens[:, pos: pos + 1], caches,
                              jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_full_config_matches_family(arch):
    """The analytic count on the FULL config lands in the advertised range
    (catches config typos without allocating the model)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen2_1_5b": (1.0e9, 2.2e9),
        "phi4_mini_3_8b": (3.0e9, 5.0e9),
        "granite_3_8b": (6.5e9, 10e9),
        "granite_34b": (30e9, 40e9),
        "pixtral_12b": (10e9, 14.5e9),
        "dbrx_132b": (110e9, 145e9),
        "deepseek_moe_16b": (13e9, 20e9),
        "xlstm_125m": (0.09e9, 0.2e9),
        "jamba_v0_1_52b": (45e9, 60e9),
        "seamless_m4t_large_v2": (1.2e9, 3.0e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, n)
