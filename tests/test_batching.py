"""Inter-query batching: eligibility, stacked-launch correctness, inertness.

Unmarked tests are tier-1 fast checks of the pure pieces: shape
extraction and program interning (``core.batch``), the stacked
group-capacity bound (``kernels.segmented_agg``), the scheduler's
per-program batch limit, and the disabled path's inertness contract
(``SchedulerConfig.batching=False`` must never touch batch state).

``@pytest.mark.batching`` tests are the runtime sweep (own CI job,
deselected from the default run via pyproject ``addopts``): the seeded
batched == serial property test, the incompatibility regressions
(snapshot versions, kernel backends, capacity overflow must degrade to
solo — never produce wrong results), and the batched small-query fuzz
corpus diffed against DuckDB (skips loudly without the ``[sql]`` extra).

Env knobs: ``BATCHING_SF`` (default 0.005), ``BATCHING_FUZZ_N``
(default 24), ``BATCHING_SEED`` (default 11).
"""

from __future__ import annotations

import functools
import os
import threading
import types

import numpy as np
import pytest

from repro.core import Session
from repro.core import batch as B
from repro.core import dtypes as dt
from repro.core import relational as rel
from repro.core.builder import QueryBuilder
from repro.core.expr import col
from repro.core.scheduler import SchedulerConfig
from repro.core.session import ExecutionOptions
from repro.kernels import segmented_agg as segagg
from repro.tpch import dbgen

from sql_oracle import (connect_with_catalog, diff_results,
                        fuzz_small_queries, require_duckdb, run_duckdb)

SF = float(os.environ.get("BATCHING_SF", "0.005"))
FUZZ_N = int(os.environ.get("BATCHING_FUZZ_N", "24"))
SEED = int(os.environ.get("BATCHING_SEED", "11"))


@functools.lru_cache(maxsize=1)
def dataset():
    return dbgen.generate(sf=SF), dbgen.load_catalog(sf=SF)


def _sched_config(**over) -> SchedulerConfig:
    base = dict(memory_budget=512 << 20, max_concurrency=4, max_queue=256,
                cache_results=False, batching=True, batch_window_ms=150.0,
                max_batch=32)
    base.update(over)
    return SchedulerConfig(**base)


def _workload(catalog, order_keys, n: int):
    """``n`` distinct-literal small queries cycling three batchable
    shapes (point lookup / filtered global agg / low-card group-by)."""
    out = []
    for i in range(n):
        kind = i % 3
        if kind == 0:
            key = int(order_keys[(i * 29) % len(order_keys)])
            out.append(QueryBuilder.scan(catalog, "orders")
                       .filter(col("o_orderkey") == key)
                       .project("o_orderkey", "o_totalprice"))
        elif kind == 1:
            out.append(QueryBuilder.scan(catalog, "lineitem")
                       .filter(col("l_quantity") < float(2 + (i % 47)))
                       .agg(total=("sum", "l_extendedprice"),
                            n=("count", None)))
        else:
            out.append(QueryBuilder.scan(catalog, "lineitem")
                       .filter(col("l_quantity") < float(3 + (i % 43)))
                       .group_by("l_returnflag")
                       .agg(total=("sum", "l_extendedprice"),
                            n=("count", None)))
    return out


def _submit_concurrently(session, builders, n_clients: int = 4):
    """Submit from client threads (so the batch window sees stragglers);
    returns handles in builder order."""
    handles: list = [None] * len(builders)
    errors: list = []

    def client(c: int):
        try:
            for i in range(c, len(builders), n_clients):
                handles[i] = session.submit(builders[i])
        except Exception as exc:  # noqa: BLE001 -- re-raised below
            errors.append(exc)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    session.gather(*handles)
    return handles


def _assert_columns_equal(ref: dict, got: dict, label: str) -> None:
    """Exact row identity for ints/keys; allclose for floats (the stacked
    one-hot contraction reduces in a different order than solo)."""
    assert set(ref) == set(got), f"{label}: column sets differ"
    for c in ref:
        r, g = np.asarray(ref[c]), np.asarray(got[c])
        assert r.shape == g.shape, f"{label}.{c}: {r.shape} != {g.shape}"
        if np.issubdtype(r.dtype, np.floating):
            np.testing.assert_allclose(g, r, rtol=1e-5, atol=1e-6,
                                       err_msg=f"{label}.{c}")
        else:
            np.testing.assert_array_equal(g, r, err_msg=f"{label}.{c}")


# ---------------------------------------------------------------------------
# tier-1: stacked group capacity (kernels.segmented_agg)
# ---------------------------------------------------------------------------

def test_stacked_group_capacity_bound():
    limit = segagg.STACKED_GROUP_LIMIT
    for mg in [1, 2, 3, 7, 16, 100, 4096, limit // 2, limit, limit + 1,
               limit * 4]:
        cap = segagg.stacked_group_capacity(mg)
        assert cap >= 1
        assert cap & (cap - 1) == 0, f"capacity {cap} not a power of two"
        if cap > 1:
            # the stacked problem must fit the kernel dispatch bound,
            # and cap is the largest power of two that does
            assert cap * mg <= limit
            assert 2 * cap > limit // mg
    # a query whose max_groups alone exceeds the limit degrades to solo
    assert segagg.stacked_group_capacity(limit + 1) == 1
    assert segagg.stacked_group_capacity(limit * 8) == 1
    with pytest.raises(ValueError):
        segagg.stacked_group_capacity(0)


def test_stacked_capacity_matches_kernel_limit():
    # hand-synced constant (kernels must not import core): a drift would
    # let a stacked problem exceed what the pallas kernels accept
    assert segagg.STACKED_GROUP_LIMIT == rel.PALLAS_AGG_GROUP_LIMIT


# ---------------------------------------------------------------------------
# tier-1: shape extraction + program interning (core.batch)
# ---------------------------------------------------------------------------

def test_extract_shape_interns_literal_variants():
    _, catalog = dataset()
    a = B.extract_shape(
        QueryBuilder.scan(catalog, "orders")
        .filter(col("o_orderkey") == 7)
        .project("o_orderkey", "o_totalprice").optimized())
    b = B.extract_shape(
        QueryBuilder.scan(catalog, "orders")
        .filter(col("o_orderkey") == 1953)
        .project("o_orderkey", "o_totalprice").optimized())
    assert a is not None and b is not None
    # literal-only variants intern to ONE program (the stacked compile
    # cache key); the literals come back as per-member parameters
    assert a.program is b.program
    assert a.params != b.params
    assert len(a.params) == len(a.program.param_dtypes) == 1


def test_extract_shape_eligible_aggregates():
    _, catalog = dataset()
    keyed = B.extract_shape(
        QueryBuilder.scan(catalog, "lineitem")
        .filter(col("l_quantity") < 5.0)
        .group_by("l_returnflag")
        .agg(total=("sum", "l_extendedprice"), n=("count", None),
             m=("avg", "l_discount")).optimized())
    assert keyed is not None
    assert keyed.program.group_keys == ("l_returnflag",)
    assert keyed.program.max_groups >= 1
    glob = B.extract_shape(
        QueryBuilder.scan(catalog, "lineitem")
        .filter(col("l_quantity") < 5.0)
        .agg(lo=("min", "l_extendedprice"),
             hi=("max", "l_extendedprice")).optimized())
    assert glob is not None
    assert glob.program.group_keys == ()


def test_extract_shape_rejects_unsupported_plans():
    _, catalog = dataset()
    li = QueryBuilder.scan(catalog, "lineitem").filter(col("l_quantity") < 5.0)
    orders = QueryBuilder.scan(catalog, "orders")
    assert B.extract_shape(
        li.join(orders, ["l_orderkey"], ["o_orderkey"])
        .agg(n=("count", None)).optimized()) is None
    assert B.extract_shape(
        li.project("l_orderkey").order_by("l_orderkey").optimized()) is None
    assert B.extract_shape(
        li.project("l_orderkey").limit(5).optimized()) is None
    assert B.extract_shape(
        li.distinct("l_returnflag").optimized()) is None


def test_batch_limit_caps_keyed_programs():
    _, catalog = dataset()
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    sch = session.scheduler()
    keyed = B.extract_shape(
        QueryBuilder.scan(catalog, "lineitem")
        .group_by("l_returnflag")
        .agg(n=("count", None)).optimized())
    assert sch._batch_limit(keyed.program) == min(
        sch.config.max_batch,
        segagg.stacked_group_capacity(keyed.program.max_groups))
    # keyless programs take the configured cap unmodified
    glob = types.SimpleNamespace(group_keys=(), max_groups=1)
    assert sch._batch_limit(glob) == sch.config.max_batch
    # capacity overflow (max_groups alone exceeds the kernel bound)
    # degrades to solo: a limit of 1 means no batch ever forms
    over = types.SimpleNamespace(group_keys=("k",),
                                 max_groups=rel.PALLAS_AGG_GROUP_LIMIT + 1)
    assert sch._batch_limit(over) == 1
    sch.close()


# ---------------------------------------------------------------------------
# tier-1: the disabled path is inert
# ---------------------------------------------------------------------------

def test_disabled_batching_is_inert():
    data, catalog = dataset()
    keys = np.asarray(data["orders"]["o_orderkey"])
    builders = _workload(catalog, keys, 6)
    refs = [Session(catalog, num_workers=1, batch_rows=16384).execute(
        b.optimized()) for b in builders]

    assert SchedulerConfig().batching is False   # opt-in by default
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config(batching=False)
    try:
        handles = _submit_concurrently(session, builders)
        stats = session.scheduler().stats()
        assert stats["batches"] == 0
        assert stats["batched_queries"] == 0
        for i, h in enumerate(handles):
            # the disabled path never inspects the plan for batchability
            assert h._batch_shape is None and h._batch_key is None
            assert "batch" not in h.executor_stats
            _assert_columns_equal(refs[i], h.result(), f"q{i}")
    finally:
        session.scheduler().close()


# ---------------------------------------------------------------------------
# -m batching: batched == serial property test
# ---------------------------------------------------------------------------

@pytest.mark.batching
def test_batched_equals_serial_property():
    """Seeded concurrent small-query workload through the batching
    scheduler must return the same rows as scheduler-less serial
    execution, and must actually form stacked launches."""
    data, catalog = dataset()
    keys = np.asarray(data["orders"]["o_orderkey"])
    builders = _workload(catalog, keys, 24)
    serial = Session(catalog, num_workers=1, batch_rows=16384)
    refs = [serial.execute(b.optimized()) for b in builders]

    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    try:
        handles = _submit_concurrently(session, builders)
        stats = session.scheduler().stats()
        assert stats["batches"] >= 1, "no stacked launch formed"
        assert stats["batched_queries"] >= 2
        batched = [h for h in handles if "batch" in h.executor_stats]
        assert len(batched) == stats["batched_queries"]
        for h in batched:
            b = h.executor_stats["batch"]
            assert b["size"] >= 2 and b["queue_delay_s"] >= 0.0
        for i, h in enumerate(handles):
            _assert_columns_equal(refs[i], h.result(), f"q{i}")
    finally:
        session.scheduler().close()


# ---------------------------------------------------------------------------
# -m batching: incompatibility regressions
# ---------------------------------------------------------------------------

@pytest.mark.batching
def test_snapshot_version_gates_compatibility():
    """Re-registering a table bumps its version; queries admitted across
    the bump share a program but must never share a stacked launch."""
    data, catalog = dataset()
    keys = np.asarray(data["orders"]["o_orderkey"])
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    try:
        q = _workload(catalog, keys, 1)[0]
        h1 = session.submit(q)
        r1 = h1.result()
        src = catalog.get("orders")
        catalog.register(src)          # same data, new version
        h2 = session.submit(q)
        r2 = h2.result()
        assert h1._batch_key == h2._batch_key       # same interned program
        assert h1._versions != h2._versions         # ...different snapshot
        _assert_columns_equal(r1, r2, "across-version")
    finally:
        session.scheduler().close()


@pytest.mark.batching
def test_backend_is_part_of_batch_key():
    data, catalog = dataset()
    keys = np.asarray(data["orders"]["o_orderkey"])
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    try:
        q = _workload(catalog, keys, 3)[2]          # keyed group-by
        h_jnp = session.submit(q)
        h_pal = session.submit(
            q, options=ExecutionOptions(kernel_backend="pallas"))
        r_jnp, r_pal = h_jnp.result(), h_pal.result()
        assert h_jnp._batch_key is not None and h_pal._batch_key is not None
        assert h_jnp._batch_key[0] is h_pal._batch_key[0]   # same program
        assert h_jnp._batch_key != h_pal._batch_key         # different key
        _assert_columns_equal(r_jnp, r_pal, "across-backend")
    finally:
        session.scheduler().close()


@pytest.mark.batching
def test_capacity_overflow_degrades_to_solo():
    """A keyed program whose ``max_groups`` alone exceeds the stacked
    kernel bound must run solo (no batch ever forms) and stay correct."""
    data, catalog = dataset()
    n = rel.PALLAS_AGG_GROUP_LIMIT + 100         # row bound > kernel limit
    rng = np.random.default_rng(3)
    wide = {"k": rng.integers(0, n, n).astype(np.int32),
            "v": rng.random(n).astype(np.float32)}
    catalog.register_numpy("wide_groups", wide,
                           {"k": dt.INT32, "v": dt.FLOAT32})
    serial = Session(catalog, num_workers=1, batch_rows=16384)

    def q(lo: float):
        return (QueryBuilder.scan(catalog, "wide_groups")
                .filter(col("v") > lo)
                .group_by("k").agg(total=("sum", "v"), cnt=("count", None)))

    builders = [q(0.1 + 0.01 * i) for i in range(3)]
    refs = [serial.execute(b.optimized()) for b in builders]
    shape = B.extract_shape(builders[0].optimized())
    assert shape is not None
    assert shape.program.max_groups > rel.PALLAS_AGG_GROUP_LIMIT

    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    try:
        assert session.scheduler()._batch_limit(shape.program) == 1
        handles = _submit_concurrently(session, builders, n_clients=3)
        stats = session.scheduler().stats()
        assert stats["batches"] == 0             # degraded to solo...
        for i, h in enumerate(handles):
            assert "batch" not in h.executor_stats
            _assert_columns_equal(refs[i], h.result(), f"q{i}")   # ...never wrong
    finally:
        session.scheduler().close()


# ---------------------------------------------------------------------------
# -m batching: small-query fuzz corpus vs DuckDB through the batched path
# ---------------------------------------------------------------------------

@pytest.mark.batching
def test_batched_fuzz_vs_duckdb():
    require_duckdb()
    _, catalog = dataset()
    con = connect_with_catalog(catalog)
    session = Session(catalog, num_workers=1, batch_rows=16384)
    session.scheduler_config = _sched_config()
    try:
        # each text twice: duplicates are compatible by construction, so
        # the sweep exercises stacked launches even when the random
        # corpus spreads across templates
        texts = fuzz_small_queries(SEED, FUZZ_N, catalog) * 2
        qbs = [session.sql(t) for t in texts]
        handles: list = [None] * len(qbs)

        def client(c: int, n_clients: int = 4):
            for i in range(c, len(qbs), n_clients):
                handles[i] = qbs[i].submit()

        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        session.gather(*handles)
        for text, qb, h in zip(texts, qbs, handles):
            diff_results(h.result(), run_duckdb(con, text),
                         qb.schema, sql=text)
    finally:
        session.scheduler().close()
        con.close()
