"""Unit-level properties of the exchange layer (paper §3.3–3.4).

Property-checked (seeded-random fallback when hypothesis is absent):

* repartition is a permutation of the valid rows — none lost, none
  duplicated — for arbitrary worker counts, validity patterns, and key
  skew (including empty inputs and all-rows-to-one-partition);
* hash-partition placement matches the host-side reference
  ``_hash_combine_np(keys) % W`` row for row;
* broadcast yields one identical replica of all valid rows per worker;
* ``HostExchange`` and ``ICIExchange`` agree on arbitrary tables.

Plus the latent empty-partition bug class (zero-capacity tables crashed
the ICI layout path) and the protocol-clone stats contract the scheduler
relies on (clones start zeroed; concurrent queries don't bleed stats).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dtypes as dt
from repro.core.table import DeviceTable
from repro.core.exchange import (ExchangeStats, HostExchange, ICIExchange,
                                 _hash_combine_np)

from _hypothesis_compat import ints, sampled, seeded_given

PROTOCOLS = (ICIExchange, HostExchange)


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

def make_table(seed: int, w: int, cap: int, key_mode: str,
               valid_mode: str) -> DeviceTable:
    """Worker-stacked [w, cap] table with controlled key skew/validity."""
    rng = np.random.default_rng(seed)
    if key_mode == "skew-one":            # every row hashes to one partition
        k = np.full((w, cap), 7, dtype=np.int32)
    elif key_mode == "few":               # handful of hot keys
        k = rng.integers(0, 3, (w, cap)).astype(np.int32)
    else:                                 # wide domain, negatives included
        k = rng.integers(-1000, 1000, (w, cap)).astype(np.int32)
    v = rng.random((w, cap)).astype(np.float32)
    if valid_mode == "none":
        valid = np.zeros((w, cap), dtype=bool)
    elif valid_mode == "one-worker":      # all data on worker 0
        valid = np.zeros((w, cap), dtype=bool)
        valid[0] = True
    else:
        valid = rng.random((w, cap)) < 0.7
    return DeviceTable({"k": jnp.asarray(k), "v": jnp.asarray(v)},
                       jnp.asarray(valid),
                       {"k": dt.INT32, "v": dt.FLOAT32})


def valid_rows(table: DeviceTable):
    """Sorted multiset of (key, value) pairs over all workers."""
    valid = np.asarray(table.validity)
    k = np.asarray(table.columns["k"])[valid]
    v = np.asarray(table.columns["v"])[valid]
    return sorted(zip(k.tolist(), v.tolist()))


def rows_per_worker(table: DeviceTable):
    """List (one entry per worker) of sorted (key, value) multisets."""
    valid = np.asarray(table.validity)
    k = np.asarray(table.columns["k"])
    v = np.asarray(table.columns["v"])
    return [sorted(zip(k[wk][valid[wk]].tolist(), v[wk][valid[wk]].tolist()))
            for wk in range(valid.shape[0])]


CONFIG = dict(seed=ints(0, 10_000), w=sampled(1, 2, 4),
              cap=sampled(1, 7, 64),
              key_mode=sampled("random", "few", "skew-one"),
              valid_mode=sampled("random", "none", "one-worker"))


# ---------------------------------------------------------------------------
# repartition properties
# ---------------------------------------------------------------------------

@seeded_given(max_examples=25, **CONFIG)
def test_repartition_is_permutation(seed, w, cap, key_mode, valid_mode):
    table = make_table(seed, w, cap, key_mode, valid_mode)
    want = valid_rows(table)
    for proto in PROTOCOLS:
        out = proto().repartition(table, ("k",), w)
        assert valid_rows(out) == want, proto.__name__


@seeded_given(max_examples=25, **CONFIG)
def test_repartition_placement_matches_host_hash(seed, w, cap, key_mode,
                                                 valid_mode):
    table = make_table(seed, w, cap, key_mode, valid_mode)
    for proto in PROTOCOLS:
        out = proto().repartition(table, ("k",), w)
        valid = np.asarray(out.validity)
        keys = np.asarray(out.columns["k"])
        for wk in range(w):
            got = keys[wk][valid[wk]]
            if got.size:
                pids = _hash_combine_np([got.astype(np.int32)]) % w
                assert (pids == wk).all(), (proto.__name__, wk)


@seeded_given(max_examples=15, **CONFIG)
def test_protocols_agree(seed, w, cap, key_mode, valid_mode):
    """Host-staged and device-native shuffles are observationally equal:
    same rows on the same workers (placement is defined by the hash)."""
    table = make_table(seed, w, cap, key_mode, valid_mode)
    ici = ICIExchange().repartition(table, ("k",), w)
    host = HostExchange().repartition(table, ("k",), w)
    assert rows_per_worker(ici) == rows_per_worker(host)


# ---------------------------------------------------------------------------
# broadcast properties
# ---------------------------------------------------------------------------

@seeded_given(max_examples=15, **CONFIG)
def test_broadcast_replicas_identical(seed, w, cap, key_mode, valid_mode):
    table = make_table(seed, w, cap, key_mode, valid_mode)
    want = valid_rows(table)
    for proto in PROTOCOLS:
        out = proto().broadcast(table, w)
        per_worker = rows_per_worker(out)
        assert len(per_worker) == w, proto.__name__
        for replica in per_worker:
            assert replica == want, proto.__name__


# ---------------------------------------------------------------------------
# empty-partition bug class: zero-capacity tables
# ---------------------------------------------------------------------------

def _zero_cap_table(w: int) -> DeviceTable:
    return DeviceTable({"k": jnp.zeros((w, 0), jnp.int32),
                        "v": jnp.zeros((w, 0), jnp.float32)},
                       jnp.zeros((w, 0), dtype=bool),
                       {"k": dt.INT32, "v": dt.FLOAT32})


def test_zero_capacity_repartition():
    """[W, 0] tables (everything filtered upstream) must shuffle cleanly:
    the ICI layout path used to crash in jnp.take on the empty row axis."""
    for w in (1, 2, 4):
        for proto in PROTOCOLS:
            out = proto().repartition(_zero_cap_table(w), ("k",), w)
            assert int(np.asarray(out.validity).sum()) == 0, proto.__name__
            # downstream operators need at least one row slot
            assert out.validity.shape[-1] >= 1, proto.__name__


def test_zero_capacity_broadcast():
    for w in (1, 2, 4):
        for proto in PROTOCOLS:
            out = proto().broadcast(_zero_cap_table(w), w)
            assert int(np.asarray(out.validity).sum()) == 0, proto.__name__
            assert out.validity.shape == (w, out.validity.shape[1])
            assert out.validity.shape[-1] >= 1, proto.__name__


# ---------------------------------------------------------------------------
# clone() stats contract (scheduler gives each query its own clone)
# ---------------------------------------------------------------------------

def test_clone_starts_with_zeroed_stats():
    table = make_table(0, 4, 32, "random", "random")
    for proto in PROTOCOLS:
        ex = proto()
        ex.repartition(table, ("k",), 4)
        ex.broadcast(table, 4)
        assert ex.stats.rounds > 0
        clone = ex.clone()
        assert clone.stats == ExchangeStats(), proto.__name__
        assert clone.stats is not ex.stats, proto.__name__
        # configuration is preserved
        if isinstance(ex, HostExchange):
            assert clone.page_rows == ex.page_rows
        else:
            assert clone.mesh is ex.mesh and clone.axis == ex.axis


def test_clone_stats_do_not_bleed_between_queries():
    """Two clones of one protocol accumulate independently and leave the
    original untouched (one clone per concurrent scheduler query)."""
    table_small = make_table(1, 2, 8, "random", "random")
    table_big = make_table(2, 2, 128, "random", "random")
    for proto in PROTOCOLS:
        parent = proto()
        a, b = parent.clone(), parent.clone()
        a.repartition(table_small, ("k",), 2)
        b.repartition(table_big, ("k",), 2)
        b.repartition(table_big, ("k",), 2)
        assert parent.stats == ExchangeStats(), proto.__name__
        assert a.stats.rounds == 1 and b.stats.rounds == 2, proto.__name__
        assert a.stats.bytes_moved != b.stats.bytes_moved or \
            a.stats.rows_moved != b.stats.rows_moved, proto.__name__


def test_scheduler_clones_isolate_per_query_exchange_stats():
    """End-to-end: concurrent scheduled queries each report their own
    exchange fragments; the session's template protocol stays zeroed."""
    from repro.core import Catalog, Session
    from repro.core.expr import col

    rng = np.random.default_rng(0)
    catalog = Catalog()
    catalog.register_numpy(
        "t", {"k": rng.integers(0, 50, 4096).astype(np.int32),
              "x": rng.random(4096).astype(np.float32)},
        {"k": dt.INT32, "x": dt.FLOAT32})
    template = ICIExchange()
    session = Session(catalog, num_workers=2, exchange=template,
                      batch_rows=1024)
    # distinct filters -> distinct fingerprints -> no coalescing
    handles = [
        session.submit(session.table("t")
                       .filter(col("k") >= 10 * i)
                       .group_by("k").agg(n=("count", None)))
        for i in range(3)
    ]
    session.gather(*handles)
    assert template.stats == ExchangeStats()
    for h in handles:
        frags = h.executor_stats["exchanges"]
        assert frags, "expected at least one exchange fragment per query"
        assert sum(f["rounds"] for f in frags.values()) > 0
        assert all(f["host_staged_bytes"] == 0 for f in frags.values())
    session.reset_scheduler()
