"""True multi-device distributed execution (subprocess with 8 host devices).

The main pytest process keeps the default single CPU device (per the
project's dry-run isolation rule); these tests re-exec python with
XLA_FLAGS=--xla_force_host_platform_device_count=8 and assert:

* query results over a real 8-device mesh match the oracle,
* the ICI exchange's data phase lowers to an all-to-all collective,
* the broadcast lowers to an all-gather,
* the host-staged exchange moves bytes through host memory.
"""

import os
import subprocess
import sys


_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_query_on_8_device_mesh_matches_oracle():
    out = _run(r"""
import jax
import numpy as np
assert jax.device_count() == 8, jax.devices()
mesh = jax.make_mesh((8,), ("workers",))
from repro.core import Session, ICIExchange
from repro.tpch import dbgen, queries, oracle
data = dbgen.generate(sf=0.002)
cat = dbgen.load_catalog(sf=0.002)
s = Session(cat, num_workers=8, exchange=ICIExchange(mesh=mesh),
            batch_rows=4096, mesh=mesh)
for q in (1, 5, 13):
    res = s.execute(queries.build_query(q, cat))
    orc = oracle.ORACLES[q](data)
    assert len(next(iter(res.values()))) == len(next(iter(orc.values()))), q
print("rows-match OK")
""")
    assert "rows-match OK" in out


def test_ici_exchange_lowers_to_all_to_all():
    out = _run(r"""
import jax
import jax.numpy as jnp
import numpy as np
mesh = jax.make_mesh((8,), ("workers",))
from repro.core import dtypes as dt
from repro.core.table import DeviceTable
from repro.core.exchange import ICIExchange, _partition_layout_table
ex = ICIExchange(mesh=mesh)
cap = 256
cols = {"k": jnp.zeros((8, cap), jnp.int32), "v": jnp.zeros((8, cap), jnp.float32)}
t = DeviceTable(cols, jnp.ones((8, cap), bool), {"k": dt.INT32, "v": dt.FLOAT32})
staged = _partition_layout_table(t, ("k",), 8, 64)
lowered = type(ex)._exchange_data.lower(ex, staged, 8, 64)
hlo = lowered.compile().as_text()
assert "all-to-all" in hlo, hlo[:3000]
print("a2a OK")

blow = type(ex)._broadcast_data.lower(ex, t, 8)
bhlo = blow.compile().as_text()
assert ("all-gather" in bhlo) or ("all-reduce" in bhlo), bhlo[:3000]
print("bcast OK")
""")
    assert "a2a OK" in out and "bcast OK" in out


def test_exchange_correctness_on_mesh():
    out = _run(r"""
import jax
import jax.numpy as jnp
import numpy as np
mesh = jax.make_mesh((8,), ("workers",))
from repro.core import dtypes as dt
from repro.core.table import DeviceTable
from repro.core.exchange import ICIExchange, HostExchange
rng = np.random.default_rng(0)
W, cap = 8, 128
k = rng.integers(0, 1000, (W, cap)).astype(np.int32)
v = rng.random((W, cap)).astype(np.float32)
t = DeviceTable({"k": jnp.asarray(k), "v": jnp.asarray(v)},
                jnp.ones((W, cap), bool), {"k": dt.INT32, "v": dt.FLOAT32})
for ex in (ICIExchange(mesh=mesh), HostExchange()):
    out = ex.repartition(t, ("k",), W)
    ov = np.asarray(out.validity)
    ok = np.asarray(out.columns["k"])
    # conservation: every row lands exactly once
    assert ov.sum() == W * cap, (type(ex).__name__, ov.sum())
    got = np.sort(ok[ov]); want = np.sort(k.reshape(-1))
    np.testing.assert_array_equal(got, want)
    # co-location: all rows with equal keys land on one worker
    owner = {}
    for w in range(W):
        for key in set(ok[w][ov[w]].tolist()):
            assert owner.setdefault(key, w) == w, (type(ex).__name__, key)
print("exchange-correct OK")
""")
    assert "exchange-correct OK" in out
