"""Roofline machinery tests: trip-count-weighted HLO parsing, collective
detection, term arithmetic."""

import jax
import jax.numpy as jnp

from repro.launch import hloparse, roofline as rf


def _layer(x, w):
    return jnp.tanh(x @ w), ()


def test_scan_body_weighted_by_trip_count():
    """XLA cost_analysis counts while bodies once; the parser must multiply
    by the trip count so scan == unrolled."""
    d, layers = 128, 8
    x = jax.ShapeDtypeStruct((32, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((layers, d, d), jnp.float32)

    def scanned(x, ws):
        y, _ = jax.lax.scan(_layer, x, ws)
        return y

    def unrolled(x, ws):
        for i in range(layers):
            x, _ = _layer(x, ws[i])
        return x

    analytic = layers * 2 * 32 * d * d
    for f in (scanned, unrolled):
        txt = jax.jit(f).lower(x, ws).compile().as_text()
        got = hloparse.analyze(txt)["flops"]
        assert got == analytic, (f.__name__, got, analytic)

    # and confirm cost_analysis alone UNDER-counts the scan (the bug the
    # parser exists to fix)
    ca = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
    # older jaxlibs return a one-element list of per-module dicts
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < analytic / 2


def test_nested_scan_weighting():
    d = 64

    def inner(x, w):
        return x @ w, ()

    def outer(x, ws):
        def body(c, wgroup):
            y, _ = jax.lax.scan(inner, c, wgroup)
            return y, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, d, d), jnp.float32)   # 15 layers total
    txt = jax.jit(outer).lower(x, ws).compile().as_text()
    got = hloparse.analyze(txt)["flops"]
    assert got == 15 * 2 * 8 * d * d


def test_collective_bytes_detected_on_mesh():
    import subprocess, sys, os
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = r"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hloparse
mesh = jax.make_mesh((8,), ("x",))
sh = NamedSharding(mesh, P("x"))
def f(a):
    return jax.lax.with_sharding_constraint(jnp.sum(a, axis=0), P())
a = jax.ShapeDtypeStruct((8, 128), jnp.float32)
with mesh:
    txt = jax.jit(f, in_shardings=sh).lower(a).compile().as_text()
r = hloparse.analyze(txt)
total = r["collective_total"]
assert total > 0, txt[:2000]
print("COLL_OK", total)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stderr
    assert "COLL_OK" in p.stdout


def test_roofline_terms_math():
    t = rf.roofline_terms(flops=197e12 * 256, bytes_accessed=819e9 * 256,
                          coll_bytes=50e9 * 256, chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    assert rf.dominant({"compute_s": 3, "memory_s": 2, "collective_s": 1}) \
        == "compute_s"


def test_model_flops_definitions():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("deepseek_moe_16b")
    train = rf.model_flops(cfg, SHAPES["train_4k"])
    # MoE: uses ACTIVE params only
    assert train == 6.0 * cfg.active_param_count() * SHAPES["train_4k"].tokens
    assert cfg.active_param_count() < 0.25 * cfg.param_count()
