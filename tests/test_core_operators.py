"""Unit + property tests for the core engine primitives and operators."""

import numpy as np
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import dtypes as dt
from repro.core import relational as rel
from repro.core import operators as ops
from repro.core.expr import col, prefix_code, year
from repro.core.table import DeviceTable, concat_tables


def _table(data, schema, capacity=None):
    return DeviceTable.from_numpy(data, schema, capacity)


# ---------------------------------------------------------------------------
# relational primitives
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=80),
       st.booleans())
def test_lexsort_single_key_matches_numpy(vals, desc):
    v = np.array(vals, dtype=np.int32)
    validity = np.ones(len(v), dtype=bool)
    order = np.asarray(rel.lexsort([jnp.asarray(v)], jnp.asarray(validity),
                                   [desc]))
    got = v[order]
    want = np.sort(v)[::-1] if desc else np.sort(v)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5)),
                min_size=1, max_size=60))
def test_lexsort_two_keys_stable(pairs):
    a = np.array([p[0] for p in pairs], dtype=np.int32)
    b = np.array([p[1] for p in pairs], dtype=np.int32)
    validity = np.ones(len(a), dtype=bool)
    order = np.asarray(rel.lexsort([jnp.asarray(a), jnp.asarray(b)],
                                   jnp.asarray(validity)))
    want = np.lexsort((b, a))   # numpy: last key is primary
    np.testing.assert_array_equal(order, want)


def test_lexsort_invalid_rows_last():
    v = np.array([5, 1, 3, 2], dtype=np.int32)
    validity = np.array([True, False, True, True])
    order = np.asarray(rel.lexsort([jnp.asarray(v)], jnp.asarray(validity)))
    assert order[-1] == 1          # the invalid row
    np.testing.assert_array_equal(v[order[:3]], [2, 3, 5])


def test_lexsort_descending_int32_min():
    """Regression: descending used to negate keys, and -INT32_MIN overflows
    back to INT32_MIN, sorting it first instead of last."""
    lo = np.iinfo(np.int32).min
    v = np.array([lo, 0, 5, lo, 7], dtype=np.int32)
    validity = np.ones(len(v), dtype=bool)
    order = np.asarray(rel.lexsort([jnp.asarray(v)], jnp.asarray(validity),
                                   [True]))
    np.testing.assert_array_equal(v[order], [7, 5, 0, lo, lo])


def test_lexsort_descending_negative_zero_stable():
    """Regression: descending no longer rewrites float keys (-0.0 -> 0.0);
    equal keys keep their original relative order."""
    f = np.array([-0.0, 1.0, 0.0, -1.0], dtype=np.float32)
    validity = np.ones(len(f), dtype=bool)
    order = np.asarray(rel.lexsort([jnp.asarray(f)], jnp.asarray(validity),
                                   [True]))
    assert f[order[0]] == 1.0 and f[order[-1]] == -1.0
    # the two zeros tie; stability keeps row 0 (-0.0) before row 2 (0.0)
    assert list(order[1:3]) == [0, 2]
    assert np.signbit(f[order[1]]) and not np.signbit(f[order[2]])


def test_lexsort_descending_bytes_key():
    """Descending over fixed-width bytes keys (multi-pass path)."""
    rows = ["bb", "aa", "cc", "ab"]
    data = dt.encode_bytes(rows, 2)
    validity = np.ones(len(rows), dtype=bool)
    order = np.asarray(rel.lexsort([jnp.asarray(data)], jnp.asarray(validity),
                                   [True]))
    assert [rows[i] for i in order] == ["cc", "bb", "ab", "aa"]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=100))
def test_group_rows_matches_numpy_unique(keys):
    k = np.array(keys, dtype=np.int32)
    validity = np.ones(len(k), dtype=bool)
    g = rel.group_rows([jnp.asarray(k)], jnp.asarray(validity), 16)
    assert int(g.num_groups) == len(np.unique(k))
    # every group's representative key is a real key
    reps = np.asarray(g.key_rows)[: int(g.num_groups)]
    assert set(k[reps].tolist()) == set(np.unique(k).tolist())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.floats(-100, 100)),
                min_size=1, max_size=100))
def test_segment_sum_matches_numpy(rows):
    k = np.array([r[0] for r in rows], dtype=np.int32)
    v = np.array([r[1] for r in rows], dtype=np.float32)
    validity = np.ones(len(k), dtype=bool)
    g = rel.group_rows([jnp.asarray(k)], jnp.asarray(validity), 8)
    sums = np.asarray(rel.segment_agg(jnp.asarray(v), g.gids, g.order,
                                      jnp.asarray(validity), 8, "sum"))
    uniq = np.unique(k)
    want = np.array([v[k == u].sum() for u in uniq], dtype=np.float32)
    got = {int(k[r]): s for r, s in zip(np.asarray(g.key_rows)[:len(uniq)],
                                        sums[:len(uniq)])}
    for u, w in zip(uniq, want):
        np.testing.assert_allclose(got[int(u)], w, rtol=1e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=60),
       st.lists(st.integers(0, 50), min_size=1, max_size=60))
def test_join_probe_matches_numpy(build, probe):
    bk = np.unique(np.array(build, dtype=np.int32))     # unique build side
    pk = np.array(probe, dtype=np.int32)
    bt = rel.join_build(jnp.asarray(bk), jnp.ones(len(bk), dtype=bool))
    res = rel.join_probe(bt, jnp.asarray(pk), jnp.ones(len(pk), dtype=bool), 1)
    matched = np.zeros(len(pk), dtype=bool)
    matched[np.asarray(res.probe_idx)[np.asarray(res.valid)]] = True
    np.testing.assert_array_equal(matched, np.isin(pk, bk))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=40),
       st.integers(2, 5))
def test_partition_layout_conserves_rows(keys, nparts):
    k = np.array(keys, dtype=np.int32)
    validity = np.ones(len(k), dtype=bool)
    pids = rel.partition_ids([jnp.asarray(k)], jnp.asarray(validity), nparts)
    cap = len(k)    # ample capacity -> nothing dropped
    gather, out_valid = rel.partition_layout(pids, jnp.asarray(validity),
                                             nparts, cap)
    assert int(np.asarray(out_valid).sum()) == len(k)
    got = np.sort(k[np.asarray(gather)[np.asarray(out_valid)]])
    np.testing.assert_array_equal(got, np.sort(k))
    # every row landed in the partition its hash says
    placed = np.asarray(gather).reshape(nparts, cap)
    valid2 = np.asarray(out_valid).reshape(nparts, cap)
    for p in range(nparts):
        rows = placed[p][valid2[p]]
        np.testing.assert_array_equal(np.asarray(pids)[rows], p)


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

_SCHEMA = {"k": dt.INT32, "v": dt.FLOAT32}


def test_filter_project_fused():
    t = _table({"k": np.arange(10), "v": np.arange(10, dtype=np.float32)},
               _SCHEMA)
    fp = ops.FilterProject(col("k") >= 5, [("doubled", col("v") * 2.0)])
    out = fp.add_input(t)[0]
    np.testing.assert_allclose(out.to_numpy()["doubled"],
                               np.arange(5, 10) * 2.0)


def test_streaming_aggregation_concat_based():
    """Paper §3.2: batch-wise partial agg + concat + re-aggregate."""
    agg = ops.HashAggregation(["k"], [("s", "sum", "v"), ("c", "count", None),
                                      ("m", "max", "v"), ("a", "avg", "v")],
                              mode="single", max_groups=8)
    agg.open()
    rng = np.random.default_rng(1)
    ks, vs = [], []
    for _ in range(5):   # five streamed batches
        k = rng.integers(0, 5, 64)
        v = rng.random(64).astype(np.float32)
        ks.append(k); vs.append(v)
        assert agg.add_input(_table({"k": k, "v": v}, _SCHEMA)) == []
    out = agg.finish()[0].to_numpy()
    k, v = np.concatenate(ks), np.concatenate(vs)
    order = np.argsort(out["k"])
    for i, u in enumerate(np.unique(k)):
        j = order[i]
        np.testing.assert_allclose(out["s"][j], v[k == u].sum(), rtol=1e-4)
        assert out["c"][j] == (k == u).sum()
        np.testing.assert_allclose(out["m"][j], v[k == u].max(), rtol=1e-6)
        np.testing.assert_allclose(out["a"][j], v[k == u].mean(), rtol=1e-4)


def test_partial_final_modes_compose():
    """Velox Partial/Final modes with an exchange in between."""
    rng = np.random.default_rng(2)
    k = rng.integers(0, 6, 256)
    v = rng.random(256).astype(np.float32)
    partial = ops.HashAggregation(["k"], [("a", "avg", "v")], "partial",
                                  max_groups=8)
    partial.open()
    partial.add_input(_table({"k": k[:128], "v": v[:128]}, _SCHEMA))
    p1 = partial.finish()[0]
    partial.open()
    partial.add_input(_table({"k": k[128:], "v": v[128:]}, _SCHEMA))
    p2 = partial.finish()[0]
    assert "a__sum" in p1.column_names and "a__cnt" in p1.column_names
    final = ops.HashAggregation(["k"], [("a", "avg", "v")], "final",
                                max_groups=8)
    final.open()
    final.add_input(concat_tables([p1, p2]))
    out = final.finish()[0].to_numpy()
    order = np.argsort(out["k"])
    for i, u in enumerate(np.unique(k)):
        np.testing.assert_allclose(out["a"][order[i]], v[k == u].mean(),
                                   rtol=1e-4)


def test_partial_emit_threshold_flow_control():
    agg = ops.HashAggregation(["k"], [("c", "count", None)], "partial",
                              max_groups=512, emit_rows=4)
    agg.open()
    emitted = []
    for i in range(4):
        k = np.arange(i * 8, i * 8 + 8)     # all-new groups each batch
        emitted += agg.add_input(_table({"k": k,
                                         "v": np.zeros(8, np.float32)},
                                        _SCHEMA))
    emitted += agg.finish()
    assert len(emitted) >= 2                # streamed early at the threshold


def test_join_types_against_numpy():
    rng = np.random.default_rng(3)
    bk = np.unique(rng.integers(0, 40, 30)).astype(np.int32)
    bp = (bk * 10).astype(np.int32)
    pk = rng.integers(0, 40, 100).astype(np.int32)
    build = _table({"k": bk, "payload": bp}, {"k": dt.INT32, "payload": dt.INT32})
    probe = _table({"k": pk, "v": np.zeros(100, np.float32)}, _SCHEMA)

    for jt in ("inner", "left_semi", "left_anti"):
        j = ops.HashJoin(["k"], ["k"], ["payload"] if jt == "inner" else (),
                         join_type=jt)
        j.add_build(build)
        j.seal_build()
        out = j.add_input(probe)[0].to_numpy()
        m = np.isin(pk, bk)
        if jt == "inner":
            np.testing.assert_array_equal(np.sort(out["k"]), np.sort(pk[m]))
            np.testing.assert_array_equal(out["payload"], out["k"] * 10)
        elif jt == "left_semi":
            np.testing.assert_array_equal(np.sort(out["k"]), np.sort(pk[m]))
        else:
            np.testing.assert_array_equal(np.sort(out["k"]), np.sort(pk[~m]))


def test_join_expansion_one_to_many():
    build = _table({"k": np.array([1, 1, 1, 2], np.int32),
                    "p": np.array([10, 11, 12, 20], np.int32)},
                   {"k": dt.INT32, "p": dt.INT32})
    probe = _table({"k": np.array([1, 2, 3], np.int32),
                    "v": np.zeros(3, np.float32)}, _SCHEMA)
    j = ops.HashJoin(["k"], ["k"], ["p"], max_matches=4)
    j.add_build(build)
    j.seal_build()
    out = j.add_input(probe)[0].to_numpy()
    assert sorted(out["p"].tolist()) == [10, 11, 12, 20]


def test_left_outer_join_matched_flag():
    build = _table({"k": np.array([1], np.int32), "p": np.array([9], np.int32)},
                   {"k": dt.INT32, "p": dt.INT32})
    probe = _table({"k": np.array([1, 2], np.int32),
                    "v": np.zeros(2, np.float32)}, _SCHEMA)
    j = ops.HashJoin(["k"], ["k"], ["p"], join_type="left_outer")
    j.add_build(build)
    j.seal_build()
    out = j.add_input(probe)[0].to_numpy()
    by_k = dict(zip(out["k"].tolist(),
                    zip(out["p"].tolist(), out["__matched"].tolist())))
    assert by_k[1] == (9, True)
    assert by_k[2] == (0, False)


def test_orderby_limit_and_descending():
    t = _table({"k": np.array([3, 1, 2, 5, 4], np.int32),
                "v": np.array([1, 2, 3, 4, 5], np.float32)}, _SCHEMA)
    ob = ops.OrderBy(["k"], [True], limit=3)
    ob.open()
    ob.add_input(t)
    out = ob.finish()[0].to_numpy()
    np.testing.assert_array_equal(out["k"], [5, 4, 3])


def test_compact_moves_valid_rows_front():
    t = _table({"k": np.arange(8), "v": np.zeros(8, np.float32)}, _SCHEMA)
    t = t.filter(jnp.asarray(np.array([0, 1, 0, 1, 1, 0, 0, 1], bool)))
    c = t.compact()
    assert bool(c.validity[:4].all()) and not bool(c.validity[4:].any())
    np.testing.assert_array_equal(np.asarray(c.columns["k"][:4]), [1, 3, 4, 7])


# ---------------------------------------------------------------------------
# expressions
# ---------------------------------------------------------------------------

def test_year_expr_exact_on_boundaries():
    days = np.array([dt.date_to_i32(s) for s in
                     ("1992-01-01", "1992-12-31", "1996-02-29", "1998-08-02")],
                    dtype=np.int32)
    t = _table({"d": days}, {"d": dt.DATE32})
    got = np.asarray(year(col("d")).evaluate(t))
    np.testing.assert_array_equal(got, [1992, 1992, 1996, 1998])


def test_prefix_code():
    phones = dt.encode_bytes(["13-555", "31-123", "07-999"], 15)
    t = _table({"p": phones}, {"p": dt.bytes_(15)})
    got = np.asarray(prefix_code(col("p"), 2).evaluate(t))
    np.testing.assert_array_equal(got, [13, 31, 7])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.text(alphabet="abcx y", min_size=0, max_size=20),
                min_size=1, max_size=30),
       st.text(alphabet="abc", min_size=1, max_size=3))
def test_contains_property(strings, needle):
    width = 24
    data = dt.encode_bytes(strings, width)
    t = _table({"s": data}, {"s": dt.bytes_(width)})
    got = np.asarray(col("s").contains(needle).evaluate(t))
    want = np.array([needle in s[:width] for s in strings])
    np.testing.assert_array_equal(got, want)


def test_multi_part_contains_ordered():
    data = dt.encode_bytes(["xx special yy requests", "requests special",
                            "specialrequests", "nothing"], 24)
    t = _table({"s": data}, {"s": dt.bytes_(24)})
    got = np.asarray(col("s").contains("special", "requests").evaluate(t))
    np.testing.assert_array_equal(got, [True, False, True, False])
