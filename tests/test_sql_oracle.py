"""SQL cross-engine differential sweeps (``-m sql_oracle``; own CI job).

Three layers, all driven from *SQL text* through ``Session.sql``:

* the 20 ported TPC-H texts vs the hand-written numpy oracle on every
  backend mode -- streaming single-worker, distributed W=2 (ICI
  exchange), and the pallas kernel backend (interpret mode off-TPU);
* the same texts vs in-process DuckDB (row counts + per-column
  checksums, ``tests/sql_oracle.py``) across the same three modes;
* a seeded SQL fuzzer over the TPC-H schema diffed against DuckDB --
  plan shapes TPC-H never exercises.

DuckDB layers skip loudly when the ``[sql]`` extra is not installed; the
numpy-oracle sweeps always run. Checksums accumulate into
``results/sql_oracle/checksums_<mode>.json`` (the CI artifact).

Env knobs: ``SQL_ORACLE_SF`` (default 0.002), ``SQL_ORACLE_FUZZ_N``
(default 24), ``SQL_ORACLE_SEED`` (default 7).
"""

import json
import os
import pathlib

import pytest

from repro.core import ICIExchange, Session
from repro.tpch import dbgen, oracle, sqltext

from sql_oracle import (HAVE_DUCKDB, SqlMismatch, check_sql,
                        connect_with_catalog, fuzz_queries, require_duckdb,
                        run_duckdb)
from tpch_util import assert_results_match

pytestmark = pytest.mark.sql_oracle

SF = float(os.environ.get("SQL_ORACLE_SF", "0.002"))
FUZZ_N = int(os.environ.get("SQL_ORACLE_FUZZ_N", "24"))
SEED = int(os.environ.get("SQL_ORACLE_SEED", "7"))

MODES = ["streaming", "w2", "pallas"]

_checksums = {m: {} for m in MODES}


@pytest.fixture(scope="module")
def data():
    return dbgen.generate(sf=SF)


@pytest.fixture(scope="module")
def catalog():
    return dbgen.load_catalog(sf=SF)


def _session(catalog, mode: str) -> Session:
    if mode == "w2":
        return Session(catalog, num_workers=2, exchange=ICIExchange(),
                       batch_rows=8192)
    if mode == "pallas":
        return Session(catalog, kernel_backend="pallas", batch_rows=16384)
    return Session(catalog, batch_rows=16384)


@pytest.fixture(scope="module")
def sessions(catalog):
    return {m: _session(catalog, m) for m in MODES}


@pytest.fixture(scope="module")
def duck(catalog):
    require_duckdb()
    con = connect_with_catalog(catalog)
    yield con
    con.close()


@pytest.fixture(scope="session", autouse=True)
def _dump_checksums():
    yield
    out = pathlib.Path("results/sql_oracle")
    out.mkdir(parents=True, exist_ok=True)
    for mode, sums in _checksums.items():
        if sums:
            (out / f"checksums_{mode}.json").write_text(
                json.dumps(sums, indent=2, sort_keys=True))


# ---------------------------------------------------------------------------
# TPC-H SQL texts vs the numpy oracle, all three backend modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("qnum", sqltext.SUPPORTED)
def test_tpch_sql_vs_numpy_oracle(qnum, mode, sessions, catalog, data):
    res = sessions[mode].sql(sqltext.sql_text(qnum, catalog)).collect()
    assert_results_match(res, oracle.ORACLES[qnum](data), qnum)


# ---------------------------------------------------------------------------
# TPC-H SQL texts vs DuckDB (row counts + per-column checksums)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("qnum", sqltext.SUPPORTED)
def test_tpch_sql_vs_duckdb(qnum, mode, sessions, catalog, duck):
    text = sqltext.sql_text(qnum, catalog)
    sums = check_sql(sessions[mode], duck, text)
    _checksums[mode][f"q{qnum}"] = sums


# ---------------------------------------------------------------------------
# seeded fuzz sweep vs DuckDB
# ---------------------------------------------------------------------------

def test_fuzz_vs_duckdb(sessions, catalog, duck):
    """Each fuzzed query runs three times: once on the plain streaming
    session, then twice on a session sharing one adaptive feedback store
    — the cold run seeds observed cardinalities, the warm run re-plans
    from them (tighter capacities, feedback-driven join sides), and both
    are checksum-diffed against DuckDB. Plan shapes TPC-H never exercises
    are exactly where an unsound warm bound would silently drop rows."""
    queries = fuzz_queries(SEED, FUZZ_N, catalog)
    adaptive = Session(catalog, batch_rows=16384, feedback=True)
    failures, skipped, checked = [], 0, 0
    for i, sql in enumerate(queries):
        ref = run_duckdb(duck, sql)
        if "cnt" in ref and len(ref["cnt"]) == 1 and ref["cnt"][0] == 0:
            # empty global aggregate: SQL NULL semantics vs the engine's
            # zero-initialized accumulators -- out of scope by design
            skipped += 1
            continue
        try:
            qb = sessions["streaming"].sql(sql)
            from sql_oracle import diff_results
            sums = diff_results(qb.collect(), ref, qb.schema, sql=sql)
            _checksums["streaming"][f"fuzz{i:03d}"] = sums
            for run in ("cold", "warm"):
                aqb = adaptive.sql(sql)
                diff_results(aqb.collect(), ref, aqb.schema,
                             sql=f"[feedback {run}] {sql}")
            checked += 1
        except SqlMismatch as exc:
            failures.append(str(exc))
    assert not failures, (
        f"{len(failures)}/{checked} fuzzed queries diverged from DuckDB:\n\n"
        + "\n\n".join(failures[:5]))
    # the sweep must actually exercise the engine, not skip its way green
    assert checked >= max(1, FUZZ_N // 2), \
        f"only {checked}/{FUZZ_N} fuzzed queries were comparable"
    # the adaptive pass must have fed the planner real observations
    assert adaptive.executor_stats()["feedback"]["entries"] > 0


def test_duckdb_available_reporting():
    """Loud, greppable signal in CI logs about the optional dependency."""
    if not HAVE_DUCKDB:
        pytest.skip("duckdb is NOT installed -- the differential layers "
                    "above were skipped; install the [sql] extra")
