"""Pallas kernels vs ref.py oracles: shape/dtype sweeps + hypothesis
property tests, all in interpret mode on CPU. The ``seeded_given`` sweeps
exercise the public ``kernels.ops`` wrappers (the layer the engine
dispatches through) on the degenerate shapes the engine produces: empty
batches, all-invalid batches, multi-slab group counts, and probe tables
whose occupied runs exhaust ``max_probes``."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, ints, sampled, seeded_given, settings, st

from repro.kernels import ops, ref
from repro.kernels.block_prefix_sum import block_prefix_sum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash_probe import build_table, hash_probe
from repro.kernels.radix_histogram import radix_histogram
from repro.kernels.segmented_agg import GROUP_BLOCK, segmented_sum


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 2, 256, 64),
                                     (1, 2, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, s, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_sweep():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    want = ref.flash_attention(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# segmented aggregation
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.floats(-10, 10)),
                min_size=1, max_size=300),
       st.sampled_from([8, 64, 200]))
def test_segmented_sum_property(rows, row_block):
    gids = jnp.asarray([r[0] for r in rows], jnp.int32)
    vals = jnp.asarray([r[1] for r in rows], jnp.float32)
    got = segmented_sum(gids, vals, 41, row_block=row_block, interpret=True)
    want = ref.segmented_agg(gids, vals, 41, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_segmented_sum_multi_slab():
    # more groups than one GROUP_BLOCK slab
    rng = np.random.default_rng(2)
    n, g = 5000, 2500
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = segmented_sum(gids, vals, g, interpret=True)
    want = ref.segmented_agg(gids, vals, g, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# radix histogram
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=500),
       st.sampled_from([16, 32]))
def test_radix_histogram_property(pids, nparts):
    p = jnp.asarray(pids, jnp.int32)
    got = radix_histogram(p, nparts, row_block=128, interpret=True)
    want = ref.radix_histogram(p, nparts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("table_size,n_keys,n_probes",
                         [(64, 30, 100), (256, 200, 500), (1024, 100, 64)])
def test_hash_probe_matches_ref(table_size, n_keys, n_probes):
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.choice(10_000, n_keys, replace=False), jnp.int32)
    vals = keys * 7
    tk, tv = build_table(keys, vals, table_size)
    probes = jnp.asarray(rng.integers(0, 10_000, n_probes), jnp.int32)
    got_f, got_v = hash_probe(tk, tv, probes, max_probes=table_size,
                              probe_block=64, interpret=True)
    want_f, want_v = ref.hash_probe(tk, tv, probes, empty_key=-1)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(got_f)],
                                  np.asarray(want_v)[np.asarray(want_f)])
    # semantic check against plain membership
    member = np.isin(np.asarray(probes), np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got_f), member)
    np.testing.assert_array_equal(np.asarray(got_v)[member],
                                  np.asarray(probes)[member] * 7)


# ---------------------------------------------------------------------------
# block prefix sum (compaction addresses)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=600),
       st.sampled_from([64, 128, 256]))
def test_block_prefix_sum_property(mask, row_block):
    m = jnp.asarray(mask, jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=row_block, interpret=True)
    want_pos, want_total = ref.block_prefix_sum(m)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want_pos))
    assert int(total) == int(want_total)


def test_prefix_sum_crosses_blocks():
    m = jnp.ones((1000,), jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(pos), np.arange(1000))
    assert int(total) == 1000


# ---------------------------------------------------------------------------
# wrapper-vs-ref property sweeps (the kernels.ops dispatch surface)
# ---------------------------------------------------------------------------

def test_empty_inputs_all_wrappers():
    """Zero-row batches are legal engine states; every wrapper must return
    correctly shaped empties instead of dividing by a zero block count."""
    e_i = jnp.zeros((0,), jnp.int32)
    e_f = jnp.zeros((0,), jnp.float32)
    e_b = jnp.zeros((0,), jnp.bool_)
    out = ops.segmented_sum(e_i, e_f, 17)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(17))
    np.testing.assert_array_equal(
        np.asarray(ops.radix_histogram(e_i, 8)), np.zeros(8, np.int32))
    pos, total = ops.block_prefix_sum(e_b)
    assert pos.shape == (0,) and int(total) == 0
    tk, tv = ops.build_table(e_i, e_i, 16)
    assert int((np.asarray(tk) != -1).sum()) == 0
    found, vals = ops.hash_probe(tk, tv, e_i)
    assert found.shape == (0,) and vals.shape == (0,)


@seeded_given(max_examples=10, n=ints(1, 400), num_groups=sampled(8, 40, 130))
def test_all_invalid_rows_property(n, num_groups):
    """All-dropped inputs (every gid/pid out of range, every mask bit off,
    an empty probe table) aggregate to zero everywhere."""
    rng = np.random.default_rng(n * 1000 + num_groups)
    gids = jnp.asarray(
        rng.integers(num_groups, num_groups + 50, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.segmented_sum(gids, vals, num_groups)
    want = ref.segmented_agg(gids, vals, num_groups, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(num_groups))

    hist = ops.radix_histogram(gids, num_groups)  # every pid out of range
    np.testing.assert_array_equal(np.asarray(hist),
                                  np.zeros(num_groups, np.int32))

    mask = jnp.zeros((n,), jnp.bool_)
    pos, total = ops.block_prefix_sum(mask)
    want_pos, want_total = ref.block_prefix_sum(mask)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want_pos))
    assert int(total) == int(want_total) == 0

    # probe against a table with zero valid build rows: all miss
    keys = jnp.asarray(rng.integers(0, 1000, 16), jnp.int32)
    tk, tv = ops.build_table(keys, keys, 64,
                             valid=jnp.zeros((16,), jnp.bool_))
    found, _ = ops.hash_probe(tk, tv, keys)
    assert not bool(found.any())


@seeded_given(max_examples=8, n=ints(1, 4000),
              num_groups=sampled(GROUP_BLOCK + 1, 2 * GROUP_BLOCK,
                                 3 * GROUP_BLOCK + 7),
              row_block=sampled(128, 1024))
def test_multi_slab_groups_property(n, num_groups, row_block):
    """num_groups > GROUP_BLOCK forces >1 accumulation slab; the kernel
    must agree with the segment_sum oracle across the slab boundary."""
    rng = np.random.default_rng(n)
    gids = jnp.asarray(rng.integers(0, num_groups + 20, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.segmented_sum(gids, vals, num_groups, row_block=row_block)
    want = ref.segmented_agg(gids, vals, num_groups, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@seeded_given(max_examples=8, n_keys=ints(4, 500),
              table_pow=sampled(64, 256, 1024), max_probes=sampled(2, 4, 8))
def test_max_probes_exhaustion_property(n_keys, table_pow, max_probes):
    """An under-provisioned ``max_probes`` may miss keys parked deep in an
    occupied run but must never fabricate a match; once ``max_probes``
    covers the longest occupied run (+1 for the terminating empty slot)
    the probe agrees with the oracle exactly. This is the contract
    ``HashJoin`` relies on when it derives ``max_probes`` from the built
    table's occupancy."""
    n_keys = min(n_keys, table_pow // 2)     # load factor <= 1/2
    rng = np.random.default_rng(n_keys * table_pow)
    keys = jnp.asarray(rng.choice(100_000, n_keys, replace=False), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 1 << 30, n_keys), jnp.int32)
    tk, tv = ops.build_table(keys, vals, table_pow)
    probes = jnp.concatenate(
        [keys, jnp.asarray(rng.integers(0, 100_000, 200), jnp.int32)])
    want_f, want_v = ref.hash_probe(tk, tv, probes, empty_key=-1)

    # exhaustion: found-set is a subset of the oracle's, values agree on it
    got_f, got_v = ops.hash_probe(tk, tv, probes, max_probes=max_probes)
    got_f, got_v = np.asarray(got_f), np.asarray(got_v)
    assert not (got_f & ~np.asarray(want_f)).any()
    np.testing.assert_array_equal(got_v[got_f], np.asarray(want_v)[got_f])

    # sufficiency: the longest occupied run bounds the probe sequence
    occ = np.asarray(tk) != -1
    runs = np.diff(np.concatenate(
        ([0], np.roll(occ, len(occ) - 1 - int(np.where(~occ)[0][-1]))
         .astype(np.int8), [0])))
    longest = int((np.where(runs == -1)[0] - np.where(runs == 1)[0]).max()) \
        if occ.any() else 0
    got_f, got_v = ops.hash_probe(tk, tv, probes, max_probes=longest + 1)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(want_f)],
                                  np.asarray(want_v)[np.asarray(want_f)])


@seeded_given(max_examples=6, n_keys=ints(1, 300), dup=sampled(False, True))
def test_build_table_probe_invariant_property(n_keys, dup):
    """Any table the cooperative build produces must satisfy the linear
    probe invariant: every inserted key is reachable from its home slot
    through a gap-free occupied run (ref.hash_probe finds all of them)."""
    rng = np.random.default_rng(n_keys)
    table_size = 1024
    keys_np = rng.choice(5000, n_keys, replace=dup)
    keys = jnp.asarray(keys_np, jnp.int32)
    vals = jnp.arange(n_keys, dtype=jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, n_keys).astype(bool))
    tk, tv = ops.build_table(keys, vals, table_size, valid=valid)
    assert int((np.asarray(tk) != -1).sum()) == int(valid.sum())
    found, _ = ref.hash_probe(tk, tv, keys, empty_key=-1)
    # every valid key must be found (invalid-only keys may still be found
    # when a duplicate of them was valid)
    assert bool(np.asarray(found)[np.asarray(valid)].all())
