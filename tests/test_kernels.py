"""Pallas kernels vs ref.py oracles: shape/dtype sweeps + hypothesis
property tests, all in interpret mode on CPU. The ``seeded_given`` sweeps
exercise the public ``kernels.ops`` wrappers (the layer the engine
dispatches through) on the degenerate shapes the engine produces: empty
batches, all-invalid batches, multi-slab group counts, and probe tables
whose occupied runs exhaust ``max_probes``."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, ints, sampled, seeded_given, settings, st

from repro.kernels import ops, ref
from repro.kernels.block_prefix_sum import block_prefix_sum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash_probe import build_table, hash_probe
from repro.kernels.radix_histogram import radix_histogram
from repro.kernels.segmented_agg import GROUP_BLOCK, segmented_sum


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 2, 256, 64),
                                     (1, 2, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, s, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_sweep():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    want = ref.flash_attention(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# segmented aggregation
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.floats(-10, 10)),
                min_size=1, max_size=300),
       st.sampled_from([8, 64, 200]))
def test_segmented_sum_property(rows, row_block):
    gids = jnp.asarray([r[0] for r in rows], jnp.int32)
    vals = jnp.asarray([r[1] for r in rows], jnp.float32)
    got = segmented_sum(gids, vals, 41, row_block=row_block, interpret=True)
    want = ref.segmented_agg(gids, vals, 41, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_segmented_sum_multi_slab():
    # more groups than one GROUP_BLOCK slab
    rng = np.random.default_rng(2)
    n, g = 5000, 2500
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = segmented_sum(gids, vals, g, interpret=True)
    want = ref.segmented_agg(gids, vals, g, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# radix histogram
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=500),
       st.sampled_from([16, 32]))
def test_radix_histogram_property(pids, nparts):
    p = jnp.asarray(pids, jnp.int32)
    got = radix_histogram(p, nparts, row_block=128, interpret=True)
    want = ref.radix_histogram(p, nparts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("table_size,n_keys,n_probes",
                         [(64, 30, 100), (256, 200, 500), (1024, 100, 64)])
def test_hash_probe_matches_ref(table_size, n_keys, n_probes):
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.choice(10_000, n_keys, replace=False), jnp.int32)
    vals = keys * 7
    tk, tv = build_table(keys, vals, table_size)
    probes = jnp.asarray(rng.integers(0, 10_000, n_probes), jnp.int32)
    got_f, got_v = hash_probe(tk, tv, probes, max_probes=table_size,
                              probe_block=64, interpret=True)
    want_f, want_v = ref.hash_probe(tk, tv, probes, empty_key=-1)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(got_f)],
                                  np.asarray(want_v)[np.asarray(want_f)])
    # semantic check against plain membership
    member = np.isin(np.asarray(probes), np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got_f), member)
    np.testing.assert_array_equal(np.asarray(got_v)[member],
                                  np.asarray(probes)[member] * 7)


# ---------------------------------------------------------------------------
# block prefix sum (compaction addresses)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=600),
       st.sampled_from([64, 128, 256]))
def test_block_prefix_sum_property(mask, row_block):
    m = jnp.asarray(mask, jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=row_block, interpret=True)
    want_pos, want_total = ref.block_prefix_sum(m)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want_pos))
    assert int(total) == int(want_total)


def test_prefix_sum_crosses_blocks():
    m = jnp.ones((1000,), jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(pos), np.arange(1000))
    assert int(total) == 1000


# ---------------------------------------------------------------------------
# wrapper-vs-ref property sweeps (the kernels.ops dispatch surface)
# ---------------------------------------------------------------------------

def test_empty_inputs_all_wrappers():
    """Zero-row batches are legal engine states; every wrapper must return
    correctly shaped empties instead of dividing by a zero block count."""
    e_i = jnp.zeros((0,), jnp.int32)
    e_f = jnp.zeros((0,), jnp.float32)
    e_b = jnp.zeros((0,), jnp.bool_)
    out = ops.segmented_sum(e_i, e_f, 17)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(17))
    np.testing.assert_array_equal(
        np.asarray(ops.radix_histogram(e_i, 8)), np.zeros(8, np.int32))
    pos, total = ops.block_prefix_sum(e_b)
    assert pos.shape == (0,) and int(total) == 0
    tk, tv = ops.build_table(e_i, e_i, 16)
    assert int((np.asarray(tk) != -1).sum()) == 0
    found, vals = ops.hash_probe(tk, tv, e_i)
    assert found.shape == (0,) and vals.shape == (0,)


@seeded_given(max_examples=10, n=ints(1, 400), num_groups=sampled(8, 40, 130))
def test_all_invalid_rows_property(n, num_groups):
    """All-dropped inputs (every gid/pid out of range, every mask bit off,
    an empty probe table) aggregate to zero everywhere."""
    rng = np.random.default_rng(n * 1000 + num_groups)
    gids = jnp.asarray(
        rng.integers(num_groups, num_groups + 50, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.segmented_sum(gids, vals, num_groups)
    want = ref.segmented_agg(gids, vals, num_groups, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(num_groups))

    hist = ops.radix_histogram(gids, num_groups)  # every pid out of range
    np.testing.assert_array_equal(np.asarray(hist),
                                  np.zeros(num_groups, np.int32))

    mask = jnp.zeros((n,), jnp.bool_)
    pos, total = ops.block_prefix_sum(mask)
    want_pos, want_total = ref.block_prefix_sum(mask)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want_pos))
    assert int(total) == int(want_total) == 0

    # probe against a table with zero valid build rows: all miss
    keys = jnp.asarray(rng.integers(0, 1000, 16), jnp.int32)
    tk, tv = ops.build_table(keys, keys, 64,
                             valid=jnp.zeros((16,), jnp.bool_))
    found, _ = ops.hash_probe(tk, tv, keys)
    assert not bool(found.any())


@seeded_given(max_examples=8, n=ints(1, 4000),
              num_groups=sampled(GROUP_BLOCK + 1, 2 * GROUP_BLOCK,
                                 3 * GROUP_BLOCK + 7),
              row_block=sampled(128, 1024))
def test_multi_slab_groups_property(n, num_groups, row_block):
    """num_groups > GROUP_BLOCK forces >1 accumulation slab; the kernel
    must agree with the segment_sum oracle across the slab boundary."""
    rng = np.random.default_rng(n)
    gids = jnp.asarray(rng.integers(0, num_groups + 20, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = ops.segmented_sum(gids, vals, num_groups, row_block=row_block)
    want = ref.segmented_agg(gids, vals, num_groups, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@seeded_given(max_examples=8, n_keys=ints(4, 500),
              table_pow=sampled(64, 256, 1024), max_probes=sampled(2, 4, 8))
def test_max_probes_exhaustion_property(n_keys, table_pow, max_probes):
    """An under-provisioned ``max_probes`` may miss keys parked deep in an
    occupied run but must never fabricate a match; once ``max_probes``
    covers the longest occupied run (+1 for the terminating empty slot)
    the probe agrees with the oracle exactly. This is the contract
    ``HashJoin`` relies on when it derives ``max_probes`` from the built
    table's occupancy."""
    n_keys = min(n_keys, table_pow // 2)     # load factor <= 1/2
    rng = np.random.default_rng(n_keys * table_pow)
    keys = jnp.asarray(rng.choice(100_000, n_keys, replace=False), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 1 << 30, n_keys), jnp.int32)
    tk, tv = ops.build_table(keys, vals, table_pow)
    probes = jnp.concatenate(
        [keys, jnp.asarray(rng.integers(0, 100_000, 200), jnp.int32)])
    want_f, want_v = ref.hash_probe(tk, tv, probes, empty_key=-1)

    # exhaustion: found-set is a subset of the oracle's, values agree on it
    got_f, got_v = ops.hash_probe(tk, tv, probes, max_probes=max_probes)
    got_f, got_v = np.asarray(got_f), np.asarray(got_v)
    assert not (got_f & ~np.asarray(want_f)).any()
    np.testing.assert_array_equal(got_v[got_f], np.asarray(want_v)[got_f])

    # sufficiency: the longest occupied run bounds the probe sequence
    occ = np.asarray(tk) != -1
    runs = np.diff(np.concatenate(
        ([0], np.roll(occ, len(occ) - 1 - int(np.where(~occ)[0][-1]))
         .astype(np.int8), [0])))
    longest = int((np.where(runs == -1)[0] - np.where(runs == 1)[0]).max()) \
        if occ.any() else 0
    got_f, got_v = ops.hash_probe(tk, tv, probes, max_probes=longest + 1)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(want_f)],
                                  np.asarray(want_v)[np.asarray(want_f)])


# ---------------------------------------------------------------------------
# expansion probe (hash_probe_multi) + composite-key packing
# ---------------------------------------------------------------------------

def _multi_oracle(tk, tv, probes):
    """All table values per probe key, as sorted lists (the kernel emits
    build-row order, and table values are build row indices)."""
    tk, tv = np.asarray(tk), np.asarray(tv)
    return [sorted(tv[tk == p].tolist()) for p in np.asarray(probes)]


@seeded_given(max_examples=8, n_keys=ints(4, 200), dup_factor=sampled(1, 3, 6),
              max_matches=sampled(1, 2, 4, 8))
def test_expansion_probe_matches_oracle_property(n_keys, dup_factor,
                                                 max_matches):
    """With enough match capacity the expansion probe returns exactly the
    duplicate build rows per key (in ascending build-row order); with less,
    it returns a prefix — never a fabricated or repeated row."""
    rng = np.random.default_rng(n_keys * 31 + dup_factor)
    base = rng.choice(50_000, n_keys, replace=False)
    keys_np = np.repeat(base, rng.integers(1, dup_factor + 1, n_keys))
    table_size = 1024
    keys_np = keys_np[: table_size // 2]     # load factor <= 1/2
    keys = jnp.asarray(keys_np, jnp.int32)
    rows = jnp.arange(keys.shape[0], dtype=jnp.int32)
    tk, tv = ops.build_table(keys, rows, table_size)
    probes = jnp.asarray(np.concatenate(
        [base, rng.integers(0, 50_000, 64)]), jnp.int32)

    count, slots = ops.hash_probe_multi(tk, tv, probes, max_matches,
                                        max_probes=table_size)
    count, slots = np.asarray(count), np.asarray(slots)
    want = _multi_oracle(tk, tv, probes)
    for i, w in enumerate(want):
        got = slots[i, : count[i]].tolist()
        assert count[i] == min(len(w), max_matches), (i, count[i], w)
        # ascending build-row order == the oracle's sorted order, so the
        # capacity-clipped kernel keeps exactly the first-m prefix
        assert got == w[:max_matches], (i, got, w)


@seeded_given(max_examples=8, n_keys=ints(4, 200), max_probes=sampled(2, 4, 8))
def test_expansion_probe_exhaustion_subset_property(n_keys, max_probes):
    """The ⊆-contract under an under-provisioned ``max_probes``, mirroring
    the single-match exhaustion sweep: matches may be missed (a run longer
    than the probe budget) but never invented, and what is returned is a
    prefix of the oracle's match list."""
    rng = np.random.default_rng(n_keys * 7)
    table_size = 256
    keys_np = rng.choice(10_000, min(n_keys, table_size // 2), replace=True)
    keys = jnp.asarray(keys_np, jnp.int32)
    rows = jnp.arange(keys.shape[0], dtype=jnp.int32)
    tk, tv = ops.build_table(keys, rows, table_size)
    probes = jnp.asarray(np.concatenate(
        [keys_np, rng.integers(0, 10_000, 100)]), jnp.int32)

    count, slots = ops.hash_probe_multi(tk, tv, probes, 8,
                                        max_probes=max_probes)
    count, slots = np.asarray(count), np.asarray(slots)
    want = _multi_oracle(tk, tv, probes)
    for i, w in enumerate(want):
        got = slots[i, : count[i]].tolist()
        assert got == w[: len(got)], (i, got, w)   # prefix, never invented


def test_expansion_probe_sentinel_key_reports_bogus_match():
    """PR-5 sentinel regression, expansion mode: a probe key equal to the
    empty sentinel (-1) compares equal to empty slots inside the kernel
    and reports a bogus match — the documented contract is that callers
    mask it (``relational``'s probe paths zero the count for sentinel
    keys), so masked counts must agree with the oracle exactly."""
    keys = jnp.asarray([5, 5, 9], jnp.int32)
    rows = jnp.arange(3, dtype=jnp.int32)
    tk, tv = ops.build_table(keys, rows, 16)
    probes = jnp.asarray([-1, 5, 9, 12], jnp.int32)
    count, slots = ops.hash_probe_multi(tk, tv, probes, 4, max_probes=16)
    count = np.asarray(count)
    assert count[0] >= 1                     # the raw kernel's bogus hit
    masked = np.where(np.asarray(probes) == -1, 0, count)
    np.testing.assert_array_equal(masked, [0, 2, 1, 0])
    np.testing.assert_array_equal(np.sort(np.asarray(slots)[1, :2]), [0, 1])


@seeded_given(max_examples=10, n=ints(1, 300),
              ncols=sampled(2, 3), span_pow=sampled(4, 10, 16))
def test_packed_key_property(n, ncols, span_pow):
    """Composite-key packing (``relational.packed_key``): injective over
    in-window tuples, nonnegative (never the sentinel), decodable back to
    the original tuple, and exactly the sentinel for out-of-window rows."""
    from repro.core import relational as rel

    rng = np.random.default_rng(n * 100 + ncols + span_pow)
    pack, prod = [], 1
    for _ in range(ncols):
        lo = int(rng.integers(-50, 50))
        # keep the window product inside the int32 key lane — the same
        # eligibility bound operators._derive_pack enforces
        budget = (np.iinfo(np.int32).max) // prod
        span = int(rng.integers(1, min(1 << span_pow, budget) + 1))
        prod *= span
        pack.append((lo, span))
    cols_np = []
    for lo, span in pack:
        # mostly in-window values, with some out-of-window outliers
        c = rng.integers(lo, lo + span, n)
        out = rng.random(n) < 0.15
        c = np.where(out, rng.integers(lo - 100, lo + span + 100, n), c)
        cols_np.append(c.astype(np.int32))
    in_window = np.ones(n, bool)
    for c, (lo, span) in zip(cols_np, pack):
        in_window &= (c >= lo) & (c < lo + span)

    key = np.asarray(rel.packed_key(
        [jnp.asarray(c) for c in cols_np], tuple(pack)))

    # sentinel preservation: out-of-window rows pack to the empty sentinel,
    # in-window rows never do (they are nonnegative by construction)
    np.testing.assert_array_equal(key == -1, ~in_window)
    assert (key[in_window] >= 0).all()

    # round-trip: decode in-window keys back to the original tuples
    dec = key[in_window].astype(np.int64)
    decoded = []
    for lo, span in reversed(pack):
        decoded.append((dec % span + lo).astype(np.int32))
        dec //= span
    for c, d in zip(cols_np, reversed(decoded)):
        np.testing.assert_array_equal(d, c[in_window])

    # injectivity over in-window tuples
    tuples = {tuple(c[i] for c in cols_np) for i in range(n) if in_window[i]}
    assert len(np.unique(key[in_window])) == len(tuples)


def test_packed_key_fits_int32_lane():
    """Windows sized to the int32 budget pack without overflow: the
    largest in-window tuple maps to span1*span2 - 1."""
    from repro.core import relational as rel

    span1, span2 = 1 << 16, (1 << 15) - 1    # product < 2^31 - 1
    pack = ((0, span1), (0, span2))
    c1 = jnp.asarray([0, span1 - 1], jnp.int32)
    c2 = jnp.asarray([0, span2 - 1], jnp.int32)
    key = np.asarray(rel.packed_key([c1, c2], pack))
    assert key[0] == 0
    assert key[1] == span1 * span2 - 1       # the largest in-window tuple
    assert (key >= 0).all()


# ---------------------------------------------------------------------------
# integer / min-max accumulators
# ---------------------------------------------------------------------------

@seeded_given(max_examples=10, n=ints(1, 2000),
              num_groups=sampled(8, 200, GROUP_BLOCK + 5))
def test_segmented_int_sum_property(n, num_groups):
    """Int accumulator vs the int32 segment_sum oracle, including values
    past float32's exact-integer range (the reason the kernel exists)."""
    import jax

    rng = np.random.default_rng(n + num_groups)
    gids = jnp.asarray(rng.integers(0, num_groups + 10, n), jnp.int32)
    vals = jnp.asarray(rng.integers(-(1 << 24), 1 << 24, n), jnp.int32)
    got = ops.segmented_int_sum(gids, vals, num_groups)
    in_range = np.asarray(gids) < num_groups
    want = jax.ops.segment_sum(vals[jnp.asarray(in_range)],
                               gids[jnp.asarray(in_range)],
                               num_segments=num_groups)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segmented_int_sum_exact_past_2_24():
    gids = jnp.zeros((3,), jnp.int32)
    vals = jnp.asarray([1 << 24, 1, 1], jnp.int32)
    out = ops.segmented_int_sum(gids, vals, 2)
    assert int(np.asarray(out)[0]) == (1 << 24) + 2


@seeded_given(max_examples=10, n=ints(1, 2000),
              num_groups=sampled(8, 200, GROUP_BLOCK + 5),
              kind=sampled("min", "max"), floats=sampled(False, True))
def test_segmented_minmax_property(n, num_groups, kind, floats):
    """Min/max accumulators vs segment_min/max, floats and ints; empty
    groups hold the reduction identity on both sides."""
    import jax

    rng = np.random.default_rng(n * 3 + num_groups)
    gids = jnp.asarray(rng.integers(0, num_groups + 10, n), jnp.int32)
    if floats:
        vals = jnp.asarray(rng.normal(0, 100, n), jnp.float32)
    else:
        vals = jnp.asarray(rng.integers(-(1 << 30), 1 << 30, n), jnp.int32)
    got = ops.segmented_minmax(gids, vals, num_groups, kind)
    in_range = jnp.asarray(np.asarray(gids) < num_groups)
    seg = jax.ops.segment_min if kind == "min" else jax.ops.segment_max
    want = seg(vals[in_range], gids[in_range], num_segments=num_groups)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@seeded_given(max_examples=6, n_keys=ints(1, 300), dup=sampled(False, True))
def test_build_table_probe_invariant_property(n_keys, dup):
    """Any table the cooperative build produces must satisfy the linear
    probe invariant: every inserted key is reachable from its home slot
    through a gap-free occupied run (ref.hash_probe finds all of them)."""
    rng = np.random.default_rng(n_keys)
    table_size = 1024
    keys_np = rng.choice(5000, n_keys, replace=dup)
    keys = jnp.asarray(keys_np, jnp.int32)
    vals = jnp.arange(n_keys, dtype=jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, n_keys).astype(bool))
    tk, tv = ops.build_table(keys, vals, table_size, valid=valid)
    assert int((np.asarray(tk) != -1).sum()) == int(valid.sum())
    found, _ = ref.hash_probe(tk, tv, keys, empty_key=-1)
    # every valid key must be found (invalid-only keys may still be found
    # when a duplicate of them was valid)
    assert bool(np.asarray(found)[np.asarray(valid)].all())
