"""Pallas kernels vs ref.py oracles: shape/dtype sweeps + hypothesis
property tests, all in interpret mode on CPU."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.block_prefix_sum import block_prefix_sum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash_probe import build_table, hash_probe
from repro.kernels.radix_histogram import radix_histogram
from repro.kernels.segmented_agg import segmented_sum


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 2, 256, 64),
                                     (1, 2, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, s, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_sweep():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 1, 256, 64)), jnp.float32)
    want = ref.flash_attention(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# segmented aggregation
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 40), st.floats(-10, 10)),
                min_size=1, max_size=300),
       st.sampled_from([8, 64, 200]))
def test_segmented_sum_property(rows, row_block):
    gids = jnp.asarray([r[0] for r in rows], jnp.int32)
    vals = jnp.asarray([r[1] for r in rows], jnp.float32)
    got = segmented_sum(gids, vals, 41, row_block=row_block, interpret=True)
    want = ref.segmented_agg(gids, vals, 41, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_segmented_sum_multi_slab():
    # more groups than one GROUP_BLOCK slab
    rng = np.random.default_rng(2)
    n, g = 5000, 2500
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    got = segmented_sum(gids, vals, g, interpret=True)
    want = ref.segmented_agg(gids, vals, g, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# radix histogram
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=500),
       st.sampled_from([16, 32]))
def test_radix_histogram_property(pids, nparts):
    p = jnp.asarray(pids, jnp.int32)
    got = radix_histogram(p, nparts, row_block=128, interpret=True)
    want = ref.radix_histogram(p, nparts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("table_size,n_keys,n_probes",
                         [(64, 30, 100), (256, 200, 500), (1024, 100, 64)])
def test_hash_probe_matches_ref(table_size, n_keys, n_probes):
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.choice(10_000, n_keys, replace=False), jnp.int32)
    vals = keys * 7
    tk, tv = build_table(keys, vals, table_size)
    probes = jnp.asarray(rng.integers(0, 10_000, n_probes), jnp.int32)
    got_f, got_v = hash_probe(tk, tv, probes, max_probes=table_size,
                              probe_block=64, interpret=True)
    want_f, want_v = ref.hash_probe(tk, tv, probes, empty_key=-1)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v)[np.asarray(got_f)],
                                  np.asarray(want_v)[np.asarray(want_f)])
    # semantic check against plain membership
    member = np.isin(np.asarray(probes), np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got_f), member)
    np.testing.assert_array_equal(np.asarray(got_v)[member],
                                  np.asarray(probes)[member] * 7)


# ---------------------------------------------------------------------------
# block prefix sum (compaction addresses)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=600),
       st.sampled_from([64, 128, 256]))
def test_block_prefix_sum_property(mask, row_block):
    m = jnp.asarray(mask, jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=row_block, interpret=True)
    want_pos, want_total = ref.block_prefix_sum(m)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(want_pos))
    assert int(total) == int(want_total)


def test_prefix_sum_crosses_blocks():
    m = jnp.ones((1000,), jnp.bool_)
    pos, total = block_prefix_sum(m, row_block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(pos), np.arange(1000))
    assert int(total) == 1000
