"""Chunkwise-parallel mLSTM (§Perf hillclimb 1) vs recurrent reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import xlstm
from repro.models.xlstm import (MLSTMState, _mlstm_chunkwise,
                                _mlstm_recurrent)


def _rand_inputs(b, s, nh, dh, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, s, nh, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, nh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, nh, dh)), jnp.float32)
    ig = jnp.asarray(rng.normal(0, 1, (b, s, nh)), jnp.float32)
    fg = jnp.asarray(rng.normal(2, 1, (b, s, nh)), jnp.float32)
    return q, k, v, ig, fg


@pytest.mark.parametrize("s", [64, 128, 256])
def test_chunkwise_matches_recurrent(s):
    b, nh, dh = 2, 2, 16

    class _Cfg:
        n_heads = nh
        mamba_expand = 2
        d_model = nh * dh // 2

    s0 = MLSTMState(jnp.zeros((b, nh, dh, dh)), jnp.zeros((b, nh, dh)),
                    jnp.full((b, nh), -1e30))
    args = _rand_inputs(b, s, nh, dh)
    s_rec, h_rec = _mlstm_recurrent(*args, s0)
    s_chk, h_chk = _mlstm_chunkwise(*args, s0)
    h_rec = np.asarray(h_rec).reshape(b, s, nh, dh)
    np.testing.assert_allclose(np.asarray(h_chk), h_rec, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk.c), np.asarray(s_rec.c),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk.n), np.asarray(s_rec.n),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk.m), np.asarray(s_rec.m),
                               rtol=2e-4, atol=2e-4)


def test_chunkwise_state_handoff_to_decode():
    """Prefill with chunkwise then decode recurrently: consistent stream."""
    b, s, nh, dh = 1, 128, 2, 16
    s0 = MLSTMState(jnp.zeros((b, nh, dh, dh)), jnp.zeros((b, nh, dh)),
                    jnp.full((b, nh), -1e30))
    q, k, v, ig, fg = _rand_inputs(b, s + 1, nh, dh, seed=1)
    # full recurrent pass over s+1 tokens = ground truth for the last token
    _, h_full = _mlstm_recurrent(q, k, v, ig, fg, s0)
    h_full = np.asarray(h_full).reshape(b, s + 1, nh, dh)
    # chunkwise over the first s, then one recurrent step
    cut = lambda a: a[:, :s]
    st, _ = _mlstm_chunkwise(cut(q), cut(k), cut(v), cut(ig), cut(fg), s0)
    st2, h_last = xlstm._mlstm_step(
        st, (q[:, s], k[:, s], v[:, s], ig[:, s], fg[:, s]))
    np.testing.assert_allclose(np.asarray(h_last), h_full[:, s],
                               rtol=2e-4, atol=2e-4)


def test_full_model_modes_agree():
    from repro.models import build_model
    from repro.models.model import synthetic_batch
    from repro.configs.base import ShapeSpec

    model = build_model(get_config("xlstm_125m", smoke=True))
    params = model.init(jax.random.key(0))
    batch = synthetic_batch(model, ShapeSpec("t", 64, 2, "train"))
    old = xlstm.MLSTM_MODE
    try:
        xlstm.MLSTM_MODE = "recurrent"
        l_rec, _ = model.forward(params, batch)
        xlstm.MLSTM_MODE = "chunkwise"
        l_chk, _ = model.forward(params, batch)
    finally:
        xlstm.MLSTM_MODE = old
    np.testing.assert_allclose(np.asarray(l_chk, np.float32),
                               np.asarray(l_rec, np.float32),
                               rtol=5e-2, atol=5e-2)
